//! Fig. 5 reproduction: end-to-end video-generation latency split into
//! attention vs everything-else, per method and sparsity.
//!
//!   * **RTX5090 (cost model)** — regenerates the paper's bars for
//!     Wan2.1-1.3B-480P and Wan2.1-14B-720P (2.30x / 4.35x headline).
//!   * **CPU (measured)** — real end-to-end generations through the
//!     coordinator on this testbed's DiT models: per-step denoise
//!     latency x sampling steps, full vs SLA2 tiers.  Shape check:
//!     SLA2 steps must be markedly cheaper than full-attention steps.
//!
//! Run: `cargo bench --bench fig5_e2e_latency`

use anyhow::Result;
use sla2::config::ServeConfig;
use sla2::coordinator::engine::Engine;
use sla2::coordinator::request::GenRequest;
use sla2::costmodel::{device, e2e, flops};
use sla2::util::bench::Table;
use sla2::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let artifacts = args.str("artifacts", "artifacts");
    let model = args.str("model", "dit-tiny");
    let steps = args.usize("steps", 6);

    // ---------------- modelled paper bars ----------------------------
    println!("=== Fig. 5: end-to-end latency, RTX5090 cost model \
              (50 sampling steps) ===\n");
    let dev = device::Device::rtx5090();
    let mut t = Table::new(&["model", "method", "attention s", "other s",
                             "total s", "e2e speedup"]);
    for pm in [&flops::WAN_1_3B, &flops::WAN_14B] {
        let full = e2e::estimate(&dev, pm, flops::AttnKind::Full, 1.0, 50,
                                 false);
        let rows = [
            ("Full Attention", full),
            ("VSA @95%", e2e::estimate(&dev, pm, flops::AttnKind::SparseOnly,
                                       0.05, 50, false)),
            ("VMoBA @95%", e2e::estimate(&dev, pm,
                                         flops::AttnKind::SparseOnly, 0.05,
                                         50, true)),
            ("SLA @95%", e2e::estimate(&dev, pm, flops::AttnKind::Sla, 0.05,
                                       50, false)),
            ("SLA2 @95%", e2e::estimate(&dev, pm,
                                        flops::AttnKind::Sla2 { quant: true },
                                        0.05, 50, false)),
            ("SLA2 @97%", e2e::estimate(&dev, pm,
                                        flops::AttnKind::Sla2 { quant: true },
                                        0.03, 50, false)),
        ];
        for (name, est) in rows {
            t.row(vec![pm.name.into(), name.into(),
                       format!("{:.1}", est.attention_s),
                       format!("{:.1}", est.other_s),
                       format!("{:.1}", est.total_s()),
                       format!("{:.2}x", full.total_s() / est.total_s())]);
        }
    }
    t.print();

    // ---------------- measured CPU end-to-end ------------------------
    println!("=== Fig. 5 companion: measured end-to-end generation on \
              this testbed (model {model}, {steps} steps, batch 1) ===\n");
    let mut t = Table::new(&["method", "total s", "s/step",
                             "speedup vs full"]);
    let mut full_total = None;
    let combos: &[(&str, &str)] = if model == "dit-tiny" {
        &[("full", "dense"), ("sla2", "s90")]
    } else {
        &[("full", "dense"), ("sla2", "s90"), ("sla2", "s95"),
          ("sla2", "s97"), ("vsa", "s95"), ("sla", "s95"),
          ("vmoba", "s95")]
    };
    for (variant, tier) in combos {
        let serve = ServeConfig {
            model: model.clone(),
            variant: variant.to_string(),
            tier: tier.to_string(),
            sample_steps: steps,
            max_batch: 1,
            batch_window_ms: 0,
            queue_capacity: 4,
        };
        let engine = match Engine::new(&artifacts, serve) {
            Ok(e) => e,
            Err(err) => {
                println!("  {variant}@{tier}: SKIP ({err:#})");
                continue;
            }
        };
        let req = [GenRequest::new(0, 1, 7, steps, tier)];
        engine.generate(&req)?; // warm: compile outside the timer
        let t0 = std::time::Instant::now();
        let reps = 2;
        for r in 0..reps {
            let req = [GenRequest::new(r, 1, 7 + r, steps, tier)];
            engine.generate(&req)?;
        }
        let total = t0.elapsed().as_secs_f64() / reps as f64;
        let speedup = match full_total {
            None => {
                full_total = Some(total);
                1.0
            }
            Some(f) => f / total,
        };
        t.row(vec![format!("{variant}@{tier}"), format!("{total:.2}"),
                   format!("{:.3}", total / steps as f64),
                   format!("{speedup:.2}x")]);
    }
    t.print();
    println!("note: CPU interpret-lowered HLO; the measured speedups \
              reflect HLO-level compute skipping, not GPU tile \
              efficiency — the RTX5090 table above carries the paper's \
              absolute claims.");
    Ok(())
}
