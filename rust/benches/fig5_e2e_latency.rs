//! Fig. 5 reproduction: end-to-end video-generation latency split into
//! attention vs everything-else, per method and sparsity.
//!
//!   * **RTX5090 (cost model)** — regenerates the paper's bars for
//!     Wan2.1-1.3B-480P and Wan2.1-14B-720P (2.30x / 4.35x headline).
//!   * **CPU (measured)** — real end-to-end generations through the
//!     coordinator on this testbed's DiT models: per-step denoise
//!     latency x sampling steps, full vs SLA2 tiers.  Shape check:
//!     SLA2 steps must be markedly cheaper than full-attention steps.
//!   * **Sharded serving (measured)** — aggregate throughput of the
//!     engine pool at 1 shard vs N shards: the host-orchestration half
//!     of the speedup story.
//!   * **Mixed-tier head-of-line (measured)** — a dense backlog in
//!     front of cheap sparse requests, served under the `fifo` vs the
//!     `class` scheduler: per-tier p50/p99 queue wait shows what the
//!     class-aware bypass buys.
//!   * **Streaming first-chunk latency (measured)** — the chunked
//!     reply path (`submit_streaming`) vs the monolithic one-shot
//!     reply: when the first frames reach the client vs the full clip
//!     (`stream_ttfc` rows).
//!   * **Overload shedding (measured)** — goodput, shed rate, degraded
//!     rate and the p99 of ADMITTED work at 1x/2x/4x offered load,
//!     with admission control on vs off (`overload_shed` rows): typed
//!     `overloaded` turn-aways plus tier degradation keep admitted
//!     latency bounded where the unprotected server lets the queue
//!     grow without limit.
//!   * **Stall recovery (measured)** — wedged backend calls (injected
//!     `hang` faults) at 0/1/2 hangs with the shard watchdog off vs on
//!     (`stall_recovery` rows): off, every hang permanently eats a
//!     shard slot and its rider request; on, the watchdog fences the
//!     wedged worker, retries the stolen batch on a replacement, and
//!     completion/goodput recover.
//!   * **Wire serde (measured)** — bytes per clip and encode/decode
//!     throughput of the v0 JSON framing vs the v1 binary framing on
//!     f32 clip payloads (`wire_serde` rows): raw little-endian
//!     tensors make the frames several times smaller and decode is a
//!     memcpy instead of a float parse.
//!   * **Connection sweep (measured)** — 1/100/1k/10k idle streaming
//!     connections parked on the reactor (`net_conn_sweep` rows):
//!     process thread count, resident memory, and the p99
//!     time-to-first-chunk of live submits riding alongside the idle
//!     herd.  Threads must stay O(reactor workers); tiers past the fd
//!     soft limit are skipped, not failed.
//!
//! Run: `cargo bench --bench fig5_e2e_latency [--json PATH|none]`
//! Writes `BENCH_fig5_e2e.json` by default.

use std::time::Instant;

use anyhow::Result;
use sla2::config::{default_num_shards, ServeConfig};
use sla2::coordinator::engine::Engine;
use sla2::coordinator::request::GenRequest;
use sla2::coordinator::wire::{self, FrameDecoder, WireFormat};
use sla2::coordinator::{run_trace, NetClient, Server, TraceConfig};
use sla2::costmodel::{device, e2e, flops};
use sla2::tensor::Tensor;
use sla2::util::bench::{self, Table};
use sla2::util::cli::Args;
use sla2::util::json::Json;
use sla2::util::rng::Pcg32;
use sla2::util::stats::Summary;

/// A numeric field from `/proc/self/status` (`Threads:` count,
/// `VmRSS:` kB, ...).  `None` off Linux or if the field is missing —
/// the sweep reports 0 rather than failing.
fn proc_status_field(key: &str) -> Option<u64> {
    let s = std::fs::read_to_string("/proc/self/status").ok()?;
    s.lines().find(|l| l.starts_with(key))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// The soft cap on open fds, from `/proc/self/limits` ("Max open
/// files" row: name, soft, hard, units).
fn open_files_soft_limit() -> Option<u64> {
    let s = std::fs::read_to_string("/proc/self/limits").ok()?;
    s.lines().find(|l| l.starts_with("Max open files"))
        .and_then(|l| l.split_whitespace().nth(3))
        .and_then(|v| v.parse().ok())
}

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let artifacts = args.str("artifacts", "artifacts");
    let model = args.str("model", "dit-tiny");
    let steps = args.usize("steps", 6);
    // "xla" replays AOT artifacts (skips sections when absent);
    // "--backend native" measures the pure-Rust SLA2 backend and runs
    // every measured section artifact-free.  --quant-mode picks how
    // the native backend's sla2 INT8 points execute (int8|sim|off).
    let backend = args.str("backend", "xla");
    let quant_mode = args.str("quant-mode", "int8");
    let mut json_rows: Vec<Json> = Vec::new();

    // ---------------- modelled paper bars ----------------------------
    println!("=== Fig. 5: end-to-end latency, RTX5090 cost model \
              (50 sampling steps) ===\n");
    let dev = device::Device::rtx5090();
    let mut t = Table::new(&["model", "method", "attention s", "other s",
                             "total s", "e2e speedup"]);
    for pm in [&flops::WAN_1_3B, &flops::WAN_14B] {
        let full = e2e::estimate(&dev, pm, flops::AttnKind::Full, 1.0, 50,
                                 false);
        let rows = [
            ("Full Attention", full),
            ("VSA @95%", e2e::estimate(&dev, pm, flops::AttnKind::SparseOnly,
                                       0.05, 50, false)),
            ("VMoBA @95%", e2e::estimate(&dev, pm,
                                         flops::AttnKind::SparseOnly, 0.05,
                                         50, true)),
            ("SLA @95%", e2e::estimate(&dev, pm, flops::AttnKind::Sla, 0.05,
                                       50, false)),
            ("SLA2 @95%", e2e::estimate(&dev, pm,
                                        flops::AttnKind::Sla2 { quant: true },
                                        0.05, 50, false)),
            ("SLA2 @97%", e2e::estimate(&dev, pm,
                                        flops::AttnKind::Sla2 { quant: true },
                                        0.03, 50, false)),
        ];
        for (name, est) in rows {
            t.row(vec![pm.name.into(), name.into(),
                       format!("{:.1}", est.attention_s),
                       format!("{:.1}", est.other_s),
                       format!("{:.1}", est.total_s()),
                       format!("{:.2}x", full.total_s() / est.total_s())]);
            json_rows.push(Json::obj()
                .push("section", "rtx5090_model")
                .push("model", pm.name)
                .push("method", name)
                .push("attention_s", est.attention_s)
                .push("other_s", est.other_s)
                .push("total_s", est.total_s())
                .push("speedup", full.total_s() / est.total_s()));
        }
    }
    t.print();

    // ---------------- measured CPU end-to-end ------------------------
    println!("=== Fig. 5 companion: measured end-to-end generation on \
              this testbed (model {model}, {steps} steps, batch 1) ===\n");
    let mut t = Table::new(&["method", "total s", "s/step",
                             "speedup vs full"]);
    let mut full_total = None;
    let combos: &[(&str, &str)] = if model == "dit-tiny" {
        &[("full", "dense"), ("sla2", "s90")]
    } else {
        &[("full", "dense"), ("sla2", "s90"), ("sla2", "s95"),
          ("sla2", "s97"), ("vsa", "s95"), ("sla", "s95"),
          ("vmoba", "s95")]
    };
    for (variant, tier) in combos {
        let serve = ServeConfig {
            model: model.clone(),
            variant: variant.to_string(),
            tier: tier.to_string(),
            backend: backend.clone(),
            quant_mode: quant_mode.clone(),
            sample_steps: steps,
            max_batch: 1,
            batch_window_ms: 0,
            queue_capacity: 4,
            num_shards: 1,
            ..ServeConfig::default()
        };
        let engine = match Engine::new(&artifacts, serve) {
            Ok(e) => e,
            Err(err) => {
                println!("  {variant}@{tier}: SKIP ({err:#})");
                continue;
            }
        };
        let req = [GenRequest::new(0, 1, 7, steps, tier)];
        // warm: compile outside the timer; a combination this backend
        // cannot serve (e.g. native has no vsa/sla/vmoba) skips its
        // row instead of aborting the whole bench
        if let Err(err) = engine.generate(&req) {
            println!("  {variant}@{tier}: SKIP ({err:#})");
            continue;
        }
        let t0 = std::time::Instant::now();
        let reps = 2;
        for r in 0..reps {
            let req = [GenRequest::new(r, 1, 7 + r, steps, tier)];
            engine.generate(&req)?;
        }
        let total = t0.elapsed().as_secs_f64() / reps as f64;
        let speedup = match full_total {
            None => {
                full_total = Some(total);
                1.0
            }
            Some(f) => f / total,
        };
        t.row(vec![format!("{variant}@{tier}"), format!("{total:.2}"),
                   format!("{:.3}", total / steps as f64),
                   format!("{speedup:.2}x")]);
        json_rows.push(Json::obj()
            .push("section", "cpu_measured")
            .push("method", format!("{variant}@{tier}"))
            .push("total_s", total)
            .push("s_per_step", total / steps as f64)
            .push("speedup_vs_full", speedup));
    }
    t.print();
    println!("note: CPU interpret-lowered HLO; the measured speedups \
              reflect HLO-level compute skipping, not GPU tile \
              efficiency — the RTX5090 table above carries the paper's \
              absolute claims.");

    // ---------------- sharded serving throughput ---------------------
    // same flag name as every other surface (serve-demo, serve_batch)
    let max_shards = args.usize("num-shards", default_num_shards().max(2));
    let shard_sweep: Vec<usize> = if max_shards <= 1 {
        vec![1]
    } else {
        vec![1, max_shards]
    };
    println!("\n=== Fig. 5 companion: engine-pool aggregate throughput \
              (model {model}, tier s90, {steps} steps) ===\n");
    let mut t = Table::new(&["shards", "requests", "wall s",
                             "throughput rps", "speedup vs 1 shard"]);
    let mut base_rps = None;
    for &shards in &shard_sweep {
        let n_requests = 4 * shards;
        let serve = ServeConfig {
            model: model.clone(),
            variant: "sla2".into(),
            tier: "s90".into(),
            backend: backend.clone(),
            quant_mode: quant_mode.clone(),
            sample_steps: steps,
            max_batch: 1,       // per-request dispatch: pure fan-out
            batch_window_ms: 0,
            queue_capacity: n_requests + shards + 4,
            num_shards: shards,
            ..ServeConfig::default()
        };
        let server = match Server::start(&artifacts, serve) {
            Ok(s) => s,
            Err(err) => {
                println!("  {shards} shard(s): SKIP ({err:#})");
                continue;
            }
        };
        // warm every shard: one compile per shard, outside the timer
        let warm: Vec<_> = (0..shards)
            .filter_map(|i| server.submit(1, 7 + i as u64, steps, "s90")
                .ok())
            .collect();
        for rx in warm {
            let _ = rx.recv();
        }
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .filter_map(|i| {
                server.submit((i % 10) as i32, 100 + i as u64, steps,
                              "s90").ok()
            })
            .collect();
        let mut completed = 0usize;
        for rx in rxs {
            if matches!(rx.recv(), Ok(Ok(_))) {
                completed += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = completed as f64 / wall.max(1e-9);
        let speedup = match base_rps {
            None => {
                base_rps = Some(rps);
                1.0
            }
            Some(b) => rps / b,
        };
        t.row(vec![format!("{shards}"), format!("{completed}"),
                   format!("{wall:.2}"), format!("{rps:.2}"),
                   format!("{speedup:.2}x")]);
        json_rows.push(Json::obj()
            .push("section", "serve_shards")
            .push("num_shards", shards)
            .push("requests", completed)
            .push("wall_s", wall)
            .push("throughput_rps", rps)
            .push("speedup_vs_1shard", speedup));
        server.shutdown();
    }
    t.print();

    // ---------------- mixed-tier head-of-line ------------------------
    // A dense backlog submitted ahead of cheap sparse requests on ONE
    // shard (so scheduling order, not parallelism, decides the wait).
    // FIFO must drain the dense backlog first; the class scheduler
    // lets the aged sparse class bypass — visible as a collapse of the
    // sparse tier's queue-wait percentiles.
    let n_dense = args.usize("hol-dense", 4);
    let n_sparse = args.usize("hol-sparse", 4);
    println!("\n=== Fig. 5 companion: mixed-tier head-of-line, fifo vs \
              class scheduler (model {model}, {n_dense} dense + \
              {n_sparse} s90, {steps} steps) ===\n");
    let mut t = Table::new(&["scheduler", "tier", "requests",
                             "queue p50 ms", "queue p99 ms"]);
    for scheduler in ["fifo", "class"] {
        let serve = ServeConfig {
            model: model.clone(),
            variant: "sla2".into(),
            tier: "s90".into(),
            backend: backend.clone(),
            quant_mode: quant_mode.clone(),
            sample_steps: steps,
            max_batch: 1,
            batch_window_ms: 0,
            queue_capacity: n_dense + n_sparse + 4,
            num_shards: 1,
            scheduler: scheduler.into(),
            bypass_threshold_ms: 10,
            ..ServeConfig::default()
        };
        let server = match Server::start(&artifacts, serve) {
            Ok(s) => s,
            Err(err) => {
                println!("  {scheduler}: SKIP ({err:#})");
                continue;
            }
        };
        // warm both tiers' executables outside the measurement
        for tier in ["dense", "s90"] {
            if let Ok(rx) = server.submit(1, 7, steps, tier) {
                let _ = rx.recv();
            }
        }
        // the head-of-line shape: dense backlog first, sparse behind
        let mut rxs = Vec::new();
        for i in 0..n_dense {
            if let Ok(rx) =
                server.submit(1, 100 + i as u64, steps, "dense")
            {
                rxs.push(("dense", rx));
            }
        }
        for i in 0..n_sparse {
            if let Ok(rx) =
                server.submit(1, 200 + i as u64, steps, "s90")
            {
                rxs.push(("s90", rx));
            }
        }
        let mut waits: Vec<(&str, f64)> = Vec::new();
        for (tier, rx) in rxs {
            if let Ok(Ok(resp)) = rx.recv() {
                waits.push((tier, resp.metrics.queue_ms));
            }
        }
        for tier in ["dense", "s90"] {
            let tier_waits: Vec<f64> = waits.iter()
                .filter(|(t, _)| *t == tier)
                .map(|(_, w)| *w)
                .collect();
            if tier_waits.is_empty() {
                continue;
            }
            let s = Summary::of(&tier_waits);
            t.row(vec![scheduler.into(), tier.into(),
                       format!("{}", tier_waits.len()),
                       format!("{:.1}", s.p50),
                       format!("{:.1}", s.p99)]);
            json_rows.push(Json::obj()
                .push("section", "mixed_tier_hol")
                .push("scheduler", scheduler)
                .push("tier", tier)
                .push("requests", tier_waits.len())
                .push("queue_p50_ms", s.p50)
                .push("queue_p99_ms", s.p99));
        }
        server.shutdown();
    }
    t.print();

    // ---------------- streaming time-to-first-chunk ------------------
    // Chunked delivery vs the monolithic reply: submit the same
    // request one-shot and streaming, and measure when the FIRST
    // frames reach the client vs when the full clip does.  In-process
    // both land close together (chunks of one sub-batch emit
    // back-to-back); the interesting spread appears when the batch
    // planner splits a dispatched batch, because earlier sub-batches
    // stream out while later ones are still denoising.
    let chunk_frames = args.usize("chunk-frames", 1);
    println!("\n=== Fig. 5 companion: streaming first-chunk latency \
              (model {model}, tier s90, {steps} steps, chunk_frames \
              {chunk_frames}) ===\n");
    let mut t = Table::new(&["mode", "first data ms", "full clip ms",
                             "chunks"]);
    let serve = ServeConfig {
        model: model.clone(),
        variant: "sla2".into(),
        tier: "s90".into(),
        backend: backend.clone(),
        quant_mode: quant_mode.clone(),
        sample_steps: steps,
        max_batch: 1,
        batch_window_ms: 0,
        queue_capacity: 8,
        num_shards: 1,
        chunk_frames,
        ..ServeConfig::default()
    };
    match Server::start(&artifacts, serve) {
        Err(err) => println!("  SKIP ({err:#})"),
        Ok(server) => {
            // warm the executable outside the timers
            if let Ok(rx) = server.submit(1, 7, steps, "s90") {
                let _ = rx.recv();
            }
            // one-shot reference
            let t0 = Instant::now();
            let resp = server.submit(1, 31, steps, "s90")
                .ok().and_then(|rx| rx.recv().ok());
            let oneshot_ms = t0.elapsed().as_secs_f64() * 1e3;
            if let Some(Ok(_)) = resp {
                t.row(vec!["oneshot".into(),
                           format!("{oneshot_ms:.1}"),
                           format!("{oneshot_ms:.1}"), "1".into()]);
                json_rows.push(Json::obj()
                    .push("section", "stream_ttfc")
                    .push("mode", "oneshot")
                    .push("first_data_ms", oneshot_ms)
                    .push("full_clip_ms", oneshot_ms)
                    .push("chunks", 1usize));
            }
            // streaming: same seed, chunked delivery
            let t0 = Instant::now();
            if let Ok(stream) = server.submit_streaming(1, 31, steps,
                                                        "s90") {
                let mut first_ms = None;
                let mut chunks = 0usize;
                while let Some(Ok(chunk)) = stream.recv() {
                    first_ms.get_or_insert_with(
                        || t0.elapsed().as_secs_f64() * 1e3);
                    chunks += 1;
                    if chunk.last {
                        break;
                    }
                }
                let full_ms = t0.elapsed().as_secs_f64() * 1e3;
                let first_ms = first_ms.unwrap_or(full_ms);
                t.row(vec!["stream".into(), format!("{first_ms:.1}"),
                           format!("{full_ms:.1}"),
                           format!("{chunks}")]);
                json_rows.push(Json::obj()
                    .push("section", "stream_ttfc")
                    .push("mode", "stream")
                    .push("chunk_frames", chunk_frames)
                    .push("first_data_ms", first_ms)
                    .push("full_clip_ms", full_ms)
                    .push("chunks", chunks));
            }
            server.shutdown();
            t.print();
        }
    }

    // ---------------- overload shedding ------------------------------
    // Open-loop Poisson traces at multiples of the server's measured
    // capacity, with admission control off (shed_watermark 1.0, the
    // default) vs on.  The protected server turns away excess work
    // with a typed `overloaded` (clients see retry_after_ms) and
    // reroutes degradable requests to a cheaper sparsity tier; the
    // payoff is a bounded p99 for the work it DOES admit.  The trace
    // mixes s90 (degradable to s95) with s97 (bottom of the ladder,
    // can only shed) so both counters exercise at overload.
    println!("\n=== Fig. 5 companion: overload shedding & tier \
              degradation (model {model}, {steps} steps) ===\n");
    let mut t = Table::new(&["shedding", "load", "offered", "completed",
                             "goodput rps", "shed", "degraded",
                             "p99 admitted ms"]);
    for shedding in [false, true] {
        let serve = ServeConfig {
            model: model.clone(),
            variant: "sla2".into(),
            tier: "s90".into(),
            backend: backend.clone(),
            quant_mode: quant_mode.clone(),
            sample_steps: steps,
            max_batch: 2,
            batch_window_ms: 0,
            queue_capacity: 64,
            num_shards: 1,
            // watermark at 4 queued requests, so 2x load trips it
            // decisively; 1.0 disables admission
            shed_watermark: if shedding { 0.0625 } else { 1.0 },
            ..ServeConfig::default()
        };
        let server = match Server::start(&artifacts, serve) {
            Ok(s) => s,
            Err(err) => {
                println!("  shedding={shedding}: SKIP ({err:#})");
                continue;
            }
        };
        // warm every tier the trace (or degradation) can route to,
        // then probe capacity closed-loop
        for tier in ["s90", "s95", "s97"] {
            if let Ok(rx) = server.submit(1, 7, steps, tier) {
                let _ = rx.recv();
            }
        }
        let t0 = Instant::now();
        let probe = 3;
        for i in 0..probe {
            if let Ok(rx) = server.submit(1, 50 + i, steps, "s90") {
                let _ = rx.recv();
            }
        }
        let capacity_rps = probe as f64
            / t0.elapsed().as_secs_f64().max(1e-6);
        for mult in [1usize, 2, 4] {
            let trace = TraceConfig {
                rps: capacity_rps * mult as f64,
                n_requests: 8 * mult,
                tiers: vec!["s90".into(), "s97".into()],
                steps,
                seed: 11 * mult as u64,
                deadline_ms: 0,
                allow_degrade: shedding,
            };
            let report = run_trace(&server, &trace)?;
            let offered = report.offered.max(1) as f64;
            let p99_ms = report.latency.as_ref()
                .map(|l| l.p99 * 1e3)
                .unwrap_or(0.0);
            t.row(vec![format!("{}", if shedding { "on" } else { "off" }),
                       format!("{mult}x"),
                       format!("{}", report.offered),
                       format!("{}", report.completed),
                       format!("{:.2}", report.throughput_rps()),
                       format!("{}", report.shed),
                       format!("{}", report.degraded),
                       format!("{p99_ms:.1}")]);
            json_rows.push(Json::obj()
                .push("section", "overload_shed")
                .push("shedding", shedding)
                .push("load_mult", mult)
                .push("offered", report.offered)
                .push("offered_rps", capacity_rps * mult as f64)
                .push("completed", report.completed)
                .push("goodput_rps", report.throughput_rps())
                .push("shed", report.shed)
                .push("shed_rate", report.shed as f64 / offered)
                .push("degraded", report.degraded)
                .push("degraded_rate", report.degraded as f64 / offered)
                .push("rejected", report.rejected)
                .push("p99_admitted_ms", p99_ms));
        }
        server.shutdown();
    }
    t.print();

    // ---------------- stall recovery (watchdog) ----------------------
    // Injected `hang` clauses wedge a shard mid-run: the backend call
    // never returns and the shard slot is pinned.  With the watchdog
    // off that slot (and the request riding it) is simply lost — the
    // surviving shard carries the rest.  With it on, the stale
    // heartbeat is detected, the wedged worker is fenced, its batch
    // retries on a replacement, and every request completes.  One-shot
    // `nth=` counters re-arm when a replacement rebuilds its injector,
    // so the stalls column can exceed the injected hang count: that is
    // sustained recovery under a repeatedly-wedging backend, not a
    // miscount.  No warm-up pass: warming would consume the `nth=`
    // counters, and the compile cost rides the first request of every
    // row equally.
    let stall_requests = args.usize("stall-requests", 8);
    println!("\n=== Fig. 5 companion: stall recovery, watchdog off vs \
              on (model {model}, {steps} steps, 2 shards, \
              {stall_requests} requests) ===\n");
    let mut t = Table::new(&["watchdog", "hangs", "offered", "completed",
                             "lost", "stalls", "goodput rps", "p99 ms"]);
    for watchdog in [false, true] {
        for hangs in [0usize, 1, 2] {
            let fault_plan = match hangs {
                0 => String::new(),
                1 => "hang:shard=0:nth=2".to_string(),
                _ => "hang:shard=0:nth=2,hang:shard=1:nth=2".to_string(),
            };
            let serve = ServeConfig {
                model: model.clone(),
                variant: "sla2".into(),
                tier: "s90".into(),
                backend: backend.clone(),
                quant_mode: quant_mode.clone(),
                sample_steps: steps,
                max_batch: 1,
                batch_window_ms: 0,
                queue_capacity: stall_requests + 4,
                num_shards: 2,
                retry_budget: 3,
                retry_backoff_ms: 5,
                quarantine_cooldown_ms: 20,
                stall_threshold_ms: if watchdog { 300 } else { 0 },
                fault_plan,
                ..ServeConfig::default()
            };
            let server = match Server::start(&artifacts, serve) {
                Ok(s) => s,
                Err(err) => {
                    println!("  watchdog={watchdog} hangs={hangs}: \
                              SKIP ({err:#})");
                    continue;
                }
            };
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..stall_requests)
                .filter_map(|i| {
                    server.submit((i % 10) as i32, 300 + i as u64,
                                  steps, "s90").ok()
                })
                .collect();
            let offered = rxs.len();
            // per-reply collector threads: a request wedged behind a
            // hung shard (watchdog off) never resolves, so every wait
            // is bounded by a shared deadline instead of recv()
            let deadline = std::time::Duration::from_secs(
                20 + 2 * steps as u64);
            let waiters: Vec<_> = rxs.into_iter()
                .map(|rx| {
                    std::thread::spawn(move || {
                        let t = Instant::now();
                        match rx.recv_timeout(deadline) {
                            Ok(Ok(_)) =>
                                Some(t.elapsed().as_secs_f64() * 1e3),
                            _ => None,
                        }
                    })
                })
                .collect();
            let lat_ms: Vec<f64> = waiters.into_iter()
                .filter_map(|w| w.join().ok().flatten())
                .collect();
            let wall = t0.elapsed().as_secs_f64();
            let completed = lat_ms.len();
            let lost = offered - completed;
            let goodput = completed as f64 / wall.max(1e-9);
            let p99_ms = if lat_ms.is_empty() {
                0.0
            } else {
                Summary::of(&lat_ms).p99
            };
            let stalls = server.metrics_snapshot()
                .get("stalls").and_then(|v| v.as_usize())
                .unwrap_or(0);
            t.row(vec![format!("{}", if watchdog { "on" } else { "off" }),
                       format!("{hangs}"), format!("{offered}"),
                       format!("{completed}"), format!("{lost}"),
                       format!("{stalls}"), format!("{goodput:.2}"),
                       format!("{p99_ms:.1}")]);
            json_rows.push(Json::obj()
                .push("section", "stall_recovery")
                .push("watchdog", watchdog)
                .push("hangs", hangs)
                .push("offered", offered)
                .push("completed", completed)
                .push("lost", lost)
                .push("stalls", stalls)
                .push("goodput_rps", goodput)
                .push("p99_ms", p99_ms));
            if watchdog || hangs == 0 {
                server.shutdown();
            } else {
                // a hung shard thread never exits and the watchdog is
                // off, so shutdown (which joins shards) would hang the
                // bench — leak the server and let process exit reap it
                std::mem::forget(server);
            }
        }
    }
    t.print();

    // ---------------- wire serde: v0 JSON vs v1 binary ---------------
    // Frame-level cost of shipping one f32 clip, measured on the real
    // codec: a dense randn payload (the realistic case — denoised
    // latents have full-precision mantissas) plus a 90%-zero payload
    // where zrle engages.  Throughput is normalized to RAW tensor
    // bytes so the formats compare apples-to-apples.
    println!("\n=== Wire serde: v0 JSON vs v1 binary framing (f32 clip \
              payloads) ===\n");
    {
        let mut rng = Pcg32::seeded(4242);
        let dense = Tensor::randn(&[16, 32, 32, 3], &mut rng);
        let mut sparse_data = vec![0.0f32; 16 * 32 * 32 * 3];
        for v in sparse_data.iter_mut() {
            if rng.f64() < 0.1 {
                *v = rng.normal();
            }
        }
        let sparse =
            Tensor::from_f32(&[16, 32, 32, 3], sparse_data)?;
        let meta = Json::obj().push("type", "clip").push("id", 1usize);
        let reps = 20usize;
        // each payload's v1 row comes first so it anchors the "vs v1"
        // ratio of the v0 row that follows it
        let cases: [(&str, &str, WireFormat, bool, &Tensor); 4] = [
            ("v1 binary", "dense", WireFormat::V1, false, &dense),
            ("v0 json", "dense", WireFormat::V0, false, &dense),
            ("v1 binary+zrle", "zero90", WireFormat::V1, true, &sparse),
            ("v0 json", "zero90", WireFormat::V0, false, &sparse),
        ];
        let mut t = Table::new(&["format", "payload", "bytes/clip",
                                 "vs v1", "encode MB/s", "decode MB/s"]);
        let mut anchor = 1usize;
        for (name, payload, fmt, compress, tensor) in cases {
            let raw_bytes = tensor.f32s()?.len() * 4;
            let t0 = Instant::now();
            let mut bytes = Vec::new();
            for _ in 0..reps {
                bytes = wire::encode(&meta, Some(tensor), fmt,
                                     compress)?;
            }
            let enc_mbps = (raw_bytes * reps) as f64
                / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
            let t0 = Instant::now();
            for _ in 0..reps {
                let mut dec = FrameDecoder::new();
                dec.feed(&bytes);
                let f = dec.next()?.expect("complete frame");
                // force the tensor out whichever path carried it
                let clip = match f.tensor {
                    Some(tt) => tt,
                    None => wire::tensor_from_json(
                        f.meta.req("clip")?)?,
                };
                assert_eq!(clip.shape, tensor.shape);
            }
            let dec_mbps = (raw_bytes * reps) as f64
                / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
            let ratio = if name.starts_with("v1") {
                anchor = bytes.len().max(1);
                1.0
            } else {
                bytes.len() as f64 / anchor as f64
            };
            t.row(vec![name.into(), payload.into(),
                       format!("{}", bytes.len()),
                       format!("{ratio:.1}x"),
                       format!("{enc_mbps:.0}"),
                       format!("{dec_mbps:.0}")]);
            json_rows.push(Json::obj()
                .push("section", "wire_serde")
                .push("format", name)
                .push("payload", payload)
                .push("raw_bytes", raw_bytes)
                .push("bytes_per_clip", bytes.len())
                .push("vs_v1_ratio", ratio)
                .push("encode_mbps", enc_mbps)
                .push("decode_mbps", dec_mbps));
        }
        t.print();
        println!("note: v0 prints every f32 as a shortest-roundtrip \
                  f64 literal (~5x the raw bytes); v1 ships the raw \
                  little-endian words and zrle only engages when it \
                  actually shrinks the payload.");
    }

    // ---------------- connection scale sweep -------------------------
    // Park an increasing herd of idle streaming connections on the
    // reactor and measure what they cost: process thread count (must
    // stay O(net_workers)), resident memory, and the p99 time-to-
    // first-chunk of live submits that share the reactor with the
    // herd.  Tiers that would blow the fd soft limit are skipped.
    let net_workers = args.usize("net-workers", 4);
    let ttfc_samples = args.usize("ttfc-samples", 5);
    println!("\n=== Net connection sweep: idle connections vs threads / \
              memory / TTFC (model {model}, {net_workers} reactor \
              workers) ===\n");
    let serve = ServeConfig {
        model: model.clone(),
        variant: "sla2".into(),
        tier: "s90".into(),
        backend: backend.clone(),
        quant_mode: quant_mode.clone(),
        sample_steps: steps,
        max_batch: 1,
        batch_window_ms: 0,
        queue_capacity: 16,
        num_shards: 1,
        chunk_frames: 1,
        listen_addr: "127.0.0.1:0".into(),
        net_workers,
        ..ServeConfig::default()
    };
    match Server::start(&artifacts, serve) {
        Err(err) => println!("  SKIP ({err:#})"),
        Ok(server) => {
            let addr = server.local_addr()
                .map(|a| a.to_string())
                .expect("listen_addr was set");
            // warm the executable outside every timer
            if let Ok(mut c) = NetClient::connect(&addr) {
                if let Ok(id) = c.submit(1, 7, steps, "s90", true) {
                    let _ = c.collect_stream(id);
                }
            }
            // each idle conn costs 2 fds in THIS process (client +
            // server end); leave headroom for shards and artifacts
            let fd_budget = open_files_soft_limit()
                .map(|soft| (soft.saturating_sub(256) / 2) as usize);
            let mut t = Table::new(&["conns", "threads", "rss MiB",
                                     "p99 ttfc ms"]);
            let mut idle: Vec<std::net::TcpStream> = Vec::new();
            for target in [1usize, 100, 1_000, 10_000] {
                if let Some(budget) = fd_budget {
                    if target > budget {
                        println!("  {target} conns: SKIP (fd soft \
                                  limit allows ~{budget})");
                        continue;
                    }
                }
                let mut hit_limit = false;
                while idle.len() < target {
                    match std::net::TcpStream::connect(&addr) {
                        Ok(s) => idle.push(s),
                        Err(err) => {
                            println!("  {target} conns: SKIP at \
                                      {} ({err})", idle.len());
                            hit_limit = true;
                            break;
                        }
                    }
                }
                if hit_limit {
                    break;
                }
                // let the reactor register the new arrivals
                std::thread::sleep(
                    std::time::Duration::from_millis(200));
                let mut ttfc_ms: Vec<f64> = Vec::new();
                for s in 0..ttfc_samples {
                    let Ok(mut c) = NetClient::connect(&addr) else {
                        break;
                    };
                    let t0 = Instant::now();
                    let Ok(id) = c.submit(1, 9_000 + s as u64, steps,
                                          "s90", true) else { break };
                    let mut first: Option<f64> = None;
                    if c.collect_stream_with(id, |_| {
                        first.get_or_insert_with(
                            || t0.elapsed().as_secs_f64() * 1e3);
                    }).is_ok() {
                        if let Some(ms) = first {
                            ttfc_ms.push(ms);
                        }
                    }
                }
                let p99 = if ttfc_ms.is_empty() {
                    0.0
                } else {
                    Summary::of(&ttfc_ms).p99
                };
                let threads = proc_status_field("Threads:")
                    .unwrap_or(0);
                let rss_mib = proc_status_field("VmRSS:")
                    .unwrap_or(0) as f64 / 1024.0;
                t.row(vec![format!("{target}"), format!("{threads}"),
                           format!("{rss_mib:.1}"),
                           format!("{p99:.1}")]);
                json_rows.push(Json::obj()
                    .push("section", "net_conn_sweep")
                    .push("idle_conns", target)
                    .push("net_workers", net_workers)
                    .push("threads", threads as usize)
                    .push("rss_mib", rss_mib)
                    .push("ttfc_samples", ttfc_ms.len())
                    .push("p99_ttfc_ms", p99));
            }
            t.print();
            println!("note: threads stay O(net_workers) however many \
                      connections are parked — the reactor multiplexes \
                      them on epoll; rss grows with per-connection \
                      buffers only.");
            drop(idle);
            server.shutdown();
        }
    }

    if let Some(path) = args.json_path("BENCH_fig5_e2e.json") {
        // host stanza: makes latency rows comparable across runners
        // (an avx2 8-core box and a scalar 2-core box are different
        // experiments, not a regression)
        let host = Json::obj()
            .push("kernel_isa",
                  sla2::runtime::native::simd::active().name())
            .push("cores", std::thread::available_parallelism()
                .map(|c| c.get()).unwrap_or(1))
            .push("shared_pool_width",
                  sla2::util::threadpool::shared_pool_width());
        let report = bench::report("fig5_e2e", json_rows)
            .push("host", host);
        bench::write_json(&path, &report)?;
        println!("\nwrote {path}");
    }
    Ok(())
}
