//! Table 2 reproduction — the three ablations:
//!
//!   1. **QAT**: quantized vs non-quantized forward — attention-output
//!      error on the same inputs + the cost model's kernel speedup
//!      (paper: quality drops w/o QAT, quant buys ~1.3x).
//!   2. **Learnable router vs Top-k router**: Stage-1 training of the
//!      router + alpha, reporting the attention-MSE trajectory (the
//!      learnable router's benefit is exactly this fit; the Top-k
//!      router is the identity-projection initialization).
//!   3. **Sparsity sweep**: SLA2 fidelity at 85-97 % sparsity
//!      (paper: quality degrades gracefully with sparsity).
//!
//! Run: `cargo bench --bench table2`

use anyhow::Result;
use sla2::config::TrainConfig;
use sla2::costmodel::{device, flops};
use sla2::runtime::Runtime;
use sla2::tensor::Tensor;
use sla2::trainer::Trainer;
use sla2::util::bench::Table;
use sla2::util::cli::Args;
use sla2::util::rng::Pcg32;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let artifacts = args.str("artifacts", "artifacts");
    let rt = Runtime::load(&artifacts)?;
    println!("=== Table 2 (ablations) ===\n");

    // ---------------- ablation 1: QAT ------------------------------
    let (n, d) = (256, 64);
    let mut rng = Pcg32::seeded(21);
    let mut q_err = 0.0;
    let mut nq_err = 0.0;
    let draws = 4;
    for _ in 0..draws {
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let full = rt.execute("attn_flash_dense_n256",
                              &[q.clone(), k.clone(), v.clone()])?;
        let qq = rt.execute("attn_sla2_s95_n256",
                            &[q.clone(), k.clone(), v.clone()])?;
        let nq = rt.execute("attn_sla2_noquant_s95_n256", &[q, k, v])?;
        q_err += qq[0].rel_err(&full[0])? / draws as f64;
        nq_err += nq[0].rel_err(&full[0])? / draws as f64;
    }
    let dev = device::Device::rtx5090();
    let g = |keep| flops::AttnGeometry { keep, ..flops::FIG4_GEOM };
    let tq = device::kernel_time_default(
        &dev, flops::AttnKind::Sla2 { quant: true }, &g(0.05));
    let tn = device::kernel_time_default(
        &dev, flops::AttnKind::Sla2 { quant: false }, &g(0.05));
    let mut t = Table::new(&["config", "attn rel.err", "kernel speedup \
                              (model)"]);
    t.row(vec!["SLA2 w/ QAT (INT8 fwd)".into(), format!("{q_err:.4}"),
               format!("{:.2}x", tn.seconds / tq.seconds)]);
    t.row(vec!["SLA2 w/o quant".into(), format!("{nq_err:.4}"),
               "1.00x".into()]);
    println!("-- QAT ablation (quant adds {:.4} error, buys {:.2}x) --",
             q_err - nq_err, tn.seconds / tq.seconds);
    t.print();

    // ------------- ablation 2: learnable router vs Top-k ------------
    println!("-- Router ablation: Stage-1 fit from the Top-k-router \
              init (identity projections = SLA's heuristic) --");
    let cfg = TrainConfig {
        model: args.str("model", "dit-tiny"),
        variant: "sla2".into(),
        tier: args.str("tier", "s90"),
        stage1_steps: args.usize("stage1-steps", 24),
        stage2_steps: 0,
        batch: 2,
        seed: 11,
        log_every: 1_000_000,
    };
    let trainer = Trainer::new(&artifacts, cfg.clone())?;
    let mut state = trainer.init_state()?;
    let losses = trainer.run_stage1(&mut state, cfg.stage1_steps,
                                    |_, _| {})?;
    let mut t = Table::new(&["router", "attention MSE"]);
    t.row(vec!["Top-k (identity proj, alpha=0.5)".into(),
               format!("{:.6}", losses[0])]);
    t.row(vec![format!("learnable (after {} stage-1 steps)",
                       cfg.stage1_steps),
               format!("{:.6}", losses.last().unwrap())]);
    t.print();
    println!("mean alpha learned: {:.3}\n", trainer.mean_alpha(&state)?);

    // ------------- ablation 3: sparsity sweep ------------------------
    println!("-- Sparsity sweep (fidelity vs sparsity; paper: 85-97 %) --");
    let mut t = Table::new(&["tier", "block sparsity", "attn rel.err",
                             "FLOPs (paper, T)"]);
    let mut rng = Pcg32::seeded(22);
    let q = Tensor::randn(&[n, d], &mut rng);
    let k = Tensor::randn(&[n, d], &mut rng);
    let v = Tensor::randn(&[n, d], &mut rng);
    let full = rt.execute("attn_flash_dense_n256",
                          &[q.clone(), k.clone(), v.clone()])?;
    let paper = flops::WAN_1_3B;
    for (tier, keep) in [("s90", 0.10), ("s95", 0.05), ("s97", 0.03)] {
        let o = rt.execute(&format!("attn_sla2_{tier}_n256"),
                           &[q.clone(), k.clone(), v.clone()])?;
        let err = o[0].rel_err(&full[0])?;
        let gg = paper.geometry(keep);
        let fl = flops::model_attention_flops(
            flops::AttnKind::Sla2 { quant: true }, &gg, paper.layers,
            paper.heads) / 1e12;
        t.row(vec![tier.into(), format!("{:.1}%", gg.sparsity() * 100.0),
                   format!("{err:.4}"), format!("{fl:.2}")]);
    }
    t.print();
    println!("paper shape to verify: error grows monotonically with \
              sparsity; QAT costs little error for its 1.3x; the \
              learnable router strictly improves on the Top-k init.");
    Ok(())
}
