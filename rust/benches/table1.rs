//! Table 1 reproduction: quality + efficiency of SLA2 vs baselines.
//!
//! Paper columns -> our columns (proxy substitutions per DESIGN.md §2):
//!   IQ  -> sharpness        AQ -> PSNR vs full-attention rollout
//!   OC  -> SSIM vs rollout  MS -> motion smoothness
//!   SC  -> subject consistency
//!   VR  -> attention relative error (lower = better, sign-flipped)
//!   FLOPs    -> analytic, at the paper's Wan geometry (abs. comparable)
//!   Sparsity -> achieved block sparsity
//!
//! Quality rows are measured by actually GENERATING clips through the
//! coordinator with each method and scoring them against the
//! full-attention rollout with the same seeds (untrained weights:
//! orderings, not absolute VBench values, are the claim under test).
//!
//! Run: `cargo bench --bench table1 [-- --model dit-tiny --steps 4]`

use anyhow::Result;
use sla2::config::ServeConfig;
use sla2::coordinator::engine::Engine;
use sla2::coordinator::request::GenRequest;
use sla2::costmodel::flops::{self, AttnKind};
use sla2::tensor::Tensor;
use sla2::util::bench::Table;
use sla2::util::cli::Args;
use sla2::video::metrics;

const SEEDS: [u64; 3] = [101, 202, 303];

fn generate_clips(artifacts: &str, model: &str, variant: &str, tier: &str,
                  steps: usize, params: Option<&[Tensor]>)
                  -> Result<Vec<Tensor>> {
    let serve = ServeConfig {
        model: model.into(),
        variant: variant.into(),
        tier: tier.into(),
        sample_steps: steps,
        max_batch: 1,
        batch_window_ms: 0,
        queue_capacity: 16,
        num_shards: 1,
        ..ServeConfig::default()
    };
    let mut engine = Engine::new(artifacts, serve)?;
    if let Some(p) = params {
        engine.set_params(p)?;
    }
    let reqs: Vec<GenRequest> = SEEDS.iter().enumerate()
        .map(|(i, &s)| GenRequest::new(i as u64, (i % 10) as i32, s, steps,
                                       tier))
        .collect();
    Ok(engine.generate(&reqs)?.into_iter().map(|(c, _)| c).collect())
}

/// Briefly fine-tune so the DiT produces non-zero, method-sensitive
/// velocities (AdaLN-zero init makes every method's rollout identical
/// — the quality columns would be degenerate on untrained weights).
fn warm_params(artifacts: &str, model: &str,
               train_steps: usize) -> Result<Option<Vec<Tensor>>> {
    if train_steps == 0 {
        return Ok(None);
    }
    use sla2::config::TrainConfig;
    use sla2::trainer::Trainer;
    let (tier, batch) = if model == "dit-tiny" { ("s90", 2) }
                        else { ("s95", 4) };
    let cfg = TrainConfig {
        model: model.into(), variant: "sla2".into(), tier: tier.into(),
        stage1_steps: 0, stage2_steps: train_steps, batch, seed: 5,
        log_every: 1_000_000,
    };
    let trainer = Trainer::new(artifacts, cfg)?;
    let mut state = trainer.init_state()?;
    let losses = trainer.run_stage2(&mut state, train_steps, |_, _| {})?;
    println!("(warmed weights: {} stage-2 steps, loss {:.4} -> {:.4})\n",
             train_steps, losses.first().unwrap(), losses.last().unwrap());
    Ok(Some(state.params))
}

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let artifacts = args.str("artifacts", "artifacts");
    let model = args.str("model", "dit-tiny");
    let steps = args.usize("steps", 4);

    let train_steps = args.usize("train-steps", 25);
    println!("=== Table 1 (proxy metrics; model {model}, {steps} sampling \
              steps, {} seeds) ===\n", SEEDS.len());
    let params = warm_params(&artifacts, &model, train_steps)?;

    // reference rollout: full attention, same seeds + weights
    let reference = generate_clips(&artifacts, &model, "full", "dense",
                                   steps, params.as_deref())?;

    // (display name, serve variant, tier, cost kind, keep)
    let mut rows: Vec<(String, &str, &str, AttnKind, f64)> = vec![
        ("Full Attention".into(), "full", "dense", AttnKind::Full, 1.0),
    ];
    let tier_list: &[(&str, f64)] = if model == "dit-tiny" {
        &[("s90", 0.10)]
    } else {
        &[("s90", 0.10), ("s95", 0.05), ("s97", 0.03)]
    };
    for (tier, keep) in tier_list {
        rows.push((format!("SLA2 @{tier}"), "sla2", tier,
                   AttnKind::Sla2 { quant: true }, *keep));
    }
    if model != "dit-tiny" {
        rows.push(("VMoBA @s95".into(), "vmoba", "s95",
                   AttnKind::SparseOnly, 0.05));
        rows.push(("VSA @s95".into(), "vsa", "s95",
                   AttnKind::SparseOnly, 0.05));
        rows.push(("SLA @s95".into(), "sla", "s95", AttnKind::Sla, 0.05));
    }

    let paper = flops::WAN_1_3B; // FLOPs column at the paper's geometry
    let mut table = Table::new(&["method", "IQ'", "OC'", "AQ'(dB)", "MS'",
                                 "SC'", "FLOPs(paper,T)", "sparsity"]);
    for (name, variant, tier, kind, keep) in rows {
        let clips = match generate_clips(&artifacts, &model, variant, tier,
                                         steps, params.as_deref()) {
            Ok(c) => c,
            Err(e) => {
                println!("  {name}: SKIP ({e:#})");
                continue;
            }
        };
        let n = clips.len() as f64;
        let mut iq = 0.0;
        let mut oc = 0.0;
        let mut aq = 0.0;
        let mut ms = 0.0;
        let mut sc = 0.0;
        for (clip, rf) in clips.iter().zip(&reference) {
            let r = metrics::report(clip, rf);
            iq += r.sharpness;
            oc += r.ssim_vs_ref;
            aq += r.psnr_vs_ref;
            ms += r.motion_smoothness;
            sc += r.subject_consistency;
        }
        let g = paper.geometry(keep);
        let fl = flops::model_attention_flops(kind, &g, paper.layers,
                                              paper.heads) / 1e12;
        let sparsity = if matches!(kind, AttnKind::Full) {
            0.0
        } else {
            g.sparsity()
        };
        table.row(vec![
            name,
            format!("{:.3}", iq / n),
            format!("{:.3}", oc / n),
            format!("{:.1}", aq / n),
            format!("{:.3}", ms / n),
            format!("{:.3}", sc / n),
            format!("{:.2}", fl),
            format!("{:.1}%", sparsity * 100.0),
        ]);
    }
    table.print();
    println!("paper shape to verify: SLA2 rows dominate VSA/VMoBA/SLA at \
              equal sparsity on AQ'/OC'; FLOPs column matches Table 1's \
              52.75T / 5.xT / 2.xT / 1.8T ladder.");
    Ok(())
}
