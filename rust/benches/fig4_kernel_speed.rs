//! Fig. 4 reproduction: kernel speed (effective TOPS = 4N^2d / t)
//! versus sparsity for SLA2 and every baseline.
//!
//! Two result sets, clearly labelled:
//!   * **RTX5090 (cost model)** — the paper-calibrated roofline model
//!     (DESIGN.md §2): this regenerates the figure's shape (who wins,
//!     by what factor, where the linear-branch floor saturates).
//!   * **CPU (measured)** — wall-clock of the real AOT HLO kernels on
//!     this testbed; interpret-mode-lowered HLO on one CPU core is NOT
//!     a GPU proxy, but it proves the kernels execute and lets the
//!     bench detect structural regressions (e.g. a dense fallback
//!     sneaking in would destroy the sparse/dense latency ratio).
//!
//! Run: `cargo bench --bench fig4_kernel_speed [--json PATH|none]`
//! Writes `BENCH_fig4_kernel.json` by default.

use anyhow::Result;
use sla2::costmodel::{device, flops};

/// The SAME harness the conformance tests gate on (naive full-softmax
/// reference, peaked-input generator, rel_err) — so the shoot-out's
/// accuracy column is measured against the identical oracle.
#[path = "../tests/common/conformance.rs"]
#[allow(dead_code)]
mod conformance;
use sla2::runtime::Runtime;
use sla2::tensor::Tensor;
use sla2::util::bench::{self, run_for, Table};
use sla2::util::cli::Args;
use sla2::util::json::Json;
use sla2::util::rng::Pcg32;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let artifacts = args.str("artifacts", "artifacts");
    let mut json_rows: Vec<Json> = Vec::new();

    // ------- modelled RTX5090 curve over a dense sparsity grid -------
    println!("=== Fig. 4: kernel speed, RTX5090 cost model \
              (N=32768, d=128) ===\n");
    let dev = device::Device::rtx5090();
    let g = |keep| flops::AttnGeometry { keep, ..flops::FIG4_GEOM };
    let fa2 = device::kernel_time_default(&dev, flops::AttnKind::Full,
                                          &g(1.0));
    let mut t = Table::new(&["sparsity", "SLA2 TOPS", "SLA2-noQ", "VSA",
                             "VMoBA", "SLA", "FlashAttn2"]);
    for sparsity in [0.80, 0.85, 0.90, 0.95, 0.97] {
        let keep = 1.0 - sparsity;
        let tops = |kind, prof: Option<device::MethodProfile>| -> f64 {
            let kt = match prof {
                Some(p) => device::kernel_time(&dev, kind, &g(keep), p),
                None => device::kernel_time_default(&dev, kind, &g(keep)),
            };
            kt.effective_tops
        };
        let methods: [(&str, f64); 6] = [
            ("SLA2", tops(flops::AttnKind::Sla2 { quant: true }, None)),
            ("SLA2-noQ", tops(flops::AttnKind::Sla2 { quant: false },
                              None)),
            ("VSA", tops(flops::AttnKind::SparseOnly, None)),
            ("VMoBA", tops(flops::AttnKind::SparseOnly,
                           Some(device::vmoba_profile()))),
            ("SLA", tops(flops::AttnKind::Sla, None)),
            ("FlashAttn2", fa2.effective_tops),
        ];
        let mut cells = vec![format!("{:.0}%", sparsity * 100.0)];
        for (method, eff_tops) in methods {
            cells.push(format!("{eff_tops:.0}"));
            json_rows.push(Json::obj()
                .push("section", "rtx5090_model")
                .push("method", method)
                .push("sparsity", sparsity)
                .push("eff_tops", eff_tops));
        }
        t.row(cells);
    }
    t.print();
    let s97 = device::kernel_time_default(
        &dev, flops::AttnKind::Sla2 { quant: true }, &g(0.03));
    let vsa95 = device::kernel_time_default(
        &dev, flops::AttnKind::SparseOnly, &g(0.05));
    let vmoba95 = device::kernel_time(&dev, flops::AttnKind::SparseOnly,
                                      &g(0.05), device::vmoba_profile());
    println!("headlines: SLA2@97% = {:.1}x FlashAttn2 (paper 18.7x), \
              {:.1}x vs VSA@95% (paper 2.6x), {:.1}x vs VMoBA@95% \
              (paper 11.7x)\n",
             fa2.seconds / s97.seconds, vsa95.seconds / s97.seconds,
             vmoba95.seconds / s97.seconds);

    // ------- measured CPU latencies of the real artifacts ------------
    println!("=== Fig. 4 companion: measured CPU latency of the AOT \
              kernels (N=256, d=64; structural check, not a GPU \
              proxy) ===\n");
    // the measured section only appends to json_rows; both the run
    // and SKIP paths fall through to the single report write below,
    // so the perf-trajectory file is always produced
    match Runtime::load(&artifacts) {
        Err(err) => println!("  SKIP measured section ({err:#})"),
        Ok(rt) => {
            let mut rng = Pcg32::seeded(4);
            let q = Tensor::randn(&[256, 64], &mut rng);
            let k = Tensor::randn(&[256, 64], &mut rng);
            let v = Tensor::randn(&[256, 64], &mut rng);
            let mut t = Table::new(&["artifact", "mean ms", "p50 ms",
                                     "p99 ms", "eff. GOPS"]);
            let c = flops::full_attention_flops(256, 64);
            let arts = ["attn_flash_dense_n256", "attn_sla2_s90_n256",
                        "attn_sla2_s95_n256", "attn_sla2_s97_n256",
                        "attn_sla2_noquant_s95_n256", "attn_sla_s95_n256",
                        "attn_vsa_s95_n256", "attn_vmoba_s95_n256"];
            for name in arts {
                if rt.manifest().artifact(name).is_err() {
                    continue;
                }
                // warm compile outside the timer; a broken artifact
                // skips, it must not abort the report
                if let Err(err) = rt.execute(
                    name, &[q.clone(), k.clone(), v.clone()])
                {
                    println!("  SKIP {name} ({err:#})");
                    continue;
                }
                let b = run_for(name, 2, 1.0, 50, || {
                    rt.execute(name, &[q.clone(), k.clone(), v.clone()])
                        .unwrap();
                });
                t.row(vec![name.into(), format!("{:.2}", b.mean_ms()),
                           format!("{:.2}", b.summary.p50 * 1e3),
                           format!("{:.2}", b.summary.p99 * 1e3),
                           format!("{:.2}", c / b.summary.mean / 1e9)]);
                json_rows.push(b.to_json()
                    .push("section", "cpu_measured")
                    .push("eff_gops", c / b.summary.mean / 1e9));
            }
            t.print();
        }
    }

    // ------- native pure-Rust kernels (always runs: no artifacts) ----
    // CI's smoke row: the native sparse/linear kernel must beat the
    // native full-softmax kernel, or block skipping is structurally
    // broken.  CPU wall-clock, not a GPU proxy — same caveat as above.
    // N=512 (not 256): t_n=32 keeps 3/2/1 blocks at s90/s95/s97, so
    // the three tier rows measure genuinely different work — at t_n=16
    // s95 and s97 would both round to kept=1 and differ only by noise.
    println!("\n=== Fig. 4 companion: native pure-Rust kernels (N=512, \
              d=64; artifact-free) ===\n");
    {
        use sla2::runtime::native::attention::{self, QuantMode,
                                               Sla2Params};
        let (n, d, b_q, b_k) = (512usize, 64usize, 32usize, 16usize);
        let t_m = n / b_q;
        let mut rng = Pcg32::seeded(9);
        let q = rng.normal_vec(n * d);
        let k = rng.normal_vec(n * d);
        let v = rng.normal_vec(n * d);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let alpha = vec![0.0f32; t_m];
        let c = flops::full_attention_flops(n, d);
        let mut t = Table::new(&["kernel", "sparsity", "mean ms",
                                 "p99 ms", "speedup vs native full"]);
        let full = run_for("native_full", 2, 0.5, 30, || {
            attention::full_attention(&q, &k, &v, n, d);
        });
        let mut emit = |name: &str, sparsity: f64,
                        b: &sla2::util::bench::BenchResult| {
            t.row(vec![name.into(), format!("{:.0}%", sparsity * 100.0),
                       format!("{:.2}", b.mean_ms()),
                       format!("{:.2}", b.summary.p99 * 1e3),
                       format!("{:.2}x",
                               full.summary.mean / b.summary.mean)]);
            json_rows.push(b.to_json()
                .push("section", "native_measured")
                .push("method", name)
                .push("sparsity", sparsity)
                .push("eff_gops", c / b.summary.mean / 1e9)
                .push("speedup_vs_full",
                      full.summary.mean / b.summary.mean));
        };
        emit("native_full", 0.0, &full);
        for (tier, k_pct, quant) in
            [("s90", 0.10, QuantMode::Int8),
             ("s95", 0.05, QuantMode::Int8),
             ("s97", 0.03, QuantMode::Int8),
             ("s95_noquant", 0.05, QuantMode::Off)] {
            let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                                 alpha_logit: &alpha };
            let t_n = n / b_k;
            let kept = attention::top_k_count(k_pct, t_n);
            let sparsity = 1.0 - kept as f64 / t_n as f64;
            let b = run_for(&format!("native_sla2_{tier}"), 2, 0.5, 30,
                            || {
                attention::sla2_attention(&q, &k, &v, &p, k_pct, n, d,
                                          b_q, b_k, quant);
            });
            emit(&format!("native_sla2_{tier}"), sparsity, &b);
        }
        t.print();
    }

    // ------- real INT8 integer kernels vs the f32 fake-quant path ----
    // The paper's Sec. 5 speedup claim, measured instead of asserted:
    // quant_mode="int8" (i8 buffers + i8 x i8 -> i32 GEMMs + hoisted
    // per-tile dequant) against quant_mode="sim" (identical int8-
    // valued operands, f32 matmuls).  The two modes are bit-identical
    // in OUTPUT (pinned by the native_backend parity suite), so every
    // speedup below is pure kernel efficiency, not accuracy trade.
    // Shapes are dit-small's head geometry (d=64, b_q=32, b_k=16).
    println!("\n=== Fig. 4 companion: real INT8 integer kernels vs f32 \
              fake-quant (dit-small head shapes: N=256, d=64, b_q=32, \
              b_k=16; artifact-free) ===\n");
    {
        use sla2::runtime::native::attention::{self, QuantMode,
                                               Sla2Params,
                                               quantize_rows_int8};
        use sla2::runtime::native::linalg;
        use std::hint::black_box;
        let (n, d, b_q, b_k) = (256usize, 64usize, 32usize, 16usize);
        let (t_m, t_n) = (n / b_q, n / b_k);
        let mut rng = Pcg32::seeded(11);
        let q = rng.normal_vec(n * d);
        let k = rng.normal_vec(n * d);
        let v = rng.normal_vec(n * d);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let alpha = vec![0.0f32; t_m];
        let mut t = Table::new(&["scope", "sparsity", "sim ms",
                                 "int8 ms", "int8 speedup"]);
        let mut emit = |scope: &str, tier: &str, sparsity: f64,
                        sim: &sla2::util::bench::BenchResult,
                        int8: &sla2::util::bench::BenchResult| {
            let speedup = sim.summary.mean / int8.summary.mean;
            t.row(vec![scope.into(),
                       format!("{:.1}%", sparsity * 100.0),
                       format!("{:.3}", sim.mean_ms()),
                       format!("{:.3}", int8.mean_ms()),
                       format!("{speedup:.2}x")]);
            json_rows.push(Json::obj()
                .push("section", "int8_vs_sim")
                .push("scope", scope)
                .push("tier", tier)
                .push("sparsity", sparsity)
                .push("sim_mean_ms", sim.mean_ms())
                .push("int8_mean_ms", int8.mean_ms())
                .push("speedup_int8_vs_sim", speedup));
        };

        // (a) GEMM micro: the quantized Q-block x K-tile product on
        // exactly the operands the attention loop feeds the kernels.
        // REPS tiles per timed closure amortize timer overhead at the
        // realistic (tiny) tile shapes.
        const REPS: usize = 64;
        let (qq, _) = quantize_rows_int8(&q[..b_q * d], d);
        let (kq, _) = quantize_rows_int8(&k[..b_k * d], d);
        let qq_f: Vec<f32> = qq.iter().map(|&x| x as f32).collect();
        let kq_f: Vec<f32> = kq.iter().map(|&x| x as f32).collect();
        let g_sim = run_for("gemm_qk_sim", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::matmul_nt(&qq_f, &kq_f, b_q, d, b_k));
            }
        });
        let g_int8 = run_for("gemm_qk_int8", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::gemm_i8_nt(&qq, &kq, b_q, d, b_k));
            }
        });
        emit("gemm_qk", "tile", 0.0, &g_sim, &g_int8);
        // P V tile shapes: (b_q, b_k) x (b_k, d)
        let pq: Vec<i8> = (0..b_q * b_k)
            .map(|i| (i % 128) as i8)
            .collect();
        let vq: Vec<i8> = kq[..b_k * d].to_vec();
        let pq_f: Vec<f32> = pq.iter().map(|&x| x as f32).collect();
        let vq_f: Vec<f32> = vq.iter().map(|&x| x as f32).collect();
        let p_sim = run_for("gemm_pv_sim", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::matmul(&pq_f, &vq_f, b_q, b_k, d));
            }
        });
        let p_int8 = run_for("gemm_pv_int8", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::gemm_i8_i32(&pq, &vq, b_q, b_k, d));
            }
        });
        emit("gemm_pv", "tile", 0.0, &p_sim, &p_int8);

        // (b) the whole sla2 attention op, int8 vs sim, per tier —
        // router + linear branch + online softmax are shared between
        // the modes, so this is the end-to-end kernel win the serve
        // path actually sees at each sparsity.
        let mut op_s90_speedup = None;
        for (tier, k_pct) in [("s90", 0.10), ("s95", 0.05),
                              ("s97", 0.03)] {
            let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                                 alpha_logit: &alpha };
            let kept = attention::top_k_count(k_pct, t_n);
            let sparsity = 1.0 - kept as f64 / t_n as f64;
            let b_sim = run_for(&format!("attn_{tier}_sim"), 2, 0.5, 30,
                                || {
                black_box(attention::sla2_attention(
                    &q, &k, &v, &p, k_pct, n, d, b_q, b_k,
                    QuantMode::Sim));
            });
            let b_int8 = run_for(&format!("attn_{tier}_int8"), 2, 0.5,
                                 30, || {
                black_box(attention::sla2_attention(
                    &q, &k, &v, &p, k_pct, n, d, b_q, b_k,
                    QuantMode::Int8));
            });
            if tier == "s90" {
                op_s90_speedup =
                    Some(b_sim.summary.mean / b_int8.summary.mean);
            }
            emit("attention_op", tier, sparsity, &b_sim, &b_int8);
        }
        t.print();
        println!("headline: integer QK GEMM {:.2}x, integer PV GEMM \
                  {:.2}x vs f32 fake-quant; whole sla2 op {:.2}x at \
                  s90 (acceptance floor 1.3x at >=90% sparsity)\n",
                 g_sim.summary.mean / g_int8.summary.mean,
                 p_sim.summary.mean / p_int8.summary.mean,
                 op_s90_speedup.unwrap_or(f64::NAN));
    }

    // ------- variant shoot-out: rel_err x speedup per variant/tier ---
    // The tentpole's evaluation: every first-class native variant
    // (`sla2` learnable-routed sparse+linear, `sparge2` top-k+top-p
    // sparse-only, `svg_ear` error-aware routed) on the SAME peaked
    // inputs the conformance suite gates on, reporting accuracy (rel
    // err vs naive full softmax) against measured speedup over the
    // native full-softmax kernel at each served tier.  CPU wall-clock,
    // not a GPU proxy — same caveat as the sections above.
    println!("\n=== Fig. 4 companion: variant shoot-out (sla2 vs \
              sparge2 vs svg_ear; peaked inputs, dit-small head N=256, \
              d=64; artifact-free) ===\n");
    {
        use sla2::runtime::native::attention::{self, QuantMode,
                                               Sla2Params};
        use std::hint::black_box;
        let shape = conformance::SHAPES[1]; // dit-small-head
        let (n, d, b_q, b_k) = (shape.n, shape.d, shape.b_q, shape.b_k);
        let (t_m, t_n) = shape.tiles();
        let (q, k, v) = conformance::peaked_qkv(
            n, d, b_q, b_k, conformance::PEAK_AMP, 42);
        let full_ref = conformance::naive_attention(&q, &k, &v, n, d);
        let eye = conformance::eye(d);
        let alpha = vec![12.0f32; t_m];
        let full_b = run_for("shootout_full", 2, 0.5, 30, || {
            black_box(attention::full_attention(&q, &k, &v, n, d));
        });
        let mut t = Table::new(&["variant", "tier", "sparsity",
                                 "rel_err", "mean ms",
                                 "speedup vs full"]);
        for (tier, k_pct) in [("s90", 0.10), ("s95", 0.05),
                              ("s97", 0.03)] {
            let kept = attention::top_k_count(k_pct, t_n);
            let sparsity = 1.0 - kept as f64 / t_n as f64;
            let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                                 alpha_logit: &alpha };
            for variant in ["sla2", "sparge2", "svg_ear"] {
                let run = || match variant {
                    "sla2" => attention::sla2_attention(
                        &q, &k, &v, &p, k_pct, n, d, b_q, b_k,
                        QuantMode::Int8),
                    "sparge2" => attention::sparge2_attention(
                        &q, &k, &v, k_pct, attention::SPARGE2_TOP_P,
                        n, d, b_q, b_k, QuantMode::Int8),
                    _ => attention::svg_ear_attention(
                        &q, &k, &v, k_pct, n, d, b_q, b_k,
                        QuantMode::Int8),
                };
                let err = conformance::rel_err(&run(), &full_ref);
                let b = run_for(&format!("shootout_{variant}_{tier}"),
                                2, 0.5, 30, || {
                    black_box(run());
                });
                let speedup = full_b.summary.mean / b.summary.mean;
                t.row(vec![variant.into(), tier.into(),
                           format!("{:.1}%", sparsity * 100.0),
                           format!("{err:.2e}"),
                           format!("{:.3}", b.mean_ms()),
                           format!("{speedup:.2}x")]);
                json_rows.push(Json::obj()
                    .push("section", "variant_shootout")
                    .push("variant", variant)
                    .push("tier", tier)
                    .push("sparsity", sparsity)
                    .push("rel_err", err)
                    .push("mean_ms", b.mean_ms())
                    .push("speedup_vs_full", speedup));
            }
        }
        t.print();
        println!("accuracy bar: conformance gates rel_err < 1e-3 (f32) \
                  at >= 90% sparsity; the rows above run the INT8 \
                  path, whose allowance is 1e-1\n");
    }

    // ------- SIMD dispatch vs forced-scalar --------------------------
    // The ISA-dispatch payoff, measured on THIS host: the dispatched
    // kernels (whatever `simd::active()` resolved to) against the same
    // calls pinned to the portable scalar reference via the
    // thread-local override.  Integer rows are bit-identical across
    // ISAs, f32 rows parity-bounded (docs/KERNELS.md §7), so every
    // speedup is pure instruction-level parallelism.  On a host with
    // no SIMD (active = scalar) the ratios read ~1.0x by construction.
    {
        use sla2::runtime::native::attention::{self, quantize_rows_int8,
                                               QuantMode, Sla2Params};
        use sla2::runtime::native::simd::{self, KernelIsa};
        use sla2::runtime::native::{linalg, stats};
        use std::hint::black_box;
        use std::sync::atomic::Ordering;

        let isa = simd::active();
        println!("\n=== Fig. 4 companion: SIMD dispatch ({isa}) vs \
                  forced-scalar (dit-small head N=256, d=64, b_q=32, \
                  b_k=16; artifact-free) ===\n");
        let (n, d, b_q, b_k) = (256usize, 64usize, 32usize, 16usize);
        let t_m = n / b_q;
        let mut rng = Pcg32::seeded(13);
        let q = rng.normal_vec(n * d);
        let k = rng.normal_vec(n * d);
        let v = rng.normal_vec(n * d);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let alpha = vec![0.0f32; t_m];
        let mut t = Table::new(&["scope", "tier", "scalar ms",
                                 "simd ms", "simd speedup"]);
        let mut emit = |scope: &str, tier: &str,
                        scalar: &sla2::util::bench::BenchResult,
                        simd_b: &sla2::util::bench::BenchResult| {
            let speedup = scalar.summary.mean / simd_b.summary.mean;
            t.row(vec![scope.into(), tier.into(),
                       format!("{:.3}", scalar.mean_ms()),
                       format!("{:.3}", simd_b.mean_ms()),
                       format!("{speedup:.2}x")]);
            json_rows.push(Json::obj()
                .push("section", "simd_vs_scalar")
                .push("scope", scope)
                .push("tier", tier)
                .push("isa", isa.name())
                .push("scalar_mean_ms", scalar.mean_ms())
                .push("simd_mean_ms", simd_b.mean_ms())
                .push("speedup_simd_vs_scalar", speedup));
            speedup
        };

        // (a) GEMM micro on the attention loop's own tile operands;
        // REPS per timed closure amortizes timer overhead (and the
        // per-closure cost of arming the thread-local ISA override)
        const REPS: usize = 64;
        let (qq, _) = quantize_rows_int8(&q[..b_q * d], d);
        let (kq, _) = quantize_rows_int8(&k[..b_k * d], d);
        let qq_f: Vec<f32> = qq.iter().map(|&x| x as f32).collect();
        let kq_f: Vec<f32> = kq.iter().map(|&x| x as f32).collect();
        let s_qk = run_for("simd_gemm_qk_scalar", 2, 0.5, 30, || {
            simd::with_forced_isa(KernelIsa::Scalar, || {
                for _ in 0..REPS {
                    black_box(linalg::gemm_i8_nt(&qq, &kq, b_q, d, b_k));
                }
            });
        });
        let v_qk = run_for("simd_gemm_qk", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::gemm_i8_nt(&qq, &kq, b_q, d, b_k));
            }
        });
        let headline_gemm = emit("gemm_i8_qk", "tile", &s_qk, &v_qk);
        let pq: Vec<i8> = (0..b_q * b_k).map(|i| (i % 128) as i8)
            .collect();
        let vq: Vec<i8> = kq[..b_k * d].to_vec();
        let s_pv = run_for("simd_gemm_pv_scalar", 2, 0.5, 30, || {
            simd::with_forced_isa(KernelIsa::Scalar, || {
                for _ in 0..REPS {
                    black_box(linalg::gemm_i8_i32(&pq, &vq, b_q, b_k, d));
                }
            });
        });
        let v_pv = run_for("simd_gemm_pv", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::gemm_i8_i32(&pq, &vq, b_q, b_k, d));
            }
        });
        emit("gemm_i8_pv", "tile", &s_pv, &v_pv);
        let s_f32 = run_for("simd_matmul_nt_scalar", 2, 0.5, 30, || {
            simd::with_forced_isa(KernelIsa::Scalar, || {
                for _ in 0..REPS {
                    black_box(linalg::matmul_nt(&qq_f, &kq_f, b_q, d,
                                                b_k));
                }
            });
        });
        let v_f32 = run_for("simd_matmul_nt", 2, 0.5, 30, || {
            for _ in 0..REPS {
                black_box(linalg::matmul_nt(&qq_f, &kq_f, b_q, d, b_k));
            }
        });
        emit("matmul_nt_f32", "tile", &s_f32, &v_f32);

        // (b) the whole sla2 op per served tier, dispatched vs scalar
        let mut op_s90 = f64::NAN;
        for (tier, k_pct) in [("s90", 0.10), ("s95", 0.05),
                              ("s97", 0.03)] {
            let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                                 alpha_logit: &alpha };
            let b_scalar = run_for(&format!("simd_op_{tier}_scalar"), 2,
                                   0.5, 30, || {
                simd::with_forced_isa(KernelIsa::Scalar, || {
                    black_box(attention::sla2_attention(
                        &q, &k, &v, &p, k_pct, n, d, b_q, b_k,
                        QuantMode::Int8));
                });
            });
            let b_simd = run_for(&format!("simd_op_{tier}"), 2, 0.5, 30,
                                 || {
                black_box(attention::sla2_attention(
                    &q, &k, &v, &p, k_pct, n, d, b_q, b_k,
                    QuantMode::Int8));
            });
            let s = emit("attention_op", tier, &b_scalar, &b_simd);
            if tier == "s90" {
                op_s90 = s;
            }
        }
        t.print();
        println!("headline: {isa} integer QK GEMM {headline_gemm:.2}x \
                  vs scalar; whole sla2 op {op_s90:.2}x at s90\n");

        // (c) intra-head parallelism: b=1 long-sequence regime, where
        // head-level fan-out has nothing to fan — query-block chunks of
        // ONE head spread across the shared pool instead.  Both sides
        // run the dispatched ISA: this row isolates the split win.
        let pool_w = sla2::util::threadpool::shared_pool_width();
        let n_long = 4096usize;
        println!("=== Fig. 4 companion: intra-head split (b=1, N=4096, \
                  d=64; splits={pool_w}) ===\n");
        let ql = rng.normal_vec(n_long * d);
        let kl = rng.normal_vec(n_long * d);
        let vl = rng.normal_vec(n_long * d);
        let alpha_l = vec![0.0f32; n_long / b_q];
        let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                             alpha_logit: &alpha_l };
        let b_seq = run_for("intra_head_seq", 1, 1.0, 10, || {
            black_box(attention::sla2_attention(
                &ql, &kl, &vl, &p, 0.05, n_long, d, b_q, b_k,
                QuantMode::Int8));
        });
        let before = stats().intra_head_splits.load(Ordering::Relaxed);
        let b_par = run_for("intra_head_split", 1, 1.0, 10, || {
            black_box(attention::sla2_attention_split(
                &ql, &kl, &vl, &p, 0.05, n_long, d, b_q, b_k,
                QuantMode::Int8, pool_w));
        });
        let split_bumps =
            stats().intra_head_splits.load(Ordering::Relaxed) - before;
        let speedup = b_seq.summary.mean / b_par.summary.mean;
        println!("  seq {:.2} ms, split {:.2} ms => {speedup:.2}x \
                  (splits counter +{split_bumps})\n",
                 b_seq.mean_ms(), b_par.mean_ms());
        json_rows.push(Json::obj()
            .push("section", "intra_head_split")
            .push("scope", "attention_op")
            .push("tier", "s95")
            .push("n", n_long)
            .push("splits", pool_w)
            .push("intra_head_splits", split_bumps as usize)
            .push("seq_mean_ms", b_seq.mean_ms())
            .push("split_mean_ms", b_par.mean_ms())
            .push("speedup_split_vs_seq", speedup));
    }

    if let Some(path) = args.json_path("BENCH_fig4_kernel.json") {
        let host = Json::obj()
            .push("kernel_isa",
                  sla2::runtime::native::simd::active().name())
            .push("cores", std::thread::available_parallelism()
                .map(|c| c.get()).unwrap_or(1))
            .push("shared_pool_width",
                  sla2::util::threadpool::shared_pool_width());
        let report = bench::report("fig4_kernel", json_rows)
            .push("host", host);
        bench::write_json(&path, &report)?;
        println!("wrote {path}");
    }
    Ok(())
}
