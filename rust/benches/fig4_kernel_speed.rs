//! Fig. 4 reproduction: kernel speed (effective TOPS = 4N^2d / t)
//! versus sparsity for SLA2 and every baseline.
//!
//! Two result sets, clearly labelled:
//!   * **RTX5090 (cost model)** — the paper-calibrated roofline model
//!     (DESIGN.md §2): this regenerates the figure's shape (who wins,
//!     by what factor, where the linear-branch floor saturates).
//!   * **CPU (measured)** — wall-clock of the real AOT HLO kernels on
//!     this testbed; interpret-mode-lowered HLO on one CPU core is NOT
//!     a GPU proxy, but it proves the kernels execute and lets the
//!     bench detect structural regressions (e.g. a dense fallback
//!     sneaking in would destroy the sparse/dense latency ratio).
//!
//! Run: `cargo bench --bench fig4_kernel_speed [--json PATH|none]`
//! Writes `BENCH_fig4_kernel.json` by default.

use anyhow::Result;
use sla2::costmodel::{device, flops};
use sla2::runtime::Runtime;
use sla2::tensor::Tensor;
use sla2::util::bench::{self, run_for, Table};
use sla2::util::cli::Args;
use sla2::util::json::Json;
use sla2::util::rng::Pcg32;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)
        .filter(|a| a != "--bench"));
    let artifacts = args.str("artifacts", "artifacts");
    let mut json_rows: Vec<Json> = Vec::new();

    // ------- modelled RTX5090 curve over a dense sparsity grid -------
    println!("=== Fig. 4: kernel speed, RTX5090 cost model \
              (N=32768, d=128) ===\n");
    let dev = device::Device::rtx5090();
    let g = |keep| flops::AttnGeometry { keep, ..flops::FIG4_GEOM };
    let fa2 = device::kernel_time_default(&dev, flops::AttnKind::Full,
                                          &g(1.0));
    let mut t = Table::new(&["sparsity", "SLA2 TOPS", "SLA2-noQ", "VSA",
                             "VMoBA", "SLA", "FlashAttn2"]);
    for sparsity in [0.80, 0.85, 0.90, 0.95, 0.97] {
        let keep = 1.0 - sparsity;
        let tops = |kind, prof: Option<device::MethodProfile>| -> f64 {
            let kt = match prof {
                Some(p) => device::kernel_time(&dev, kind, &g(keep), p),
                None => device::kernel_time_default(&dev, kind, &g(keep)),
            };
            kt.effective_tops
        };
        let methods: [(&str, f64); 6] = [
            ("SLA2", tops(flops::AttnKind::Sla2 { quant: true }, None)),
            ("SLA2-noQ", tops(flops::AttnKind::Sla2 { quant: false },
                              None)),
            ("VSA", tops(flops::AttnKind::SparseOnly, None)),
            ("VMoBA", tops(flops::AttnKind::SparseOnly,
                           Some(device::vmoba_profile()))),
            ("SLA", tops(flops::AttnKind::Sla, None)),
            ("FlashAttn2", fa2.effective_tops),
        ];
        let mut cells = vec![format!("{:.0}%", sparsity * 100.0)];
        for (method, eff_tops) in methods {
            cells.push(format!("{eff_tops:.0}"));
            json_rows.push(Json::obj()
                .push("section", "rtx5090_model")
                .push("method", method)
                .push("sparsity", sparsity)
                .push("eff_tops", eff_tops));
        }
        t.row(cells);
    }
    t.print();
    let s97 = device::kernel_time_default(
        &dev, flops::AttnKind::Sla2 { quant: true }, &g(0.03));
    let vsa95 = device::kernel_time_default(
        &dev, flops::AttnKind::SparseOnly, &g(0.05));
    let vmoba95 = device::kernel_time(&dev, flops::AttnKind::SparseOnly,
                                      &g(0.05), device::vmoba_profile());
    println!("headlines: SLA2@97% = {:.1}x FlashAttn2 (paper 18.7x), \
              {:.1}x vs VSA@95% (paper 2.6x), {:.1}x vs VMoBA@95% \
              (paper 11.7x)\n",
             fa2.seconds / s97.seconds, vsa95.seconds / s97.seconds,
             vmoba95.seconds / s97.seconds);

    // ------- measured CPU latencies of the real artifacts ------------
    println!("=== Fig. 4 companion: measured CPU latency of the AOT \
              kernels (N=256, d=64; structural check, not a GPU \
              proxy) ===\n");
    // the measured section only appends to json_rows; both the run
    // and SKIP paths fall through to the single report write below,
    // so the perf-trajectory file is always produced
    match Runtime::load(&artifacts) {
        Err(err) => println!("  SKIP measured section ({err:#})"),
        Ok(rt) => {
            let mut rng = Pcg32::seeded(4);
            let q = Tensor::randn(&[256, 64], &mut rng);
            let k = Tensor::randn(&[256, 64], &mut rng);
            let v = Tensor::randn(&[256, 64], &mut rng);
            let mut t = Table::new(&["artifact", "mean ms", "p50 ms",
                                     "p99 ms", "eff. GOPS"]);
            let c = flops::full_attention_flops(256, 64);
            let arts = ["attn_flash_dense_n256", "attn_sla2_s90_n256",
                        "attn_sla2_s95_n256", "attn_sla2_s97_n256",
                        "attn_sla2_noquant_s95_n256", "attn_sla_s95_n256",
                        "attn_vsa_s95_n256", "attn_vmoba_s95_n256"];
            for name in arts {
                if rt.manifest().artifact(name).is_err() {
                    continue;
                }
                // warm compile outside the timer; a broken artifact
                // skips, it must not abort the report
                if let Err(err) = rt.execute(
                    name, &[q.clone(), k.clone(), v.clone()])
                {
                    println!("  SKIP {name} ({err:#})");
                    continue;
                }
                let b = run_for(name, 2, 1.0, 50, || {
                    rt.execute(name, &[q.clone(), k.clone(), v.clone()])
                        .unwrap();
                });
                t.row(vec![name.into(), format!("{:.2}", b.mean_ms()),
                           format!("{:.2}", b.summary.p50 * 1e3),
                           format!("{:.2}", b.summary.p99 * 1e3),
                           format!("{:.2}", c / b.summary.mean / 1e9)]);
                json_rows.push(b.to_json()
                    .push("section", "cpu_measured")
                    .push("eff_gops", c / b.summary.mean / 1e9));
            }
            t.print();
        }
    }

    if let Some(path) = args.json_path("BENCH_fig4_kernel.json") {
        let report = bench::report("fig4_kernel", json_rows);
        bench::write_json(&path, &report)?;
        println!("wrote {path}");
    }
    Ok(())
}
