//! Native SLA2 attention: the paper's forward math (Secs. 3–5) on
//! host f32 slices, mirroring the Pallas kernel + jax references in
//! `python/compile/kernels/` (`sla2_fwd.py`, `router.py`, `quant.py`,
//! `ref.py`) operation-for-operation:
//!
//! * **router** — `P_c = softmax(proj_q(pool(Q)) proj_k(pool(K))^T /
//!   sqrt d)`, hard Top-k per row (ties broken by rank, stable);
//! * **sparse branch** `O_s` — FlashAttention-style online softmax
//!   over the kept tiles only (never materializing N x N), optionally
//!   through the INT8 quantization points of Alg. 2 (SageAttention
//!   scheme: per-row Q/K scales, fixed 1/127 P scale, per-column V
//!   scales within each tile).  [`QuantMode`] picks how those points
//!   execute: [`QuantMode::Int8`] stores the quantized operands as
//!   `i8` and runs the real `i8 x i8 -> i32` GEMMs
//!   (`gemm_i8_nt`/`gemm_i8_i32`), dequantizing once per tile via
//!   the hoisted scales; [`QuantMode::Sim`] is the f32 fake-quant
//!   simulation (identical int8-valued operands, f32 matmuls) kept as
//!   the parity oracle — the two are bit-identical whenever f32 can
//!   accumulate the integer products exactly, which holds for every
//!   served head shape (see `docs/KERNELS.md`);
//! * **linear branch** `O_l` — running `H = sum phi(K_j)^T V_j`,
//!   `Z = sum colsum(phi(K_j))` over the complement tiles, normalized
//!   per query row;
//! * **combination** — `O = a ⊙ O_s + (1-a) ⊙ O_l` with
//!   `a = sigmoid(alpha_logit)` per query block (Eq. 13).
//!
//! Two training-free comparison variants share all of this machinery
//! through the same masked core (docs/KERNELS.md, "Variant
//! dispatch"): [`sparge2_attention`] — hybrid top-k ∪ top-p block
//! mask feeding the sparse branch only — and [`svg_ear_attention`] —
//! top-k plus error-aware linear compensation, with the mix weight
//! derived from the pooled kept mass instead of a learned alpha.
//!
//! All functions are single-head: `q`, `k`, `v` are `(n, d)` row-major
//! slices.  Tile loops run in ascending `j` order like the kernel's
//! `fori_loop`, so f32 accumulation order matches the lowered HLO.
//!
//! **Intra-head parallelism:** query blocks carry no cross-block
//! state, so the `*_attention_split` entry points partition them into
//! contiguous chunks fanned across `util::threadpool::shared_map` —
//! the long-sequence/few-heads regime where head-level fan-out leaves
//! cores idle (docs/KERNELS.md §7).  Stitched chunks are bit-identical
//! to the sequential loop; per-head hoists (routing, K smoothing,
//! tile quantization, H/Z states) are computed once and shared
//! read-only.

use anyhow::bail;

use super::linalg::{dot, gemm_i8_i32_into, gemm_i8_nt_into, matmul,
                    matmul_into, matmul_nt, matmul_nt_into, matmul_tn,
                    sigmoid, softmax_rows};
use super::stats;

pub const NEG_INF: f32 = -1e30;
/// Linear-branch denominator guard (ref.py EPS).
const EPS_LINEAR: f32 = 1e-9;
/// Quantization scale guard (quant.py EPS).
const EPS_QUANT: f32 = 1e-8;
const INT8_MAX: f32 = 127.0;

/// How the INT8 quantization points of Alg. 2 (Sec. 5) execute in the
/// sparse branch — the `quant_mode` serving knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// Real integer kernels: K/V tiles and Q blocks live in `i8`
    /// buffers, `Q Kᵀ` / `P V` run as `i8 x i8 -> i32` GEMMs, and the
    /// `i32` tiles are dequantized once via the hoisted per-row /
    /// per-column scales.  The default serving mode.
    Int8,
    /// The f32 fake-quant simulation: identical int8-valued operands,
    /// but every matmul stays f32.  Pays quantization error without
    /// the integer speed — kept as the parity oracle for `Int8`
    /// (bit-identical on every served head shape) and as the
    /// measurement baseline in `fig4_kernel_speed`'s `int8_vs_sim`
    /// section.
    Sim,
    /// No quantization: the exact f32 sparse branch (the
    /// `sla2_noquant` variant).
    Off,
}

impl QuantMode {
    /// Parse the `quant_mode` config string.
    ///
    /// ```
    /// use sla2::runtime::native::attention::QuantMode;
    /// assert_eq!(QuantMode::parse("int8").unwrap(), QuantMode::Int8);
    /// assert_eq!(QuantMode::parse("sim").unwrap(), QuantMode::Sim);
    /// assert_eq!(QuantMode::parse("off").unwrap(), QuantMode::Off);
    /// assert!(QuantMode::parse("fp4").is_err());
    /// ```
    pub fn parse(s: &str) -> anyhow::Result<QuantMode> {
        match s {
            "int8" => Ok(QuantMode::Int8),
            "sim" => Ok(QuantMode::Sim),
            "off" => Ok(QuantMode::Off),
            other => bail!("unknown quant_mode {other:?} (expected \
                            \"int8\", \"sim\" or \"off\")"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            QuantMode::Int8 => "int8",
            QuantMode::Sim => "sim",
            QuantMode::Off => "off",
        }
    }

    /// Whether the sparse branch quantizes at all (Int8 or Sim).
    pub fn is_quantized(self) -> bool {
        !matches!(self, QuantMode::Off)
    }
}

/// Router + mixing parameters for one head (shared across heads of a
/// block in the DiT — same layout as `model.py`).
pub struct Sla2Params<'a> {
    pub proj_q: &'a [f32],      // (d, d)
    pub proj_k: &'a [f32],      // (d, d)
    pub alpha_logit: &'a [f32], // (t_m,) pre-sigmoid mixing logits
}

/// Vanilla softmax attention — the 0%-sparsity baseline and the
/// parity oracle (`ref.full_attention`).
pub fn full_attention(q: &[f32], k: &[f32], v: &[f32], n: usize,
                      d: usize) -> Vec<f32> {
    full_attention_split(q, k, v, n, d, 1)
}

/// [`full_attention`] with an intra-head fan-out factor: query rows
/// split into `splits` contiguous chunks mapped over the shared pool.
/// Each output row depends only on its own query row (per-row softmax,
/// per-row `P V` products with a fixed accumulation order), so the
/// stitched result is bit-identical to `splits = 1`.  Callers already
/// running ON the pool must pass 1 (nested fan-out deadlocks).
pub fn full_attention_split(q: &[f32], k: &[f32], v: &[f32], n: usize,
                            d: usize, splits: usize) -> Vec<f32> {
    use std::sync::atomic::Ordering::Relaxed;
    stats().full_heads.fetch_add(1, Relaxed);
    let scale = 1.0 / (d as f32).sqrt();
    let splits = splits.clamp(1, n.max(1));
    if splits == 1 {
        let mut s = matmul_nt(q, k, n, d, n);
        for x in s.iter_mut() {
            *x *= scale;
        }
        softmax_rows(&mut s, n);
        return matmul(&s, v, n, n, d);
    }
    stats().intra_head_splits.fetch_add(1, Relaxed);
    let per = n.div_ceil(splits);
    let chunks = n.div_ceil(per);
    let shared = std::sync::Arc::new((q.to_vec(), k.to_vec(),
                                      v.to_vec()));
    let parts =
        crate::util::threadpool::shared_map(chunks, move |ci| {
            let (q, k, v) = shared.as_ref();
            let (r0, r1) = (ci * per, ((ci + 1) * per).min(n));
            let mut s = matmul_nt(&q[r0 * d..r1 * d], k, r1 - r0, d, n);
            for x in s.iter_mut() {
                *x *= scale;
            }
            softmax_rows(&mut s, n);
            matmul(&s, v, r1 - r0, n, d)
        });
    let mut out = Vec::with_capacity(n * d);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

/// SageAttention K-smoothing: subtract the per-feature mean over
/// tokens (softmax-invariant, shrinks the INT8 dynamic range).
pub fn smooth_k(k: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut mean = vec![0.0f32; d];
    for row in k.chunks_exact(d) {
        for (m, v) in mean.iter_mut().zip(row) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f32;
    }
    let mut out = Vec::with_capacity(k.len());
    for row in k.chunks_exact(d) {
        out.extend(row.iter().zip(&mean).map(|(v, m)| v - m));
    }
    out
}

/// Linear-attention feature map: softmax over the feature dim (the
/// paper's phi) — strictly positive, so the normalizer never vanishes.
pub fn phi_softmax(x: &[f32], d: usize) -> Vec<f32> {
    let mut out = x.to_vec();
    softmax_rows(&mut out, d);
    out
}

/// Mean-pool consecutive `block` rows: `(n, d) -> (n/block, d)`.
pub fn pool_blocks(x: &[f32], n: usize, d: usize, block: usize)
                   -> Vec<f32> {
    let t = n / block;
    let mut out = vec![0.0f32; t * d];
    for (bi, chunk) in x.chunks_exact(block * d).enumerate() {
        let orow = &mut out[bi * d..(bi + 1) * d];
        for row in chunk.chunks_exact(d) {
            for (o, v) in orow.iter_mut().zip(row) {
                *o += v;
            }
        }
        for o in orow.iter_mut() {
            *o /= block as f32;
        }
    }
    out
}

/// Number of key blocks the sparse branch keeps per query block (at
/// least 1 so no softmax row is empty) — mirrors `router.top_k_count`.
pub fn top_k_count(k_pct: f64, t_n: usize) -> usize {
    ((k_pct * t_n as f64).round() as usize).max(1)
}

/// Pooled block-score matrix `softmax(proj_q(pool(Q))
/// proj_k(pool(K))^T / sqrt d)`: `(t_m * t_n)` row-major, each row a
/// distribution over key blocks.  `proj = None` skips the projections
/// — the training-free variants' scores.  Skipping is bit-identical
/// to projecting by an exact identity matrix (an f32 dot product
/// against 0/1 columns only ever adds exact zeros), which is what
/// lets the sparge2-at-p=0 property test pin this against
/// [`router_mask`] with identity projections.
pub fn pooled_block_scores(q: &[f32], k: &[f32],
                           proj: Option<(&[f32], &[f32])>, n: usize,
                           d: usize, b_q: usize, b_k: usize)
                           -> Vec<f32> {
    let (t_m, t_n) = (n / b_q, n / b_k);
    let mut qb = pool_blocks(q, n, d, b_q);
    let mut kb = pool_blocks(k, n, d, b_k);
    if let Some((proj_q, proj_k)) = proj {
        qb = matmul(&qb, proj_q, t_m, d, d);
        kb = matmul(&kb, proj_k, t_n, d, d);
    }
    let scale = 1.0 / (d as f32).sqrt();
    let mut p_c = matmul_nt(&qb, &kb, t_m, d, t_n);
    for v in p_c.iter_mut() {
        *v *= scale;
    }
    softmax_rows(&mut p_c, t_n);
    p_c
}

/// Key-block indices of one score row sorted by descending score,
/// ties broken by index (stable sort == jnp's stable argsort rank
/// trick).  Every mask builder sorts this same way, so top-k and
/// top-p selections are prefixes of one shared order and their union
/// is just the longer prefix.
fn sorted_row_indices(row: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a])
        .unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// The learnable router `R(Q, K) -> M_c` (Sec. 4, hard Top-k):
/// `(t_m * t_n)` row-major mask, 1 = sparse branch.  Ties broken by
/// index (stable sort), matching jnp's stable argsort rank trick.
/// With identity projections this IS the SLA magnitude heuristic
/// (Sec. 8, insight 1.c).
pub fn router_mask(q: &[f32], k: &[f32], proj_q: &[f32], proj_k: &[f32],
                   k_pct: f64, n: usize, d: usize, b_q: usize,
                   b_k: usize) -> Vec<u8> {
    let (t_m, t_n) = (n / b_q, n / b_k);
    let p_c = pooled_block_scores(q, k, Some((proj_q, proj_k)), n, d,
                                  b_q, b_k);
    let kc = top_k_count(k_pct, t_n);
    let mut mask = vec![0u8; t_m * t_n];
    for (row, mrow) in p_c.chunks_exact(t_n)
        .zip(mask.chunks_exact_mut(t_n))
    {
        for &j in &sorted_row_indices(row)[..kc] {
            mrow[j] = 1;
        }
    }
    mask
}

/// Cumulative softmax mass a `sparge2` top-p prefix must reach before
/// it stops growing.
pub const SPARGE2_TOP_P: f64 = 0.90;

/// Error tolerance for `svg_ear` routing: query blocks whose estimated
/// sparse-approximation error (1 − kept pooled mass) stays at or below
/// this serve sparse-only; higher-error blocks route their complement
/// through the H/Z linear branch as compensation.
pub const SVG_EAR_TAU: f32 = 0.02;

/// Minimal score-sorted prefix length whose cumulative mass reaches
/// `top_p`: 0 when `top_p <= 0` (a mass of zero already qualifies),
/// the full row when even all blocks fall short of `top_p`.
/// Accumulates in sorted order in f64; the minimal-prefix property
/// test recomputes this exact loop, so keep it dumb.
fn top_p_count(row: &[f32], idx: &[usize], top_p: f64) -> usize {
    let mut cum = 0.0f64;
    let mut np = 0;
    for &j in idx {
        if cum >= top_p {
            break;
        }
        cum += row[j] as f64;
        np += 1;
    }
    np
}

/// The `sparge2` hybrid mask (SpargeAttention2-style, training-free):
/// per row, top-k ∪ top-p over the parameter-free pooled scores.
/// Both selections are prefixes of the same stable descending sort,
/// so the union is the longer prefix — `max(kc, np)` blocks.
/// `top_p = 0` degenerates to pure top-k (bit-equal to
/// [`router_mask`] with identity projections, property-tested), and
/// the `kc >= 1` floor from [`top_k_count`] means no row ever
/// empties.
#[allow(clippy::too_many_arguments)]
pub fn sparge2_mask(q: &[f32], k: &[f32], k_pct: f64, top_p: f64,
                    n: usize, d: usize, b_q: usize, b_k: usize)
                    -> Vec<u8> {
    let (t_m, t_n) = (n / b_q, n / b_k);
    let p_c = pooled_block_scores(q, k, None, n, d, b_q, b_k);
    let kc = top_k_count(k_pct, t_n);
    let mut mask = vec![0u8; t_m * t_n];
    for (row, mrow) in p_c.chunks_exact(t_n)
        .zip(mask.chunks_exact_mut(t_n))
    {
        let idx = sorted_row_indices(row);
        let keep = kc.max(top_p_count(row, &idx, top_p)).min(t_n);
        for &j in &idx[..keep] {
            mrow[j] = 1;
        }
    }
    mask
}

/// Parameter-free error-aware routing (the `svg_ear` variant,
/// SVG-EAR-style): a top-k mask over the un-projected pooled scores
/// plus one mix weight per query block derived from the same scores.
/// The pooled softmax row is a cheap proxy for the true attention
/// mass, so `err_i = 1 − Σ_{kept j} p_c[i][j]` estimates the softmax
/// mass the sparse branch discards for block i.  `err <= τ` ⇒ mix
/// 1.0 (pure sparse — the linear branch is skipped entirely);
/// otherwise mix = kept mass, so the linear compensation weight
/// `1 − mix` tracks the estimated error.  No RNG, no learned state:
/// identical inputs give identical routing (property-tested).
pub fn svg_ear_routing(q: &[f32], k: &[f32], k_pct: f64, n: usize,
                       d: usize, b_q: usize, b_k: usize)
                       -> (Vec<u8>, Vec<f32>) {
    let (t_m, t_n) = (n / b_q, n / b_k);
    let p_c = pooled_block_scores(q, k, None, n, d, b_q, b_k);
    let kc = top_k_count(k_pct, t_n);
    let mut mask = vec![0u8; t_m * t_n];
    let mut mix = Vec::with_capacity(t_m);
    for (row, mrow) in p_c.chunks_exact(t_n)
        .zip(mask.chunks_exact_mut(t_n))
    {
        for &j in &sorted_row_indices(row)[..kc] {
            mrow[j] = 1;
        }
        // sum kept mass in ascending j (mask order), not sort order,
        // so the estimate is independent of tie-break details
        let kept_mass: f32 = row.iter().zip(mrow.iter())
            .filter(|&(_, &m)| m == 1)
            .map(|(p, _)| *p)
            .sum();
        mix.push(if 1.0 - kept_mass <= SVG_EAR_TAU {
            1.0
        } else {
            kept_mass.clamp(0.0, 1.0)
        });
    }
    (mask, mix)
}

/// Symmetric per-row INT8 quantization: returns the `i8` matrix and
/// one scale per row (`x ≈ x_q * scale`, `scale = amax/127 + ε`).
///
/// The symmetric-scale bound (property-tested, derived in
/// `docs/KERNELS.md`): every element satisfies
/// `|x - scale * x_q| <= scale / 2` — the scale strictly exceeds
/// `amax/127`, so `|x/scale| < 127` and the clamp never bites.
///
/// Rounding: `f32::round` (half away from zero) vs jnp's half-to-even
/// — they differ only on exact .5 boundaries, which random inputs hit
/// with probability ~0; parity tests budget for the stray flip.
///
/// ```
/// use sla2::runtime::native::attention::quantize_rows_int8;
/// let x = [1.0f32, -2.0, 0.5, 0.25];
/// let (xq, scales) = quantize_rows_int8(&x, 2);
/// assert_eq!(xq, vec![63, -127, 127, 63]); // per-row amax -> ±127
/// for (i, (&v, &q)) in x.iter().zip(&xq).enumerate() {
///     let s = scales[i / 2];
///     assert!((v - s * q as f32).abs() <= 0.5 * s);
/// }
/// ```
pub fn quantize_rows_int8(x: &[f32], cols: usize)
                          -> (Vec<i8>, Vec<f32>) {
    let mut xq = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(x.len() / cols);
    for row in x.chunks_exact(cols) {
        let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = amax / INT8_MAX + EPS_QUANT;
        scales.push(scale);
        xq.extend(row.iter().map(|v| {
            (v / scale).round().clamp(-INT8_MAX, INT8_MAX) as i8
        }));
    }
    (xq, scales)
}

/// Per-column INT8 quantization of one V tile (`quantize_int8(v,
/// axis=0)`): returns `(v_q, s_v)` with one scale per feature column.
///
/// ```
/// use sla2::runtime::native::attention::quantize_cols_int8;
/// // one column spanning [−4, 2], one spanning [−1, 8]
/// let v = [2.0f32, 8.0, -4.0, -1.0];
/// let (vq, sv) = quantize_cols_int8(&v, 2);
/// assert_eq!(vq, vec![63, 127, -127, -16]);
/// assert!((sv[0] - 4.0 / 127.0).abs() < 1e-6);
/// ```
pub fn quantize_cols_int8(v: &[f32], cols: usize)
                          -> (Vec<i8>, Vec<f32>) {
    let mut col_amax = vec![0.0f32; cols];
    for row in v.chunks_exact(cols) {
        for (m, x) in col_amax.iter_mut().zip(row) {
            *m = m.max(x.abs());
        }
    }
    let s_v: Vec<f32> = col_amax.iter()
        .map(|a| a / INT8_MAX + EPS_QUANT)
        .collect();
    let mut vq = Vec::with_capacity(v.len());
    for row in v.chunks_exact(cols) {
        vq.extend(row.iter().zip(&s_v).map(|(x, s)| {
            (x / s).round().clamp(-INT8_MAX, INT8_MAX) as i8
        }));
    }
    (vq, s_v)
}

/// Inverse of [`quantize_rows_int8`]: `x ≈ x_q * scale` per row.
///
/// ```
/// use sla2::runtime::native::attention::{dequantize_rows_int8,
///                                        quantize_rows_int8};
/// let x = [0.75f32, -0.25, 1.5, 3.0];
/// let (xq, s) = quantize_rows_int8(&x, 2);
/// let back = dequantize_rows_int8(&xq, &s, 2);
/// for (v, b) in x.iter().zip(&back) {
///     assert!((v - b).abs() <= 0.5 * s[1].max(s[0]));
/// }
/// ```
pub fn dequantize_rows_int8(xq: &[i8], scales: &[f32], cols: usize)
                            -> Vec<f32> {
    debug_assert_eq!(xq.len(), scales.len() * cols);
    xq.chunks_exact(cols)
        .zip(scales)
        .flat_map(|(row, &s)| row.iter().map(move |&q| q as f32 * s))
        .collect()
}

/// Widen an `i8` buffer to int8-valued f32s — the sim path's operands
/// (identical values to the integer path's, by construction).
fn widen_i8(x: &[i8]) -> Vec<f32> {
    x.iter().map(|&v| v as f32).collect()
}

/// f32-simulated `P_ij V_j` (Alg. 2 line 17): P has a fixed 1/127
/// scale (it lives in [0, 1] post online-softmax rescaling); `vq_f` /
/// `sv` come pre-quantized per tile (int8-valued f32 mirror).  `pq`
/// and `out` are caller scratch, reused across every (query block,
/// tile) pair of a chunk.
fn sim_matmul_pv(p: &[f32], vq_f: &[f32], sv: &[f32], rows: usize,
                 b_k: usize, d: usize, pq: &mut Vec<f32>,
                 out: &mut Vec<f32>) {
    pq.clear();
    pq.extend(p.iter()
        .map(|x| (x * INT8_MAX).round().clamp(0.0, INT8_MAX)));
    matmul_into(pq, vq_f, rows, b_k, d, out);
    for row in out.chunks_exact_mut(d) {
        for (o, s) in row.iter_mut().zip(sv) {
            *o *= s / INT8_MAX;
        }
    }
}

/// Real-INT8 `P_ij V_j`: quantize P to `i8` with the fixed 1/127
/// scale, run the integer GEMM, dequantize once per column.  Computes
/// `(sv[c] / 127) * acc` with the exact operations [`sim_matmul_pv`]
/// applies to identical integer values, so the two paths agree
/// bit-for-bit while the f32 accumulation stays exact.  `pq` / `pvi` /
/// `out` are caller scratch, reused across tiles.
#[allow(clippy::too_many_arguments)]
fn int8_matmul_pv(p: &[f32], vq: &[i8], sv: &[f32], rows: usize,
                  b_k: usize, d: usize, pq: &mut Vec<i8>,
                  pvi: &mut Vec<i32>, out: &mut Vec<f32>) {
    pq.clear();
    pq.extend(p.iter()
        .map(|x| (x * INT8_MAX).round().clamp(0.0, INT8_MAX) as i8));
    gemm_i8_i32_into(pq, vq, rows, b_k, d, pvi);
    out.clear();
    for row in pvi.chunks_exact(d) {
        out.extend(row.iter().zip(sv)
            .map(|(&acc, s)| acc as f32 * (s / INT8_MAX)));
    }
}

/// Loop-invariant INT8 state of one key tile: quantized K (per-row
/// scales) and V (per-column scales) — hoisted out of the query-block
/// loop, which would otherwise redo this `t_m` times per tile.  The
/// `i8` buffers are the integer GEMM operands; the `_f` mirrors are
/// the same values widened to f32, populated only for
/// [`QuantMode::Sim`] so the fake-quant path is not pessimized by
/// per-tile widening.
struct QuantTile {
    kq: Vec<i8>,
    sk: Vec<f32>,
    vq: Vec<i8>,
    sv: Vec<f32>,
    kq_f: Vec<f32>,
    vq_f: Vec<f32>,
}

/// Loop-invariant quantized Q state of one query block (Alg. 2 line
/// 13, hoisted): `i8` values, per-row scales, and the sim-mode f32
/// mirror.
struct QuantBlock {
    qq: Vec<i8>,
    sq: Vec<f32>,
    qq_f: Vec<f32>,
}

/// Full SLA2 op for one head (Eq. 13): route, run both branches, mix
/// with `a = sigmoid(alpha_logit)` per query block.
///
/// `mask` is the `(t_m * t_n)` block mask (1 = sparse).  `quant`
/// picks how the INT8 points of Sec. 5 execute in the sparse branch
/// (real integer GEMMs, f32 simulation, or no quantization).
/// K-smoothing is applied before BOTH branches (Alg. 2 line 2).
#[allow(clippy::too_many_arguments)]
pub fn sla2_attention_masked(q: &[f32], k: &[f32], v: &[f32],
                             mask: &[u8], alpha_logit: &[f32], n: usize,
                             d: usize, b_q: usize, b_k: usize,
                             quant: QuantMode) -> Vec<f32> {
    let mix: Vec<f32> =
        alpha_logit.iter().map(|&l| sigmoid(l)).collect();
    masked_attention_core(q, k, v, mask, &mix, n, d, b_q, b_k, quant, 1)
}

/// Loop-invariant state of one masked-core invocation — everything
/// computed ONCE per head and shared read-only by every query-block
/// chunk: smoothed K, phi features, per-tile INT8 quantization, H/Z
/// linear tile states.  Owned (not borrowed) so the intra-head fan
/// can move it into an `Arc` for the pool's `'static` closures; the
/// q/k/v copies are O(n·d), noise next to the attention work itself.
struct CoreState {
    q: Vec<f32>,
    k_sm: Vec<f32>,
    v: Vec<f32>,
    mask: Vec<u8>,
    mix: Vec<f32>,
    qphi: Vec<f32>,
    quant_tiles: Option<Vec<Option<QuantTile>>>,
    h_tiles: Vec<Vec<f32>>,
    z_tiles: Vec<Vec<f32>>,
    d: usize,
    b_q: usize,
    b_k: usize,
    t_m: usize,
    t_n: usize,
    scale: f32,
    quant: QuantMode,
}

/// Hoist the per-head loop invariants (and bump the per-head stats).
#[allow(clippy::too_many_arguments)]
fn build_core_state(q: &[f32], k: &[f32], v: &[f32], mask: &[u8],
                    mix: &[f32], n: usize, d: usize, b_q: usize,
                    b_k: usize, quant: QuantMode) -> CoreState {
    use std::sync::atomic::Ordering::Relaxed;
    let (t_m, t_n) = (n / b_q, n / b_k);
    debug_assert_eq!(mask.len(), t_m * t_n);
    debug_assert_eq!(mix.len(), t_m);
    let kept: u64 = mask.iter().map(|&m| m as u64).sum();
    let st = stats();
    st.attn_heads.fetch_add(1, Relaxed);
    st.sparse_tiles.fetch_add(kept, Relaxed);
    st.linear_tiles.fetch_add((t_m * t_n) as u64 - kept, Relaxed);
    match quant {
        QuantMode::Int8 => {
            st.quant_heads.fetch_add(1, Relaxed);
            st.int8_heads.fetch_add(1, Relaxed);
        }
        QuantMode::Sim => {
            st.quant_heads.fetch_add(1, Relaxed);
            st.sim_heads.fetch_add(1, Relaxed);
        }
        QuantMode::Off => {}
    }

    let k_sm = smooth_k(k, n, d);
    // phi features and per-tile H/Z exist only to serve blocks that
    // actually mix in the linear branch; an all-1.0 mix (sparge2, or
    // svg_ear under its error tolerance) skips the whole apparatus
    let needs_linear = mix.iter().any(|&a| a < 1.0);
    let qphi = if needs_linear {
        phi_softmax(q, d)
    } else {
        Vec::new()
    };
    let kphi = if needs_linear {
        phi_softmax(&k_sm, d)
    } else {
        Vec::new()
    };
    let scale = 1.0 / (d as f32).sqrt();

    // per-tile INT8 K/V quantization — loop-invariant across query
    // blocks (depends only on j), so hoist it like h_tiles/z_tiles
    // instead of re-quantizing each kept tile t_m times.  Only tiles
    // SOME query block routes to the sparse branch get quantized: at
    // high sparsity most tiles are linear-only and the quantization
    // work would be dead (None is never read — guarded by the mask).
    let tile_kept: Vec<bool> = (0..t_n)
        .map(|j| (0..t_m).any(|i| mask[i * t_n + j] == 1))
        .collect();
    let quant_tiles: Option<Vec<Option<QuantTile>>> =
        quant.is_quantized().then(|| {
            (0..t_n)
                .map(|j| {
                    tile_kept[j].then(|| {
                        let (kq, sk) = quantize_rows_int8(
                            &k_sm[j * b_k * d..(j + 1) * b_k * d], d);
                        let (vq, sv) = quantize_cols_int8(
                            &v[j * b_k * d..(j + 1) * b_k * d], d);
                        let (kq_f, vq_f) = if quant == QuantMode::Sim {
                            (widen_i8(&kq), widen_i8(&vq))
                        } else {
                            (Vec::new(), Vec::new())
                        };
                        QuantTile { kq, sk, vq, sv, kq_f, vq_f }
                    })
                })
                .collect()
        });

    // per-key-block linear states H_j = phi(K_j)^T V_j, Z_j =
    // colsum(phi(K_j)) — computed once, combined per query block in
    // ascending j order (the kernel's fori_loop order)
    let mut h_tiles = Vec::with_capacity(t_n);
    let mut z_tiles = Vec::with_capacity(t_n);
    if needs_linear {
        for j in 0..t_n {
            let kp = &kphi[j * b_k * d..(j + 1) * b_k * d];
            let vt = &v[j * b_k * d..(j + 1) * b_k * d];
            h_tiles.push(matmul_tn(kp, vt, b_k, d, d));
            let mut z = vec![0.0f32; d];
            for row in kp.chunks_exact(d) {
                for (zz, x) in z.iter_mut().zip(row) {
                    *zz += x;
                }
            }
            z_tiles.push(z);
        }
    }

    CoreState {
        q: q.to_vec(),
        k_sm,
        v: v.to_vec(),
        mask: mask.to_vec(),
        mix: mix.to_vec(),
        qphi,
        quant_tiles,
        h_tiles,
        z_tiles,
        d,
        b_q,
        b_k,
        t_m,
        t_n,
        scale,
        quant,
    }
}

/// Compute query blocks `i0..i1` into `out` (exactly those blocks'
/// rows).  Blocks carry no cross-`i` state, so any partition of
/// `0..t_m` stitches bit-identically to the sequential loop — the
/// invariant the intra-head fan rests on.  All tile scratch lives
/// here and is reused across the chunk's (query block × tile) pairs:
/// the sparse branch allocates nothing per pair.
fn core_rows(st: &CoreState, i0: usize, i1: usize, out: &mut [f32]) {
    let (d, b_q, b_k, t_n) = (st.d, st.b_q, st.b_k, st.t_n);
    debug_assert_eq!(out.len(), (i1 - i0) * b_q * d);
    let mut s: Vec<f32> = Vec::new(); // score tile, becomes P in place
    let mut s_i32: Vec<i32> = Vec::new(); // int8 Q·Kᵀ accumulators
    let mut pq_i8: Vec<i8> = Vec::new(); // quantized P (int8 path)
    let mut pq_f: Vec<f32> = Vec::new(); // quantized P (sim path)
    let mut pvi: Vec<i32> = Vec::new(); // int8 P·V accumulators
    let mut pv: Vec<f32> = Vec::new(); // dequantized P·V tile
    let mut ol: Vec<f32> = Vec::new(); // phi(Q_i) @ H
    let mut corr = vec![0.0f32; b_q];
    let mut m_i = vec![NEG_INF; b_q];
    let mut l_i = vec![0.0f32; b_q];
    let mut acc = vec![0.0f32; b_q * d];
    let mut h: Vec<f32> = Vec::new();
    let mut z: Vec<f32> = Vec::new();

    for i in i0..i1 {
        let qi = &st.q[i * b_q * d..(i + 1) * b_q * d];
        let block_linear = st.mix[i] < 1.0;
        // hoisted Alg. 2 line 13: quant(Q_i) is loop-invariant
        let q_quant: Option<QuantBlock> =
            st.quant.is_quantized().then(|| {
                let (qq, sq) = quantize_rows_int8(qi, d);
                let qq_f = if st.quant == QuantMode::Sim {
                    widen_i8(&qq)
                } else {
                    Vec::new()
                };
                QuantBlock { qq, sq, qq_f }
            });

        // ---- sparse branch: online softmax over kept tiles ----------
        for x in m_i.iter_mut() {
            *x = NEG_INF;
        }
        for x in l_i.iter_mut() {
            *x = 0.0;
        }
        for x in acc.iter_mut() {
            *x = 0.0;
        }
        // ---- linear branch: complement accumulation (only for
        //      blocks that actually mix, i.e. mix[i] < 1.0) ----------
        if block_linear {
            h.clear();
            h.resize(d * d, 0.0);
            z.clear();
            z.resize(d, 0.0);
        }

        for j in 0..t_n {
            if st.mask[i * t_n + j] == 0 {
                if block_linear {
                    for (hh, x) in h.iter_mut().zip(&st.h_tiles[j]) {
                        *hh += x;
                    }
                    for (zz, x) in z.iter_mut().zip(&st.z_tiles[j]) {
                        *zz += x;
                    }
                }
                continue;
            }
            let kj = &st.k_sm[j * b_k * d..(j + 1) * b_k * d];
            let vj = &st.v[j * b_k * d..(j + 1) * b_k * d];
            // Alg. 2 line 14: S = dequant(quant(Q) quant(K)^T).  The
            // int8 path widens the exact i32 accumulators to f32 and
            // applies the identical per-(row, col) scale product the
            // sim path applies to its (equal-valued) f32 sums, so the
            // two modes agree bit-for-bit while the sums stay within
            // f32's exact-integer range (docs/KERNELS.md).
            match (&q_quant, &st.quant_tiles) {
                (Some(qb), Some(qt)) => {
                    // mask == 1 here, so the tile was quantized above
                    let tile = qt[j].as_ref().expect("kept tile");
                    if st.quant == QuantMode::Int8 {
                        gemm_i8_nt_into(&qb.qq, &tile.kq, b_q, d, b_k,
                                        &mut s_i32);
                        s.clear();
                        s.extend(s_i32.iter().map(|&x| x as f32));
                    } else {
                        matmul_nt_into(&qb.qq_f, &tile.kq_f, b_q, d,
                                       b_k, &mut s);
                    }
                    for (r, srow) in s.chunks_exact_mut(b_k).enumerate()
                    {
                        for (x, skv) in srow.iter_mut().zip(&tile.sk) {
                            *x *= qb.sq[r] * skv;
                        }
                    }
                }
                _ => matmul_nt_into(qi, kj, b_q, d, b_k, &mut s),
            }
            for x in s.iter_mut() {
                *x *= st.scale;
            }
            // one online-softmax step (Alg. 2 lines 13-18): `s`
            // becomes P in place
            for r in 0..b_q {
                let srow = &mut s[r * b_k..(r + 1) * b_k];
                let row_max = srow.iter().cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                let m_new = m_i[r].max(row_max);
                let mut sum = 0.0f32;
                for x in srow.iter_mut() {
                    *x = (*x - m_new).exp();
                    sum += *x;
                }
                corr[r] = (m_i[r] - m_new).exp();
                l_i[r] = corr[r] * l_i[r] + sum;
                m_i[r] = m_new;
            }
            match &st.quant_tiles {
                Some(qt) => {
                    let tile = qt[j].as_ref().expect("kept tile");
                    if st.quant == QuantMode::Int8 {
                        int8_matmul_pv(&s, &tile.vq, &tile.sv, b_q,
                                       b_k, d, &mut pq_i8, &mut pvi,
                                       &mut pv);
                    } else {
                        sim_matmul_pv(&s, &tile.vq_f, &tile.sv, b_q,
                                      b_k, d, &mut pq_f, &mut pv);
                    }
                }
                None => matmul_into(&s, vj, b_q, b_k, d, &mut pv),
            }
            for r in 0..b_q {
                let arow = &mut acc[r * d..(r + 1) * d];
                let prow = &pv[r * d..(r + 1) * d];
                for (a, x) in arow.iter_mut().zip(prow) {
                    *a = corr[r] * *a + x;
                }
            }
        }

        // Alg. 2 lines 23-24 + the Eq. 13 mix.  The whole query
        // block's o_l = phi(Q_i) @ H is one (b_q, d) x (d, d) matmul
        // (same ikj accumulation order as the old per-row loops).
        // mix[i] == 1.0 collapses to the pure sparse output — the
        // `(1 − mix)` term would be an exact zero times a finite
        // value (den >= EPS_LINEAR), so the fast path is
        // value-identical to mixing.
        let ob = (i - i0) * b_q * d;
        if block_linear {
            let a = st.mix[i];
            let qp_block = &st.qphi[i * b_q * d..(i + 1) * b_q * d];
            matmul_into(qp_block, &h, b_q, d, d, &mut ol);
            for r in 0..b_q {
                let l_safe = if l_i[r] > 0.0 { l_i[r] } else { 1.0 };
                let qp = &qp_block[r * d..(r + 1) * d];
                let den = dot(qp, &z) + EPS_LINEAR;
                let orow = &mut out[ob + r * d..ob + (r + 1) * d];
                for (c, o) in orow.iter_mut().enumerate() {
                    let o_s = acc[r * d + c] / l_safe;
                    *o = a * o_s + (1.0 - a) * ol[r * d + c] / den;
                }
            }
        } else {
            for r in 0..b_q {
                let l_safe = if l_i[r] > 0.0 { l_i[r] } else { 1.0 };
                let orow = &mut out[ob + r * d..ob + (r + 1) * d];
                for (c, o) in orow.iter_mut().enumerate() {
                    *o = acc[r * d + c] / l_safe;
                }
            }
        }
    }
}

/// The shared masked sparse+linear engine every variant dispatches
/// into: online-softmax sparse branch over the masked-in tiles (with
/// the Alg. 2 INT8 points per `quant`), H/Z linear branch over each
/// query block's complement, combined per block as
/// `O_i = mix[i] ⊙ O_s + (1 − mix[i]) ⊙ O_l`.
///
/// `mix[i]` is the post-sigmoid weight: `sla2` passes
/// `sigmoid(alpha_logit)`, `svg_ear` its error-derived kept-mass
/// weights, `sparge2` all-1.0.  A weight of exactly 1.0
/// short-circuits the linear branch for that block — the `(1 − mix)`
/// term is an exact f32 zero and the denominator is finite, so
/// skipping is value-identical while the sparse-only variants never
/// pay for phi/H/Z.
///
/// `splits > 1` fans contiguous query-block chunks across the shared
/// pool (intra-head parallelism for the long-sequence/few-heads
/// regime) — bit-identical to `splits = 1` by the [`core_rows`]
/// independence invariant.  Callers already running ON the pool must
/// pass 1 (nested fan-out deadlocks).
#[allow(clippy::too_many_arguments)]
fn masked_attention_core(q: &[f32], k: &[f32], v: &[f32], mask: &[u8],
                         mix: &[f32], n: usize, d: usize, b_q: usize,
                         b_k: usize, quant: QuantMode, splits: usize)
                         -> Vec<f32> {
    let st = build_core_state(q, k, v, mask, mix, n, d, b_q, b_k,
                              quant);
    let t_m = st.t_m;
    let splits = splits.clamp(1, t_m.max(1));
    if splits == 1 {
        let mut out = vec![0.0f32; n * d];
        core_rows(&st, 0, t_m, &mut out);
        return out;
    }
    stats().intra_head_splits
        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let per = t_m.div_ceil(splits);
    let chunks = t_m.div_ceil(per);
    let st = std::sync::Arc::new(st);
    let parts =
        crate::util::threadpool::shared_map(chunks, move |ci| {
            let (i0, i1) = (ci * per, ((ci + 1) * per).min(st.t_m));
            let mut part = vec![0.0f32; (i1 - i0) * st.b_q * st.d];
            core_rows(&st, i0, i1, &mut part);
            part
        });
    let mut out = Vec::with_capacity(n * d);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

/// SLA2 with the learnable router (the full op `model.py` dispatches
/// to for the `sla2` / `sla2_noquant` variants).
#[allow(clippy::too_many_arguments)]
pub fn sla2_attention(q: &[f32], k: &[f32], v: &[f32], p: &Sla2Params,
                      k_pct: f64, n: usize, d: usize, b_q: usize,
                      b_k: usize, quant: QuantMode) -> Vec<f32> {
    sla2_attention_split(q, k, v, p, k_pct, n, d, b_q, b_k, quant, 1)
}

/// [`sla2_attention`] with an intra-head fan-out factor: `splits > 1`
/// fans contiguous query-block chunks across the shared pool,
/// bit-identical to `splits = 1` (query blocks carry no cross-block
/// state).  Routing and the per-head hoists run once; only the
/// query-block loop fans out.  Callers already running ON the pool
/// must pass 1 (nested fan-out deadlocks).
#[allow(clippy::too_many_arguments)]
pub fn sla2_attention_split(q: &[f32], k: &[f32], v: &[f32],
                            p: &Sla2Params, k_pct: f64, n: usize,
                            d: usize, b_q: usize, b_k: usize,
                            quant: QuantMode, splits: usize)
                            -> Vec<f32> {
    stats().sla2_heads
        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    // router sees the UN-smoothed K (sla2.py order); smoothing is
    // softmax-invariant for the router scores anyway
    let mask = router_mask(q, k, p.proj_q, p.proj_k, k_pct, n, d, b_q,
                           b_k);
    let mix: Vec<f32> =
        p.alpha_logit.iter().map(|&l| sigmoid(l)).collect();
    masked_attention_core(q, k, v, &mask, &mix, n, d, b_q, b_k, quant,
                          splits)
}

/// The `sparge2` variant: hybrid top-k+top-p mask, sparse branch
/// only.  The complement is dropped outright (no linear
/// compensation) — true to SpargeAttention2, which bets the top-p
/// union already captured the mass worth keeping.  Shares the
/// online-softmax + INT8 machinery with `sla2` via
/// [`sla2_attention_masked`]'s core.
#[allow(clippy::too_many_arguments)]
pub fn sparge2_attention(q: &[f32], k: &[f32], v: &[f32], k_pct: f64,
                         top_p: f64, n: usize, d: usize, b_q: usize,
                         b_k: usize, quant: QuantMode) -> Vec<f32> {
    sparge2_attention_split(q, k, v, k_pct, top_p, n, d, b_q, b_k,
                            quant, 1)
}

/// [`sparge2_attention`] with an intra-head fan-out factor (same
/// `splits` contract as [`sla2_attention_split`]).
#[allow(clippy::too_many_arguments)]
pub fn sparge2_attention_split(q: &[f32], k: &[f32], v: &[f32],
                               k_pct: f64, top_p: f64, n: usize,
                               d: usize, b_q: usize, b_k: usize,
                               quant: QuantMode, splits: usize)
                               -> Vec<f32> {
    stats().sparge2_heads
        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mask = sparge2_mask(q, k, k_pct, top_p, n, d, b_q, b_k);
    let mix = vec![1.0f32; n / b_q];
    masked_attention_core(q, k, v, &mask, &mix, n, d, b_q, b_k, quant,
                          splits)
}

/// The `svg_ear` variant: top-k sparse branch plus error-aware linear
/// compensation — [`svg_ear_routing`] decides per query block whether
/// the pooled-mass error estimate warrants routing the complement
/// through the H/Z branch.  Parameter-free: no learned projections,
/// no learned alpha.
#[allow(clippy::too_many_arguments)]
pub fn svg_ear_attention(q: &[f32], k: &[f32], v: &[f32], k_pct: f64,
                         n: usize, d: usize, b_q: usize, b_k: usize,
                         quant: QuantMode) -> Vec<f32> {
    svg_ear_attention_split(q, k, v, k_pct, n, d, b_q, b_k, quant, 1)
}

/// [`svg_ear_attention`] with an intra-head fan-out factor (same
/// `splits` contract as [`sla2_attention_split`]).
#[allow(clippy::too_many_arguments)]
pub fn svg_ear_attention_split(q: &[f32], k: &[f32], v: &[f32],
                               k_pct: f64, n: usize, d: usize,
                               b_q: usize, b_k: usize, quant: QuantMode,
                               splits: usize) -> Vec<f32> {
    use std::sync::atomic::Ordering::Relaxed;
    let (mask, mix) = svg_ear_routing(q, k, k_pct, n, d, b_q, b_k);
    let compensated = mix.iter().filter(|&&a| a < 1.0).count() as u64;
    let st = stats();
    st.svg_ear_heads.fetch_add(1, Relaxed);
    st.ear_compensated_blocks.fetch_add(compensated, Relaxed);
    masked_attention_core(q, k, v, &mask, &mix, n, d, b_q, b_k, quant,
                          splits)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
        let num: f64 = a.iter().zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
        num.sqrt() / (den.sqrt() + 1e-9)
    }

    /// Dense masked-softmax reference (`ref.block_sparse_attention`).
    fn dense_sparse_ref(q: &[f32], k: &[f32], v: &[f32], mask: &[u8],
                        n: usize, d: usize, b_q: usize, b_k: usize)
                        -> Vec<f32> {
        let t_n = n / b_k;
        let scale = 1.0 / (d as f32).sqrt();
        let mut s = matmul_nt(q, k, n, d, n);
        for i in 0..n {
            for j in 0..n {
                let m = mask[(i / b_q) * t_n + j / b_k];
                s[i * n + j] = if m > 0 { s[i * n + j] * scale }
                               else { NEG_INF };
            }
        }
        softmax_rows(&mut s, n);
        matmul(&s, v, n, n, d)
    }

    /// Dense masked-linear reference
    /// (`ref.dense_masked_linear_attention`).
    fn dense_linear_ref(q: &[f32], k: &[f32], v: &[f32], mask: &[u8],
                        n: usize, d: usize, b_q: usize, b_k: usize)
                        -> Vec<f32> {
        let t_n = n / b_k;
        let qp = phi_softmax(q, d);
        let kp = phi_softmax(k, d);
        let mut w = matmul_nt(&qp, &kp, n, d, n);
        for i in 0..n {
            for j in 0..n {
                if mask[(i / b_q) * t_n + j / b_k] > 0 {
                    w[i * n + j] = 0.0;
                }
            }
        }
        for row in w.chunks_exact_mut(n) {
            let den: f32 = row.iter().sum::<f32>() + EPS_LINEAR;
            for x in row.iter_mut() {
                *x /= den;
            }
        }
        matmul(&w, v, n, n, d)
    }

    fn qkv(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        (rng.normal_vec(n * d), rng.normal_vec(n * d), rng.normal_vec(n * d))
    }

    #[test]
    fn router_keeps_exactly_kc_blocks_per_row() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let (q, k, _) = qkv(n, d, 1);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        for k_pct in [0.05, 0.10, 0.5] {
            let mask = router_mask(&q, &k, &eye, &eye, k_pct, n, d, b_q,
                                   b_k);
            let kc = top_k_count(k_pct, n / b_k);
            for row in mask.chunks_exact(n / b_k) {
                assert_eq!(row.iter().map(|&m| m as usize).sum::<usize>(),
                           kc);
            }
        }
    }

    #[test]
    fn sparse_branch_matches_dense_masked_softmax() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let (q, k, v) = qkv(n, d, 2);
        let (t_m, t_n) = (n / b_q, n / b_k);
        // adversarial mask (not router-derived), >= 1 kept per row
        let mut rng = Pcg32::seeded(3);
        let mut mask = vec![0u8; t_m * t_n];
        for row in mask.chunks_exact_mut(t_n) {
            row[rng.below(t_n as u32) as usize] = 1;
            for m in row.iter_mut() {
                if rng.f32() < 0.4 {
                    *m = 1;
                }
            }
        }
        // alpha ~ 1: isolate the sparse branch (sigmoid(30) = 1 - 1e-13)
        let alpha = vec![30.0f32; t_m];
        // compare against the smoothed K the op applies internally
        let k_sm = smooth_k(&k, n, d);
        let got = sla2_attention_masked(&q, &k, &v, &mask, &alpha, n, d,
                                        b_q, b_k, QuantMode::Off);
        let want = dense_sparse_ref(&q, &k_sm, &v, &mask, n, d, b_q, b_k);
        assert!(rel_err(&got, &want) < 1e-5,
                "sparse branch diverged: {}", rel_err(&got, &want));
    }

    #[test]
    fn linear_branch_matches_dense_masked_linear() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let (q, k, v) = qkv(n, d, 4);
        let (t_m, t_n) = (n / b_q, n / b_k);
        let mut rng = Pcg32::seeded(5);
        let mut mask = vec![0u8; t_m * t_n];
        for row in mask.chunks_exact_mut(t_n) {
            // keep one block sparse (router invariant), rest linear
            row[rng.below(t_n as u32) as usize] = 1;
        }
        // alpha ~ 0: isolate the linear branch
        let alpha = vec![-30.0f32; t_m];
        let k_sm = smooth_k(&k, n, d);
        let got = sla2_attention_masked(&q, &k, &v, &mask, &alpha, n, d,
                                        b_q, b_k, QuantMode::Off);
        let want = dense_linear_ref(&q, &k_sm, &v, &mask, n, d, b_q, b_k);
        assert!(rel_err(&got, &want) < 1e-5,
                "linear branch diverged: {}", rel_err(&got, &want));
    }

    #[test]
    fn alpha_mixes_the_branches() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let (q, k, v) = qkv(n, d, 6);
        let (t_m, t_n) = (n / b_q, n / b_k);
        let mut mask = vec![0u8; t_m * t_n];
        for row in mask.chunks_exact_mut(t_n) {
            row[0] = 1;
            row[3] = 1;
        }
        let run = |logit: f32| sla2_attention_masked(
            &q, &k, &v, &mask, &vec![logit; t_m], n, d, b_q, b_k,
            QuantMode::Off);
        let (o_s, o_l, o_mid) = (run(30.0), run(-30.0), run(0.0));
        let want: Vec<f32> = o_s.iter().zip(&o_l)
            .map(|(s, l)| 0.5 * s + 0.5 * l)
            .collect();
        assert!(rel_err(&o_mid, &want) < 1e-5);
    }

    #[test]
    fn quant_path_is_close_but_not_identical() {
        let (n, d, b_q, b_k) = (64, 32, 8, 4);
        let (q, k, v) = qkv(n, d, 7);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let alpha = vec![0.5f32; n / b_q];
        let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                             alpha_logit: &alpha };
        let exact = sla2_attention(&q, &k, &v, &p, 0.25, n, d, b_q, b_k,
                                   QuantMode::Off);
        for mode in [QuantMode::Int8, QuantMode::Sim] {
            let quant = sla2_attention(&q, &k, &v, &p, 0.25, n, d, b_q,
                                       b_k, mode);
            let err = rel_err(&quant, &exact);
            assert!(err > 1e-7,
                    "{mode:?} path must actually quantize");
            assert!(err < 5e-2,
                    "{mode:?} INT8 error too large: {err}");
        }
    }

    #[test]
    fn int8_and_sim_modes_are_bit_identical() {
        // in-crate smoke for the f32-exactness argument
        // (docs/KERNELS.md): the integer path reproduces the f32
        // fake-quant simulation BIT-for-bit, not just within rel_err.
        // The full parity suite (dit-tiny AND dit-small head shapes,
        // several k_pct) lives in rust/tests/native_backend.rs.
        let (n, d, b_q, b_k) = (64, 32, 8, 4);
        let (q, k, v) = qkv(n, d, 21);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let alpha = vec![0.3f32; n / b_q];
        let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                             alpha_logit: &alpha };
        let int8 = sla2_attention(&q, &k, &v, &p, 0.25, n, d, b_q, b_k,
                                  QuantMode::Int8);
        let sim = sla2_attention(&q, &k, &v, &p, 0.25, n, d, b_q, b_k,
                                 QuantMode::Sim);
        assert_eq!(int8, sim,
                   "int8 and sim quant modes diverged on a shape where \
                    the i32 accumulators are f32-exact");
    }

    // NOTE: the symmetric-scale roundtrip bound is property-tested in
    // rust/tests/native_backend.rs (util::proptest harness) — no unit
    // copy here, one place to update if the bound changes.  Likewise
    // the sparge2/svg_ear mask invariants (minimal top-p prefix,
    // union never empties, p=0 bit-equals top-k, routing determinism)
    // — the unit tests below cover the shapes of behavior, the
    // property tests the invariants.

    /// Block-aligned one-hot inputs: every token of query block i
    /// points at the basis vector of key block 2i (needs t_n = 2 t_m
    /// and d >= t_n), so pooled scores are amp at j = 2i and 0
    /// elsewhere — maximally peaked rows for routing tests.  v is
    /// random so outputs are informative.
    fn onehot_qkv(n: usize, d: usize, b_q: usize, b_k: usize,
                  amp: f32, seed: u64)
                  -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (t_m, t_n) = (n / b_q, n / b_k);
        assert_eq!(t_n, 2 * t_m);
        assert!(d >= t_n);
        let mut q = vec![0.0f32; n * d];
        for i in 0..t_m {
            for r in 0..b_q {
                q[(i * b_q + r) * d + 2 * i] = amp;
            }
        }
        let mut k = vec![0.0f32; n * d];
        for j in 0..t_n {
            for r in 0..b_k {
                k[(j * b_k + r) * d + j] = 1.0;
            }
        }
        let mut rng = Pcg32::seeded(seed);
        let v = rng.normal_vec(n * d);
        (q, k, v)
    }

    #[test]
    fn sparge2_topp_widens_on_flat_scores_only() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let t_n = n / b_k;
        // flat pooled scores (all-zero q/k): every block carries
        // exactly 1/t_n mass, so reaching p = 0.9 needs all t_n
        let q0 = vec![0.0f32; n * d];
        let k0 = vec![0.0f32; n * d];
        let flat = sparge2_mask(&q0, &k0, 0.10, 0.90, n, d, b_q, b_k);
        for row in flat.chunks_exact(t_n) {
            assert_eq!(row.iter().map(|&m| m as usize).sum::<usize>(),
                       t_n, "uniform rows must widen to the full row");
        }
        // peaked scores: the top block alone carries ~all the mass,
        // so top-p adds nothing beyond top-k's kc = 1
        let (q, k, _) = onehot_qkv(n, d, b_q, b_k, 40.0, 9);
        let peaked = sparge2_mask(&q, &k, 0.10, 0.90, n, d, b_q, b_k);
        for (i, row) in peaked.chunks_exact(t_n).enumerate() {
            assert_eq!(row.iter().map(|&m| m as usize).sum::<usize>(),
                       1);
            assert_eq!(row[2 * i], 1, "hot block must be the kept one");
        }
    }

    #[test]
    fn sparge2_matches_dense_masked_softmax_on_its_own_mask() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let (q, k, v) = qkv(n, d, 10);
        let mask = sparge2_mask(&q, &k, 0.25, 0.5, n, d, b_q, b_k);
        let got = sparge2_attention(&q, &k, &v, 0.25, 0.5, n, d, b_q,
                                    b_k, QuantMode::Off);
        let k_sm = smooth_k(&k, n, d);
        let want = dense_sparse_ref(&q, &k_sm, &v, &mask, n, d, b_q,
                                    b_k);
        assert!(rel_err(&got, &want) < 1e-5,
                "sparge2 sparse-only output diverged: {}",
                rel_err(&got, &want));
    }

    #[test]
    fn svg_ear_compensates_exactly_the_high_error_blocks() {
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let t_n = n / b_k;
        // flat rows: kept mass = kc/t_n = 0.125, err = 0.875 > tau
        // => every block compensates with mix = kept mass
        let q0 = vec![0.0f32; n * d];
        let k0 = vec![0.0f32; n * d];
        let (_, mix) = svg_ear_routing(&q0, &k0, 0.10, n, d, b_q, b_k);
        for &a in &mix {
            assert!((a - 1.0 / t_n as f32).abs() < 1e-6,
                    "uniform rows must mix by kept mass, got {a}");
        }
        // peaked rows: kept mass ~ 1, err < tau => pure sparse
        let (q, k, _) = onehot_qkv(n, d, b_q, b_k, 40.0, 11);
        let (_, mix) = svg_ear_routing(&q, &k, 0.10, n, d, b_q, b_k);
        assert!(mix.iter().all(|&a| a == 1.0),
                "peaked rows must serve sparse-only: {mix:?}");
    }

    #[test]
    fn svg_ear_equals_sparge2_when_no_block_compensates() {
        // on peaked inputs both variants keep the same top-k mask and
        // svg_ear's mix is all-1.0, so the two ops must agree
        // bit-for-bit through the shared core (including Int8)
        let (n, d, b_q, b_k) = (32, 16, 8, 4);
        let (q, k, v) = onehot_qkv(n, d, b_q, b_k, 40.0, 12);
        for mode in [QuantMode::Off, QuantMode::Int8] {
            let ear = svg_ear_attention(&q, &k, &v, 0.10, n, d, b_q,
                                        b_k, mode);
            let sp = sparge2_attention(&q, &k, &v, 0.10, 0.0, n, d,
                                       b_q, b_k, mode);
            assert_eq!(ear, sp, "{mode:?} outputs diverged");
        }
    }

    #[test]
    fn intra_head_split_is_bit_identical_and_counted() {
        use std::sync::atomic::Ordering::Relaxed;
        let (n, d, b_q, b_k) = (64, 32, 8, 4);
        let (q, k, v) = qkv(n, d, 33);
        let eye: Vec<f32> = (0..d * d)
            .map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 })
            .collect();
        let alpha = vec![0.4f32; n / b_q];
        let p = Sla2Params { proj_q: &eye, proj_k: &eye,
                             alpha_logit: &alpha };
        for quant in [QuantMode::Off, QuantMode::Int8] {
            let seq = sla2_attention(&q, &k, &v, &p, 0.25, n, d, b_q,
                                     b_k, quant);
            // t_m = 8 here: exercise even, uneven, one-block-per-chunk
            // and over-subscribed (clamped) fan-outs
            for splits in [2usize, 3, 8, 64] {
                let before = stats().intra_head_splits.load(Relaxed);
                let par = sla2_attention_split(&q, &k, &v, &p, 0.25, n,
                                               d, b_q, b_k, quant,
                                               splits);
                assert_eq!(par, seq,
                           "{quant:?} splits={splits} must stitch \
                            bit-identically");
                assert!(stats().intra_head_splits.load(Relaxed) > before,
                        "fanning must bump the intra_head_splits stat");
            }
        }
        // the other entry points share the same invariant
        assert_eq!(full_attention_split(&q, &k, &v, n, d, 4),
                   full_attention(&q, &k, &v, n, d));
        assert_eq!(
            sparge2_attention_split(&q, &k, &v, 0.25, 0.5, n, d, b_q,
                                    b_k, QuantMode::Int8, 4),
            sparge2_attention(&q, &k, &v, 0.25, 0.5, n, d, b_q, b_k,
                              QuantMode::Int8));
        assert_eq!(
            svg_ear_attention_split(&q, &k, &v, 0.10, n, d, b_q, b_k,
                                    QuantMode::Off, 4),
            svg_ear_attention(&q, &k, &v, 0.10, n, d, b_q, b_k,
                              QuantMode::Off));
    }

    #[test]
    fn full_attention_row_stochastic_sanity() {
        let (n, d) = (16, 8);
        let (q, k, _) = qkv(n, d, 8);
        // v = all-ones => softmax(scores) @ v = all-ones exactly
        let v = vec![1.0f32; n * d];
        let o = full_attention(&q, &k, &v, n, d);
        assert!(o.iter().all(|x| (x - 1.0).abs() < 1e-5));
    }
}
