//! Native DiT forward pass + typed parameter set.
//!
//! Mirrors `python/compile/model.py` operation-for-operation: AdaLN-
//! zero blocks over patchified video latents, conditioned on a
//! diffusion timestep and class label, with the attention op dispatched
//! per head to the chosen variant (full softmax, SLA2, or the
//! training-free comparison variants `sparge2` / `svg_ear` — see
//! [`SUPPORTED_VARIANTS`]).
//!
//! [`NativeParams`] is parsed from the **canonical flatten order** —
//! jax's `tree_flatten` order (dict keys sorted, lists in sequence)
//! that `model.flatten_params` defines and both `manifest.params` and
//! the trainer's state vector follow:
//!
//! ```text
//! blocks/<i>/{ada_b, ada_w, attn_alpha_logit, attn_proj_k,
//!             attn_proj_o, attn_proj_q, mlp_b1, mlp_b2, mlp_w1,
//!             mlp_w2, out_b, out_w, qkv_b, qkv_w}   for i in 0..depth
//! final_ada_b, final_ada_w, final_b, final_w,
//! patch_b, patch_w, t_b1, t_b2, t_w1, t_w2, y_embed
//! ```

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

use super::attention::{self, QuantMode, Sla2Params};
use super::linalg::{add_bias, gelu, layer_norm_rows, matmul,
                    modulate_rows};

/// Attention variants the native backend implements — the closed set
/// `attn_mode` resolves and both the serving config validation and
/// the per-request variant check admit.  Keep in sync with the
/// [`AttnMode`] arms and the README knob table.
pub const SUPPORTED_VARIANTS: [&str; 5] =
    ["full", "sla2", "sla2_noquant", "sparge2", "svg_ear"];

/// Which attention op the forward runs (per head).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttnMode {
    /// Vanilla softmax attention (the `full` variant / `dense` tier).
    Full,
    /// SLA2: learned router + sparse/linear branches + alpha mix;
    /// `quant` picks how the INT8 points of Sec. 5 execute in the
    /// sparse path (real integer GEMMs, f32 simulation, or none).
    Sla2 { k_pct: f64, quant: QuantMode },
    /// SpargeAttention2-style hybrid top-k ∪ top-p block mask feeding
    /// the sparse branch only (training-free: no projections, no
    /// alpha, the complement is dropped).
    Sparge2 { k_pct: f64, top_p: f64, quant: QuantMode },
    /// SVG-EAR-style parameter-free error-aware routing: top-k sparse
    /// branch plus linear compensation on query blocks whose pooled
    /// kept-mass error estimate exceeds the tolerance.
    SvgEar { k_pct: f64, quant: QuantMode },
}

/// One transformer block's parameters (canonical key order).
#[derive(Debug, Clone)]
pub struct BlockParams {
    pub ada_b: Vec<f32>,       // (6d,)
    pub ada_w: Vec<f32>,       // (d, 6d)
    pub alpha_logit: Vec<f32>, // (t_m,)
    pub proj_k: Vec<f32>,      // (head_dim, head_dim)
    pub proj_o: Vec<f32>,      // (head_dim, head_dim) — SLA baseline
    pub proj_q: Vec<f32>,      // (head_dim, head_dim)
    pub mlp_b1: Vec<f32>,      // (mh,)
    pub mlp_b2: Vec<f32>,      // (d,)
    pub mlp_w1: Vec<f32>,      // (d, mh)
    pub mlp_w2: Vec<f32>,      // (mh, d)
    pub out_b: Vec<f32>,       // (d,)
    pub out_w: Vec<f32>,       // (heads*head_dim, d)
    pub qkv_b: Vec<f32>,       // (3*heads*head_dim,)
    pub qkv_w: Vec<f32>,       // (d, 3*heads*head_dim)
}

/// The full DiT parameter set, host-resident.
#[derive(Debug, Clone)]
pub struct NativeParams {
    pub blocks: Vec<BlockParams>,
    pub final_ada_b: Vec<f32>, // (2d,)
    pub final_ada_w: Vec<f32>, // (d, 2d)
    pub final_b: Vec<f32>,     // (patch_dim,)
    pub final_w: Vec<f32>,     // (d, patch_dim)
    pub patch_b: Vec<f32>,     // (d,)
    pub patch_w: Vec<f32>,     // (patch_dim, d)
    pub t_b1: Vec<f32>,        // (d,)
    pub t_b2: Vec<f32>,        // (d,)
    pub t_w1: Vec<f32>,        // (d, d)
    pub t_w2: Vec<f32>,        // (d, d)
    pub y_embed: Vec<f32>,     // (num_classes + 1, d)
    /// MLP hidden width, derived from `mlp_w1` (the manifest does not
    /// record `mlp_ratio`; python defaults to 4)
    pub mlp_hidden: usize,
}

/// Latent-patch feature size `pt*ph*pw*C` (mirrors
/// `ModelConfig.patch_dim` on the python side).
pub fn patch_dim(cfg: &ModelConfig) -> usize {
    cfg.patch.iter().product::<usize>() * cfg.video[3]
}

impl NativeParams {
    /// Tensors this model needs in canonical flatten order.
    pub fn expected_len(cfg: &ModelConfig) -> usize {
        cfg.depth * 14 + 11
    }

    /// Parse from tensors in canonical flatten order (manifest params
    /// / trainer state).  Every shape is validated, so a contract
    /// drift surfaces as a readable error instead of garbage clips.
    pub fn from_flat(cfg: &ModelConfig, tensors: &[Tensor])
                     -> Result<NativeParams> {
        ensure!(tensors.len() == Self::expected_len(cfg),
                "expected {} parameter tensors for {} (depth {}), got {}",
                Self::expected_len(cfg), cfg.name, cfg.depth,
                tensors.len());
        let mut it = tensors.iter();
        let (d, hd) = (cfg.dim, cfg.heads * cfg.head_dim);
        let pd = patch_dim(cfg);
        let mut take = |name: &str, shape: &[usize]| -> Result<Vec<f32>> {
            let t = it.next().expect("length checked above");
            ensure!(t.shape == shape,
                    "param {name}: expected shape {shape:?}, got {:?} — \
                     canonical flatten order drifted", t.shape);
            Ok(t.f32s().with_context(|| format!("param {name}"))?.to_vec())
        };
        let mut blocks = Vec::with_capacity(cfg.depth);
        let mut mlp_hidden = 4 * d;
        for b in 0..cfg.depth {
            let ada_b = take(&format!("blocks/{b}/ada_b"), &[6 * d])?;
            let ada_w = take(&format!("blocks/{b}/ada_w"), &[d, 6 * d])?;
            let alpha_logit =
                take(&format!("blocks/{b}/attn_alpha_logit"), &[cfg.t_m])?;
            let proj_k = take(&format!("blocks/{b}/attn_proj_k"),
                              &[cfg.head_dim, cfg.head_dim])?;
            let proj_o = take(&format!("blocks/{b}/attn_proj_o"),
                              &[cfg.head_dim, cfg.head_dim])?;
            let proj_q = take(&format!("blocks/{b}/attn_proj_q"),
                              &[cfg.head_dim, cfg.head_dim])?;
            // mlp width comes from the tensor itself (mlp_ratio is not
            // in the manifest); the b1/w1 pair must agree
            let mlp_b1_t = &tensors[b * 14 + 6];
            ensure!(mlp_b1_t.shape.len() == 1,
                    "blocks/{b}/mlp_b1 must be rank 1");
            mlp_hidden = mlp_b1_t.shape[0];
            let mlp_b1 = take(&format!("blocks/{b}/mlp_b1"),
                              &[mlp_hidden])?;
            let mlp_b2 = take(&format!("blocks/{b}/mlp_b2"), &[d])?;
            let mlp_w1 = take(&format!("blocks/{b}/mlp_w1"),
                              &[d, mlp_hidden])?;
            let mlp_w2 = take(&format!("blocks/{b}/mlp_w2"),
                              &[mlp_hidden, d])?;
            let out_b = take(&format!("blocks/{b}/out_b"), &[d])?;
            let out_w = take(&format!("blocks/{b}/out_w"), &[hd, d])?;
            let qkv_b = take(&format!("blocks/{b}/qkv_b"), &[3 * hd])?;
            let qkv_w = take(&format!("blocks/{b}/qkv_w"), &[d, 3 * hd])?;
            blocks.push(BlockParams {
                ada_b, ada_w, alpha_logit, proj_k, proj_o, proj_q,
                mlp_b1, mlp_b2, mlp_w1, mlp_w2, out_b, out_w, qkv_b,
                qkv_w,
            });
        }
        Ok(NativeParams {
            blocks,
            final_ada_b: take("final_ada_b", &[2 * d])?,
            final_ada_w: take("final_ada_w", &[d, 2 * d])?,
            final_b: take("final_b", &[pd])?,
            final_w: take("final_w", &[d, pd])?,
            patch_b: take("patch_b", &[d])?,
            patch_w: take("patch_w", &[pd, d])?,
            t_b1: take("t_b1", &[d])?,
            t_b2: take("t_b2", &[d])?,
            t_w1: take("t_w1", &[d, d])?,
            t_w2: take("t_w2", &[d, d])?,
            y_embed: take("y_embed", &[cfg.num_classes + 1, d])?,
            mlp_hidden,
        })
    }

    /// Seeded parameter init mirroring `model.init_params` semantics
    /// (AdaLN-zero: gates start at 0; identity router projections;
    /// alpha at the kept-mass prior).  The value STREAM differs from
    /// jax's PRNG — this init exists for artifact-free deployments,
    /// where determinism (not bit-parity with python) is the contract.
    pub fn init_seeded(cfg: &ModelConfig, seed: u64) -> NativeParams {
        let mut rng = Pcg32::seeded(seed);
        let (d, hd) = (cfg.dim, cfg.heads * cfg.head_dim);
        let pd = patch_dim(cfg);
        let mh = 4 * d;
        let mut dense = |fan_in: usize, fan_out: usize| -> Vec<f32> {
            let std = 1.0 / (fan_in as f32).sqrt();
            (0..fan_in * fan_out).map(|_| rng.normal() * std).collect()
        };
        let eye = |k: usize, scale: f32| -> Vec<f32> {
            (0..k * k)
                .map(|i| if i % (k + 1) == 0 { scale } else { 0.0 })
                .collect()
        };
        let patch_w = dense(pd, d);
        let t_w1 = dense(d, d);
        let t_w2 = dense(d, d);
        let blocks = (0..cfg.depth)
            .map(|_| BlockParams {
                ada_b: vec![0.0; 6 * d],
                ada_w: vec![0.0; d * 6 * d],
                alpha_logit: vec![-2.2; cfg.t_m],
                proj_k: eye(cfg.head_dim, 1.0),
                proj_o: eye(cfg.head_dim, 0.5),
                proj_q: eye(cfg.head_dim, 1.0),
                mlp_b1: vec![0.0; mh],
                mlp_b2: vec![0.0; d],
                mlp_w1: dense(d, mh),
                mlp_w2: dense(mh, d),
                out_b: vec![0.0; d],
                out_w: dense(hd, d),
                qkv_b: vec![0.0; 3 * hd],
                qkv_w: dense(d, 3 * hd),
            })
            .collect();
        let mut rng2 = rng;
        let y_embed = (0..(cfg.num_classes + 1) * d)
            .map(|_| rng2.normal() * 0.02)
            .collect();
        NativeParams {
            blocks,
            final_ada_b: vec![0.0; 2 * d],
            final_ada_w: vec![0.0; d * 2 * d],
            final_b: vec![0.0; pd],
            final_w: vec![0.0; d * pd],
            patch_b: vec![0.0; d],
            patch_w,
            t_b1: vec![0.0; d],
            t_b2: vec![0.0; d],
            t_w1,
            t_w2,
            y_embed,
            mlp_hidden: mh,
        }
    }
}

/// `(T, H, W, C) -> (n_tokens, patch_dim)` — mirrors `model.patchify`.
pub fn patchify(x: &[f32], cfg: &ModelConfig) -> Vec<f32> {
    let [t, h, w, c] = cfg.video;
    let [pt, ph, pw] = cfg.patch;
    let (gt, gh, gw) = (t / pt, h / ph, w / pw);
    let pd = patch_dim(cfg);
    let mut out = vec![0.0f32; cfg.n_tokens * pd];
    for tt in 0..gt {
        for hh in 0..gh {
            for ww in 0..gw {
                let tok = (tt * gh + hh) * gw + ww;
                for dt in 0..pt {
                    for dh in 0..ph {
                        for dw in 0..pw {
                            for cc in 0..c {
                                let src = (((tt * pt + dt) * h
                                    + hh * ph + dh) * w
                                    + ww * pw + dw) * c + cc;
                                let dst = tok * pd
                                    + ((dt * ph + dh) * pw + dw) * c + cc;
                                out[dst] = x[src];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// `(n_tokens, patch_dim) -> (T, H, W, C)` — inverse of [`patchify`].
pub fn unpatchify(tokens: &[f32], cfg: &ModelConfig) -> Vec<f32> {
    let [t, h, w, c] = cfg.video;
    let [pt, ph, pw] = cfg.patch;
    let (gt, gh, gw) = (t / pt, h / ph, w / pw);
    let pd = patch_dim(cfg);
    let mut out = vec![0.0f32; t * h * w * c];
    for tt in 0..gt {
        for hh in 0..gh {
            for ww in 0..gw {
                let tok = (tt * gh + hh) * gw + ww;
                for dt in 0..pt {
                    for dh in 0..ph {
                        for dw in 0..pw {
                            for cc in 0..c {
                                let dst = (((tt * pt + dt) * h
                                    + hh * ph + dh) * w
                                    + ww * pw + dw) * c + cc;
                                let src = tok * pd
                                    + ((dt * ph + dh) * pw + dw) * c + cc;
                                out[dst] = tokens[src];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Sinusoidal embedding of a scalar diffusion time in [0, 1]
/// (`model.timestep_embedding`).
pub fn timestep_embedding(t: f32, dim: usize) -> Vec<f32> {
    let half = dim / 2;
    let mut out = vec![0.0f32; 2 * half];
    for i in 0..half {
        let freq = (-(10000.0f32).ln() * i as f32 / half as f32).exp();
        let arg = t * 1000.0 * freq;
        out[i] = arg.cos();
        out[half + i] = arg.sin();
    }
    out
}

/// Sequence length below which intra-head fan-out is never worth the
/// chunk bookkeeping: short heads finish in microseconds and the
/// pool round-trip would dominate.  At or above this, a b=1 request
/// with fewer heads than pool workers splits WITHIN each head (see
/// [`denoise_forward`]).
pub const INTRA_HEAD_MIN_TOKENS: usize = 1024;

/// One head's attention dispatch.  `splits > 1` fans each head's
/// query blocks across the shared pool (intra-head parallelism) —
/// only legal when the caller is NOT itself a pool worker.
fn head_attention(cfg: &ModelConfig, blk: &BlockParams, q: &[f32],
                  k: &[f32], v: &[f32], mode: AttnMode,
                  splits: usize) -> Vec<f32> {
    let (n, d) = (cfg.n_tokens, cfg.head_dim);
    match mode {
        AttnMode::Full => {
            attention::full_attention_split(q, k, v, n, d, splits)
        }
        AttnMode::Sla2 { k_pct, quant } => {
            attention::sla2_attention_split(
                q, k, v,
                &Sla2Params {
                    proj_q: &blk.proj_q,
                    proj_k: &blk.proj_k,
                    alpha_logit: &blk.alpha_logit,
                },
                k_pct, n, d, cfg.b_q, cfg.b_k, quant, splits)
        }
        // the training-free variants never read block parameters —
        // that is the point of the comparison
        AttnMode::Sparge2 { k_pct, top_p, quant } => {
            attention::sparge2_attention_split(q, k, v, k_pct, top_p,
                                               n, d, cfg.b_q, cfg.b_k,
                                               quant, splits)
        }
        AttnMode::SvgEar { k_pct, quant } => {
            attention::svg_ear_attention_split(q, k, v, k_pct, n, d,
                                               cfg.b_q, cfg.b_k, quant,
                                               splits)
        }
    }
}

/// DiT forward for ONE sample: `x` is the flat `(T, H, W, C)` noisy
/// latent, `t` the diffusion time, `y` the class label (out-of-range
/// labels clamp to the null class, matching jax's clipped indexing).
/// Returns the flat velocity prediction.
///
/// `parallel_heads` fans the per-block head attentions out over the
/// shared native pool — callers already running ON that pool (the
/// batch-parallel path) must pass `false` or risk the classic nested
/// fan-out deadlock.  When the sequence is long
/// (`n_tokens >= INTRA_HEAD_MIN_TOKENS`) and there are fewer heads
/// than pool workers, the fan-out flips INSIDE the heads instead:
/// heads run sequentially and each one partitions its query blocks
/// across the pool, so b=1 long-context latency scales with cores
/// (bit-identical either way — see docs/KERNELS.md §7).
pub fn denoise_forward(cfg: &ModelConfig, params: &Arc<NativeParams>,
                       x: &[f32], t: f32, y: i32, mode: AttnMode,
                       parallel_heads: bool) -> Result<Vec<f32>> {
    ensure!(x.len() == cfg.video_numel(),
            "latent has {} elements, model {} wants {}", x.len(),
            cfg.name, cfg.video_numel());
    super::stats().denoise_forwards
        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let p = params.as_ref();
    let (n, d) = (cfg.n_tokens, cfg.dim);
    let hd = cfg.heads * cfg.head_dim;
    let pd = patch_dim(cfg);

    // patch embedding + conditioning vector
    let mut tokens = matmul(&patchify(x, cfg), &p.patch_w, n, pd, d);
    add_bias(&mut tokens, &p.patch_b);
    let mut temb = matmul(&timestep_embedding(t, d), &p.t_w1, 1, d, d);
    add_bias(&mut temb, &p.t_b1);
    for v in temb.iter_mut() {
        *v = v.tanh();
    }
    let mut cond = matmul(&temb, &p.t_w2, 1, d, d);
    add_bias(&mut cond, &p.t_b2);
    let yi = (y.max(0) as usize).min(cfg.num_classes);
    for (cv, ye) in cond.iter_mut().zip(&p.y_embed[yi * d..(yi + 1) * d])
    {
        *cv += ye;
    }

    let mut hstate = tokens;
    for bi in 0..p.blocks.len() {
        let blk = &p.blocks[bi];
        let mut ada = matmul(&cond, &blk.ada_w, 1, d, 6 * d);
        add_bias(&mut ada, &blk.ada_b);
        let (sh1, sc1) = (&ada[..d], &ada[d..2 * d]);
        let g1 = &ada[2 * d..3 * d];
        let (sh2, sc2) = (&ada[3 * d..4 * d], &ada[4 * d..5 * d]);
        let g2 = &ada[5 * d..6 * d];

        // attention sub-block
        let mut a_in = layer_norm_rows(&hstate, d);
        modulate_rows(&mut a_in, sh1, sc1);
        let mut qkv = matmul(&a_in, &blk.qkv_w, n, d, 3 * hd);
        add_bias(&mut qkv, &blk.qkv_b);
        // row layout per token: [q heads | k heads | v heads]
        let hdim = cfg.head_dim;
        let extract = |which: usize, head: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(n * hdim);
            for tok in 0..n {
                let base = tok * 3 * hd + which * hd + head * hdim;
                out.extend_from_slice(&qkv[base..base + hdim]);
            }
            out
        };
        // Parallelism shape: with plenty of heads, one pool task per
        // head (the classic fan-out).  In the long-sequence/few-heads
        // regime (b=1 long-context), head-level fan-out caps at
        // cfg.heads tasks and leaves the rest of the pool idle — so
        // run heads SEQUENTIALLY here and let each head fan its query
        // blocks across the whole pool instead.  This thread is not a
        // pool worker (parallel_heads contract), so the inner fan
        // cannot deadlock.
        let pool_w = crate::util::threadpool::shared_pool_width();
        let intra_splits = if parallel_heads
            && cfg.n_tokens >= INTRA_HEAD_MIN_TOKENS
            && cfg.heads < pool_w
        {
            pool_w
        } else {
            1
        };
        let heads_out: Vec<Vec<f32>> = if intra_splits > 1 {
            (0..cfg.heads)
                .map(|hh| head_attention(
                    cfg, blk, &extract(0, hh), &extract(1, hh),
                    &extract(2, hh), mode, intra_splits))
                .collect()
        } else if parallel_heads && cfg.heads >= 2 {
            let inputs: Arc<Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>> =
                Arc::new((0..cfg.heads)
                    .map(|hh| (extract(0, hh), extract(1, hh),
                               extract(2, hh)))
                    .collect());
            let params = Arc::clone(params);
            let cfg = cfg.clone();
            crate::util::threadpool::shared_map(cfg.heads, move |hh| {
                let (q, k, v) = &inputs[hh];
                head_attention(&cfg, &params.blocks[bi], q, k, v, mode,
                               1)
            })
        } else {
            (0..cfg.heads)
                .map(|hh| head_attention(
                    cfg, blk, &extract(0, hh), &extract(1, hh),
                    &extract(2, hh), mode, 1))
                .collect()
        };
        let mut concat = vec![0.0f32; n * hd];
        for (hh, ho) in heads_out.iter().enumerate() {
            for tok in 0..n {
                concat[tok * hd + hh * hdim..tok * hd + (hh + 1) * hdim]
                    .copy_from_slice(&ho[tok * hdim..(tok + 1) * hdim]);
            }
        }
        let mut attn = matmul(&concat, &blk.out_w, n, hd, d);
        add_bias(&mut attn, &blk.out_b);
        for (hrow, arow) in hstate.chunks_exact_mut(d)
            .zip(attn.chunks_exact(d))
        {
            for ((hv, av), gv) in hrow.iter_mut().zip(arow).zip(g1) {
                *hv += gv * av;
            }
        }

        // MLP sub-block
        let mut m_in = layer_norm_rows(&hstate, d);
        modulate_rows(&mut m_in, sh2, sc2);
        let mut hidden = matmul(&m_in, &blk.mlp_w1, n, d, p.mlp_hidden);
        add_bias(&mut hidden, &blk.mlp_b1);
        for v in hidden.iter_mut() {
            *v = gelu(*v);
        }
        let mut mlp = matmul(&hidden, &blk.mlp_w2, n, p.mlp_hidden, d);
        add_bias(&mut mlp, &blk.mlp_b2);
        for (hrow, mrow) in hstate.chunks_exact_mut(d)
            .zip(mlp.chunks_exact(d))
        {
            for ((hv, mv), gv) in hrow.iter_mut().zip(mrow).zip(g2) {
                *hv += gv * mv;
            }
        }
    }

    // final AdaLN + projection back to patches
    let mut fada = matmul(&cond, &p.final_ada_w, 1, d, 2 * d);
    add_bias(&mut fada, &p.final_ada_b);
    let (fsh, fsc) = (&fada[..d], &fada[d..]);
    let mut out_tokens = layer_norm_rows(&hstate, d);
    modulate_rows(&mut out_tokens, fsh, fsc);
    let mut out = matmul(&out_tokens, &p.final_w, n, d, pd);
    add_bias(&mut out, &p.final_b);
    Ok(unpatchify(&out, cfg))
}

/// Map a sparsity tier to the fraction of key blocks kept (mirrors
/// aot.py's `TIERS` plus the `dense` keep-everything tier).  `None`
/// for unknown tiers — the XLA backend fails those with a
/// missing-artifact error, and the native backend must not silently
/// serve dense attention for a typo'd tier instead.
pub fn tier_k_pct(tier: &str) -> Option<f64> {
    match tier {
        "s90" => Some(0.10),
        "s95" => Some(0.05),
        "s97" => Some(0.03),
        "dense" => Some(1.0),
        _ => None,
    }
}

/// Resolve (variant, tier) to the attention mode the forward runs.
/// `quant_mode` is the backend's configured `quant_mode` knob — it
/// applies to the quantizing variants (`sla2`, `sparge2`, `svg_ear`);
/// `sla2_noquant` always runs the exact f32 sparse branch and `full`
/// never quantizes.  Unknown variants fail with the full supported
/// set spelled out so operators can discover what exists.
pub fn attn_mode(variant: &str, tier: &str, quant_mode: QuantMode)
                 -> Result<AttnMode> {
    let k_pct = tier_k_pct(tier).with_context(|| format!(
        "unknown tier {tier:?} (have: s90, s95, s97, dense)"))?;
    match variant {
        "full" => Ok(AttnMode::Full),
        // NOTE: sla2 at k_pct=1.0 is NOT plain full attention — every
        // block goes sparse, the linear branch is empty, and the mix
        // yields `a ⊙ O_full` (alpha-scaled), exactly like the python
        // model.  Running the real kernel preserves that semantics.
        "sla2" => Ok(AttnMode::Sla2 { k_pct, quant: quant_mode }),
        "sla2_noquant" => {
            Ok(AttnMode::Sla2 { k_pct, quant: QuantMode::Off })
        }
        "sparge2" => Ok(AttnMode::Sparge2 {
            k_pct,
            top_p: attention::SPARGE2_TOP_P,
            quant: quant_mode,
        }),
        "svg_ear" => Ok(AttnMode::SvgEar { k_pct, quant: quant_mode }),
        other => bail!("native backend does not implement attention \
                        variant {other:?} (supported: {})",
                       SUPPORTED_VARIANTS.join(", ")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "dit-tiny".into(),
            video: [4, 8, 8, 3],
            patch: [2, 2, 2],
            dim: 64,
            depth: 2,
            heads: 2,
            head_dim: 32,
            b_q: 8,
            b_k: 4,
            n_tokens: 32,
            t_m: 4,
            t_n: 8,
            num_classes: 10,
            param_count: 0,
        }
    }

    #[test]
    fn patchify_roundtrip() {
        let cfg = tiny();
        let mut rng = Pcg32::seeded(1);
        let x = rng.normal_vec(cfg.video_numel());
        let tokens = patchify(&x, &cfg);
        assert_eq!(tokens.len(), cfg.n_tokens * patch_dim(&cfg));
        assert_eq!(unpatchify(&tokens, &cfg), x);
    }

    #[test]
    fn timestep_embedding_endpoints() {
        let e = timestep_embedding(0.0, 8);
        assert_eq!(e.len(), 8);
        // t=0: cos(0)=1, sin(0)=0
        assert!(e[..4].iter().all(|v| (v - 1.0).abs() < 1e-6));
        assert!(e[4..].iter().all(|v| v.abs() < 1e-6));
        let e1 = timestep_embedding(0.5, 8);
        assert!(e1.iter().any(|v| (v - 1.0).abs() > 1e-3));
    }

    #[test]
    fn init_is_deterministic_and_parses_flat() {
        let cfg = tiny();
        let a = NativeParams::init_seeded(&cfg, 42);
        let b = NativeParams::init_seeded(&cfg, 42);
        assert_eq!(a.patch_w, b.patch_w);
        assert_eq!(a.blocks[1].qkv_w, b.blocks[1].qkv_w);
        let c = NativeParams::init_seeded(&cfg, 43);
        assert_ne!(a.patch_w, c.patch_w);
        assert_eq!(a.mlp_hidden, 4 * cfg.dim);
    }

    #[test]
    fn from_flat_validates_count_and_shapes() {
        let cfg = tiny();
        assert_eq!(NativeParams::expected_len(&cfg), 39);
        let err = NativeParams::from_flat(&cfg, &[]).unwrap_err();
        assert!(format!("{err:#}").contains("39"));
    }

    #[test]
    fn adaln_zero_init_predicts_zero_velocity() {
        // AdaLN-zero + zero final projection: the untrained model's
        // velocity is exactly 0 for every variant — the property the
        // XLA artifacts exhibit too (see table1's warm_params note)
        let cfg = tiny();
        let p = Arc::new(NativeParams::init_seeded(&cfg, 42));
        let mut rng = Pcg32::seeded(9);
        let x = rng.normal_vec(cfg.video_numel());
        for mode in [AttnMode::Full,
                     AttnMode::Sla2 { k_pct: 0.10,
                                      quant: QuantMode::Int8 },
                     AttnMode::Sparge2 { k_pct: 0.10,
                                         top_p: 0.9,
                                         quant: QuantMode::Int8 },
                     AttnMode::SvgEar { k_pct: 0.10,
                                        quant: QuantMode::Int8 }] {
            let vel = denoise_forward(&cfg, &p, &x, 0.7, 3, mode, false)
                .unwrap();
            assert!(vel.iter().all(|v| *v == 0.0),
                    "AdaLN-zero init must gate everything off");
        }
    }

    #[test]
    fn forward_is_deterministic_and_variant_sensitive() {
        let cfg = tiny();
        // perturb the gates so attention actually reaches the output
        let mut p = NativeParams::init_seeded(&cfg, 42);
        let mut rng = Pcg32::seeded(11);
        for blk in &mut p.blocks {
            for v in blk.ada_w.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
        for v in p.final_w.iter_mut() {
            *v = rng.normal() * 0.05;
        }
        let p = Arc::new(p);
        let x = rng.normal_vec(cfg.video_numel());
        let full = denoise_forward(&cfg, &p, &x, 0.5, 1, AttnMode::Full,
                                   false).unwrap();
        let again = denoise_forward(&cfg, &p, &x, 0.5, 1, AttnMode::Full,
                                    false).unwrap();
        assert_eq!(full, again);
        let sla2 = denoise_forward(
            &cfg, &p, &x, 0.5, 1,
            AttnMode::Sla2 { k_pct: 0.10, quant: QuantMode::Off },
            false).unwrap();
        assert_ne!(full, sla2,
                   "sparse attention must differ from full attention \
                    once gates are non-zero");
        // head-parallel path must be value-identical to sequential
        let par = denoise_forward(&cfg, &p, &x, 0.5, 1, AttnMode::Full,
                                  true).unwrap();
        assert_eq!(full, par);
    }

    #[test]
    fn tier_and_variant_resolution() {
        assert_eq!(tier_k_pct("s95"), Some(0.05));
        assert_eq!(tier_k_pct("dense"), Some(1.0));
        assert_eq!(tier_k_pct("s99"), None);
        let qm = QuantMode::Int8;
        assert_eq!(attn_mode("full", "dense", qm).unwrap(),
                   AttnMode::Full);
        // sla2 at the dense tier stays SLA2 (alpha-scaled full, python
        // semantics) — the engine's variant_for_tier rewrites dense
        // requests to "full" before they reach a backend
        assert_eq!(attn_mode("sla2", "dense", qm).unwrap(),
                   AttnMode::Sla2 { k_pct: 1.0, quant: qm });
        assert_eq!(attn_mode("sla2", "s97", qm).unwrap(),
                   AttnMode::Sla2 { k_pct: 0.03, quant: qm });
        // the configured mode reaches the sla2 variant...
        assert_eq!(attn_mode("sla2", "s90", QuantMode::Sim).unwrap(),
                   AttnMode::Sla2 { k_pct: 0.10,
                                    quant: QuantMode::Sim });
        // ...but sla2_noquant pins Off regardless of the knob
        assert_eq!(attn_mode("sla2_noquant", "s90", qm).unwrap(),
                   AttnMode::Sla2 { k_pct: 0.10,
                                    quant: QuantMode::Off });
        // the training-free variants resolve with the configured
        // quant mode and sparge2 picks up the top-p constant
        assert_eq!(attn_mode("sparge2", "s90", qm).unwrap(),
                   AttnMode::Sparge2 {
                       k_pct: 0.10,
                       top_p: attention::SPARGE2_TOP_P,
                       quant: qm,
                   });
        assert_eq!(attn_mode("svg_ear", "s95", QuantMode::Off).unwrap(),
                   AttnMode::SvgEar { k_pct: 0.05,
                                      quant: QuantMode::Off });
        // a typo'd tier must ERROR, not silently serve dense attention
        assert!(attn_mode("sla2", "s99", qm).is_err());
        // unimplemented variants error even at the dense tier, and the
        // message lists the whole supported set so operators can
        // discover the variants that DO exist
        for tier in ["s95", "dense"] {
            let err = format!("{:#}",
                              attn_mode("vsa", tier, qm).unwrap_err());
            for v in SUPPORTED_VARIANTS {
                assert!(err.contains(v),
                        "error must list {v:?}, got: {err}");
            }
        }
    }
}
