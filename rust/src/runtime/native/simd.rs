//! Runtime-dispatched SIMD primitives for the native kernels.
//!
//! One ISA is selected per process — AVX2(+FMA) or SSE4.1 on x86_64
//! (via `is_x86_feature_detected!`), NEON on aarch64, scalar anywhere
//! else — and every hot inner kernel in `linalg.rs` / `attention.rs`
//! routes through the four primitives here:
//!
//! * [`dot_i8`]   — widening `i8 x i8 -> i32` dot product (the
//!   `gemm_i8_nt` inner kernel: AVX2 `_mm256_madd_epi16` on
//!   sign-extended operands, SSE4.1 `_mm_madd_epi16`, NEON
//!   `vmull_s8`/`vpadalq_s16`).
//! * [`axpy_i8_i32`] — `acc[j] += x * b[j]` with `i8` operands widened
//!   to `i32` (the `gemm_i8_i32` inner loop).
//! * [`dot_f32`]  — horizontal f32 dot product.
//! * [`axpy_f32`] — `acc[j] += x * b[j]` over f32 rows (the `matmul` /
//!   `matmul_tn` inner loop).
//!
//! # Numerics contract
//!
//! The integer primitives are **bit-identical** to their scalar
//! references on every input: integer adds are exact, so lane order is
//! free.  The f32 primitives split two ways:
//!
//! * [`axpy_f32`] is **bit-identical** to scalar: each output lane
//!   performs the same `mul` + `add` rounding sequence the scalar loop
//!   does (deliberately NOT fused into an FMA), and lanes are
//!   independent output elements — so `matmul` / `matmul_tn` keep the
//!   ascending-`k`-per-element order the bit-identity tests pin.
//! * [`dot_f32`] is **parity-bounded** (rel_err < 1e-6 vs scalar): the
//!   horizontal reduction stripes partial sums across lanes, which
//!   reassociates the adds.  Inputs shorter than one SIMD chunk fall
//!   through to the strict sequential scalar loop, so tiny-`k` calls
//!   (the `k <= 4` shapes some tests compare bit-exactly against
//!   `matmul`) are unchanged, and for a single SSE/NEON chunk the
//!   lanes are reduced in ascending order — also scalar-exact.
//!
//! # Selection and overrides
//!
//! The active ISA resolves once, at the first kernel call:
//! `SLA2_FORCE_SCALAR=1` (env) pins scalar unconditionally; otherwise
//! an ISA requested via [`request`] (the `--kernel-isa` knob) wins if
//! the host supports it; otherwise the best detected ISA.  Tests and
//! benches use [`with_forced_isa`] for a *thread-scoped* override that
//! cannot perturb concurrently running tests.
use std::cell::Cell;
use std::fmt;

use anyhow::{bail, Result};
use once_cell::sync::{Lazy, OnceCell};

/// The instruction sets the dispatch layer knows about.  Every
/// variant exists on every build target; [`KernelIsa::available`]
/// says which ones the running host can execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable scalar reference kernels (always available).
    Scalar,
    /// x86_64 SSE4.1: 4-wide f32, `_mm_madd_epi16` i8 dots.
    Sse41,
    /// x86_64 AVX2+FMA: 8-wide f32, `_mm256_madd_epi16` i8 dots.
    Avx2,
    /// aarch64 NEON: 4-wide f32, `vmull_s8` widening i8 dots.
    Neon,
}

impl KernelIsa {
    /// The wire/CLI name (`--kernel-isa` values, `native_kernels.isa`).
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Sse41 => "sse41",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Neon => "neon",
        }
    }

    /// Parse a `--kernel-isa` value.  `"auto"` is `None` (detect);
    /// unknown names are a startup error, not a silent fallback.
    pub fn parse(name: &str) -> Result<Option<KernelIsa>> {
        Ok(Some(match name {
            "auto" => return Ok(None),
            "scalar" => KernelIsa::Scalar,
            "sse41" => KernelIsa::Sse41,
            "avx2" => KernelIsa::Avx2,
            "neon" => KernelIsa::Neon,
            other => bail!(
                "unknown kernel ISA {other:?} (expected auto|scalar|\
                 sse41|avx2|neon)"),
        }))
    }

    /// Can the running host execute this ISA's kernels?
    pub fn available(self) -> bool {
        match self {
            KernelIsa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx2 => {
                // the f32 dot uses FMA alongside AVX2; every real AVX2
                // part has it, but detect both so the pairing is sound
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Sse41 => {
                std::arch::is_x86_feature_detected!("sse4.1")
            }
            #[cfg(target_arch = "aarch64")]
            KernelIsa::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

impl fmt::Display for KernelIsa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Best ISA the host supports (ignoring every override).
pub fn detect() -> KernelIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if KernelIsa::Avx2.available() {
            return KernelIsa::Avx2;
        }
        if KernelIsa::Sse41.available() {
            return KernelIsa::Sse41;
        }
    }
    #[cfg(target_arch = "aarch64")]
    return KernelIsa::Neon;
    #[allow(unreachable_code)]
    KernelIsa::Scalar
}

/// ISA requested via [`request`] before first use (`--kernel-isa`).
static REQUESTED: OnceCell<KernelIsa> = OnceCell::new();

/// The process-wide resolved ISA.  Priority: `SLA2_FORCE_SCALAR` env
/// > [`REQUESTED`] > [`detect`].  Resolved once, at the first kernel
/// call (or the first explicit [`active`] query).
static ACTIVE: Lazy<KernelIsa> = Lazy::new(|| {
    if force_scalar_env() {
        return KernelIsa::Scalar;
    }
    if let Some(&isa) = REQUESTED.get() {
        return isa;
    }
    detect()
});

fn force_scalar_env() -> bool {
    std::env::var("SLA2_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// The process-wide active ISA (resolving it if needed).
pub fn active() -> KernelIsa {
    *ACTIVE
}

/// Request a specific ISA for the process (the `--kernel-isa` knob).
/// `"auto"` keeps detection.  Errors on unknown names, on ISAs the
/// host lacks, and on requests that arrive after the process already
/// resolved a different ISA (kernels may have run with it; switching
/// mid-flight would make bench rows unattributable).  Returns the ISA
/// the process will use — note `SLA2_FORCE_SCALAR` still wins.
pub fn request(name: &str) -> Result<KernelIsa> {
    let Some(isa) = KernelIsa::parse(name)? else {
        return Ok(active());
    };
    if !isa.available() {
        bail!("kernel ISA {name:?} is not available on this host \
               (detected: {})", detect());
    }
    if let Some(&resolved) = Lazy::get(&ACTIVE) {
        if resolved != isa && !force_scalar_env() {
            bail!("kernel ISA already resolved to {resolved}; \
                   --kernel-isa must be set before the first kernel \
                   call");
        }
        return Ok(resolved);
    }
    if let Err(prior) = REQUESTED.set(isa) {
        if prior != isa {
            bail!("kernel ISA already requested as {prior}; \
                   conflicting --kernel-isa {name:?}");
        }
    }
    Ok(active())
}

thread_local! {
    /// Thread-scoped ISA override ([`with_forced_isa`]) — lets tests
    /// and benches compare ISAs inside one process without racing
    /// concurrently running tests on the process-wide [`ACTIVE`].
    static TL_OVERRIDE: Cell<Option<KernelIsa>> = const { Cell::new(None) };
}

/// The ISA the *calling thread* dispatches on right now.
pub fn current() -> KernelIsa {
    TL_OVERRIDE.with(Cell::get).unwrap_or_else(active)
}

/// Run `f` with the calling thread's kernels pinned to `isa`, then
/// restore (panic-safe).  Thread-scoped: work `f` fans out to pool
/// threads still runs on the process-wide ISA.
pub fn with_forced_isa<R>(isa: KernelIsa, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<KernelIsa>);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(TL_OVERRIDE.with(|c| c.replace(Some(isa))));
    f()
}

// ---------------------------------------------------------------------
// scalar references — the portable baseline and the parity oracle
// ---------------------------------------------------------------------

/// Strict sequential-`k` f32 dot product (the scalar reference).
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Unrolled `i8 x i8 -> i32` dot product: four independent accumulator
/// lanes break the add dependency chain (exact, so lane order is free).
pub fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    let n4 = a.len().min(b.len()) & !3;
    let mut acc = [0i32; 4];
    for (ca, cb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4))
    {
        acc[0] += ca[0] as i32 * cb[0] as i32;
        acc[1] += ca[1] as i32 * cb[1] as i32;
        acc[2] += ca[2] as i32 * cb[2] as i32;
        acc[3] += ca[3] as i32 * cb[3] as i32;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (&x, &y) in a[n4..].iter().zip(&b[n4..]) {
        s += x as i32 * y as i32;
    }
    s
}

/// `acc[j] += x * b[j]` — separate mul and add roundings per element
/// (the contract the SIMD lanes reproduce bit-exactly).
pub fn axpy_f32_scalar(acc: &mut [f32], x: f32, b: &[f32]) {
    for (o, &bv) in acc.iter_mut().zip(b) {
        *o += x * bv;
    }
}

/// `acc[j] += x * b[j]` with `b` widened `i8 -> i32`.
pub fn axpy_i8_i32_scalar(acc: &mut [i32], x: i32, b: &[i8]) {
    for (o, &bv) in acc.iter_mut().zip(b) {
        *o += x * bv as i32;
    }
}

// ---------------------------------------------------------------------
// dispatched primitives
// ---------------------------------------------------------------------

/// Horizontal f32 dot product — parity-bounded vs scalar (rel_err
/// < 1e-6); inputs shorter than one SIMD chunk take the strict
/// sequential scalar path.
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    match current() {
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 if n >= 8 => unsafe { x86::dot_f32_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Sse41 if n >= 4 => unsafe { x86::dot_f32_sse41(a, b) },
        #[cfg(target_arch = "aarch64")]
        KernelIsa::Neon if n >= 4 => unsafe { neon::dot_f32_neon(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

/// Widening `i8 x i8 -> i32` dot product — bit-identical to
/// [`dot_i8_scalar`] on every input (integer adds are exact).
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len().min(b.len());
    match current() {
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 if n >= 16 => unsafe { x86::dot_i8_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Sse41 if n >= 8 => unsafe { x86::dot_i8_sse41(a, b) },
        #[cfg(target_arch = "aarch64")]
        KernelIsa::Neon if n >= 16 => unsafe { neon::dot_i8_neon(a, b) },
        _ => dot_i8_scalar(a, b),
    }
}

/// `acc[j] += x * b[j]` over f32 — bit-identical to the scalar loop
/// (independent lanes, unfused mul+add).
pub fn axpy_f32(acc: &mut [f32], x: f32, b: &[f32]) {
    let n = acc.len().min(b.len());
    match current() {
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 if n >= 8 => unsafe {
            x86::axpy_f32_avx2(acc, x, b)
        },
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Sse41 if n >= 4 => unsafe {
            x86::axpy_f32_sse41(acc, x, b)
        },
        #[cfg(target_arch = "aarch64")]
        KernelIsa::Neon if n >= 4 => unsafe {
            neon::axpy_f32_neon(acc, x, b)
        },
        _ => axpy_f32_scalar(acc, x, b),
    }
}

/// `acc[j] += x * b[j]` with `i8` operands widened to `i32` —
/// bit-identical to scalar (exact).
pub fn axpy_i8_i32(acc: &mut [i32], x: i32, b: &[i8]) {
    let n = acc.len().min(b.len());
    match current() {
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 if n >= 8 => unsafe {
            x86::axpy_i8_i32_avx2(acc, x, b)
        },
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Sse41 if n >= 4 => unsafe {
            x86::axpy_i8_i32_sse41(acc, x, b)
        },
        #[cfg(target_arch = "aarch64")]
        KernelIsa::Neon if n >= 8 => unsafe {
            neon::axpy_i8_i32_neon(acc, x, b)
        },
        _ => axpy_i8_i32_scalar(acc, x, b),
    }
}

// ---------------------------------------------------------------------
// x86_64: AVX2(+FMA) and SSE4.1
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2+FMA support at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_fmadd_ps(av, bv, acc);
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut sum = 0.0f32;
        for l in lanes {
            sum += l;
        }
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified SSE4.1 support at runtime.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dot_f32_sse41(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = _mm_setzero_ps();
        let mut i = 0;
        while i + 4 <= n {
            let av = _mm_loadu_ps(a.as_ptr().add(i));
            let bv = _mm_loadu_ps(b.as_ptr().add(i));
            acc = _mm_add_ps(acc, _mm_mul_ps(av, bv));
            i += 4;
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        // ascending lane order: a single-chunk call reduces exactly
        // like the sequential scalar loop
        let mut sum = 0.0f32;
        for l in lanes {
            sum += l;
        }
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }

    /// Sign-extend 16 `i8` lanes to `i16`, multiply pairwise and add
    /// adjacent pairs into 8 `i32` lanes (`_mm256_madd_epi16`) — the
    /// signed-safe version of the `maddubs` idiom (whose first operand
    /// is unsigned and would corrupt negative Q values).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 16 <= n {
            let av = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let bv = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let aw = _mm256_cvtepi8_epi16(av);
            let bw = _mm256_cvtepi8_epi16(bv);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(aw, bw));
            i += 16;
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut sum: i32 = lanes.iter().sum();
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified SSE4.1 support at runtime.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dot_i8_sse41(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let mut acc = _mm_setzero_si128();
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm_loadl_epi64(a.as_ptr().add(i) as *const __m128i);
            let bv = _mm_loadl_epi64(b.as_ptr().add(i) as *const __m128i);
            let aw = _mm_cvtepi8_epi16(av);
            let bw = _mm_cvtepi8_epi16(bv);
            acc = _mm_add_epi32(acc, _mm_madd_epi16(aw, bw));
            i += 8;
        }
        let mut lanes = [0i32; 4];
        _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, acc);
        let mut sum: i32 = lanes.iter().sum();
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32_avx2(acc: &mut [f32], x: f32, b: &[f32]) {
        let n = acc.len().min(b.len());
        let xv = _mm256_set1_ps(x);
        let mut i = 0;
        while i + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            // unfused mul+add: bit-identical to the scalar loop's two
            // roundings (an FMA here would single-round and diverge)
            let sum = _mm256_add_ps(av, _mm256_mul_ps(xv, bv));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), sum);
            i += 8;
        }
        while i < n {
            acc[i] += x * b[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified SSE4.1 support at runtime.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn axpy_f32_sse41(acc: &mut [f32], x: f32, b: &[f32]) {
        let n = acc.len().min(b.len());
        let xv = _mm_set1_ps(x);
        let mut i = 0;
        while i + 4 <= n {
            let bv = _mm_loadu_ps(b.as_ptr().add(i));
            let av = _mm_loadu_ps(acc.as_ptr().add(i));
            let sum = _mm_add_ps(av, _mm_mul_ps(xv, bv));
            _mm_storeu_ps(acc.as_mut_ptr().add(i), sum);
            i += 4;
        }
        while i < n {
            acc[i] += x * b[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_i8_i32_avx2(acc: &mut [i32], x: i32, b: &[i8]) {
        let n = acc.len().min(b.len());
        let xv = _mm256_set1_epi32(x);
        let mut i = 0;
        while i + 8 <= n {
            let bv = _mm_loadl_epi64(b.as_ptr().add(i) as *const __m128i);
            let bw = _mm256_cvtepi8_epi32(bv);
            let prod = _mm256_mullo_epi32(bw, xv);
            let av =
                _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(acc.as_mut_ptr().add(i) as *mut __m256i,
                                _mm256_add_epi32(av, prod));
            i += 8;
        }
        while i < n {
            acc[i] += x * b[i] as i32;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must have verified SSE4.1 support at runtime.
    #[target_feature(enable = "sse4.1")]
    pub unsafe fn axpy_i8_i32_sse41(acc: &mut [i32], x: i32, b: &[i8]) {
        let n = acc.len().min(b.len());
        let xv = _mm_set1_epi32(x);
        let mut i = 0;
        while i + 4 <= n {
            let raw =
                (b.as_ptr().add(i) as *const i32).read_unaligned();
            let bw = _mm_cvtepi8_epi32(_mm_cvtsi32_si128(raw));
            let prod = _mm_mullo_epi32(bw, xv);
            let av =
                _mm_loadu_si128(acc.as_ptr().add(i) as *const __m128i);
            _mm_storeu_si128(acc.as_mut_ptr().add(i) as *mut __m128i,
                             _mm_add_epi32(av, prod));
            i += 4;
        }
        while i < n {
            acc[i] += x * b[i] as i32;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------
// aarch64: NEON
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is mandatory on aarch64; unsafe only for the intrinsics.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let av = vld1q_f32(a.as_ptr().add(i));
            let bv = vld1q_f32(b.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(av, bv));
            i += 4;
        }
        let mut lanes = [0.0f32; 4];
        vst1q_f32(lanes.as_mut_ptr(), acc);
        let mut sum = 0.0f32;
        for l in lanes {
            sum += l;
        }
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }

    /// Widening multiply (`vmull_s8`) + pairwise accumulate
    /// (`vpadalq_s16`) — the portable-NEON form of the `sdot` idiom.
    ///
    /// # Safety
    /// NEON is mandatory on aarch64; unsafe only for the intrinsics.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8_neon(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_s32(0);
        let mut i = 0;
        while i + 16 <= n {
            let av = vld1q_s8(a.as_ptr().add(i));
            let bv = vld1q_s8(b.as_ptr().add(i));
            let lo = vmull_s8(vget_low_s8(av), vget_low_s8(bv));
            let hi = vmull_s8(vget_high_s8(av), vget_high_s8(bv));
            acc = vpadalq_s16(acc, lo);
            acc = vpadalq_s16(acc, hi);
            i += 16;
        }
        let mut sum = vaddvq_s32(acc);
        while i < n {
            sum += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        sum
    }

    /// # Safety
    /// NEON is mandatory on aarch64; unsafe only for the intrinsics.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f32_neon(acc: &mut [f32], x: f32, b: &[f32]) {
        let n = acc.len().min(b.len());
        let xv = vdupq_n_f32(x);
        let mut i = 0;
        while i + 4 <= n {
            let bv = vld1q_f32(b.as_ptr().add(i));
            let av = vld1q_f32(acc.as_ptr().add(i));
            // unfused mul+add (no vfmaq): scalar-identical rounding
            let sum = vaddq_f32(av, vmulq_f32(xv, bv));
            vst1q_f32(acc.as_mut_ptr().add(i), sum);
            i += 4;
        }
        while i < n {
            acc[i] += x * b[i];
            i += 1;
        }
    }

    /// # Safety
    /// NEON is mandatory on aarch64; unsafe only for the intrinsics.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_i8_i32_neon(acc: &mut [i32], x: i32, b: &[i8]) {
        let n = acc.len().min(b.len());
        let xv = vdupq_n_s32(x);
        let mut i = 0;
        while i + 8 <= n {
            let bv = vld1_s8(b.as_ptr().add(i));
            let bw = vmovl_s8(bv);
            let w0 = vmovl_s16(vget_low_s16(bw));
            let w1 = vmovl_s16(vget_high_s16(bw));
            let a0 = vld1q_s32(acc.as_ptr().add(i));
            let a1 = vld1q_s32(acc.as_ptr().add(i + 4));
            vst1q_s32(acc.as_mut_ptr().add(i),
                      vaddq_s32(a0, vmulq_s32(w0, xv)));
            vst1q_s32(acc.as_mut_ptr().add(i + 4),
                      vaddq_s32(a1, vmulq_s32(w1, xv)));
            i += 8;
        }
        while i < n {
            acc[i] += x * b[i] as i32;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn detection_returns_an_available_isa() {
        let isa = detect();
        assert!(isa.available(), "{isa} detected but not available");
        assert!(KernelIsa::Scalar.available());
        // the resolved process ISA is one the host can run
        assert!(active().available());
    }

    #[test]
    fn parse_round_trips_and_rejects_junk() {
        for isa in [KernelIsa::Scalar, KernelIsa::Sse41, KernelIsa::Avx2,
                    KernelIsa::Neon] {
            assert_eq!(KernelIsa::parse(isa.name()).unwrap(), Some(isa));
        }
        assert_eq!(KernelIsa::parse("auto").unwrap(), None);
        assert!(KernelIsa::parse("avx512").is_err());
        assert!(KernelIsa::parse("").is_err());
    }

    #[test]
    fn with_forced_isa_scopes_and_restores() {
        let before = current();
        let inside = with_forced_isa(KernelIsa::Scalar, current);
        assert_eq!(inside, KernelIsa::Scalar);
        assert_eq!(current(), before, "override leaked past its scope");
        // nested overrides unwind in order
        with_forced_isa(KernelIsa::Scalar, || {
            let seen = with_forced_isa(detect(), current);
            assert_eq!(seen, detect());
            assert_eq!(current(), KernelIsa::Scalar);
        });
    }

    #[test]
    fn integer_primitives_bit_identical_to_scalar_all_remainders() {
        // k sweeps every remainder class of the 16/8/4-wide chunks,
        // plus the shapes the attention path actually runs (d = 32/64,
        // b_k = 16) and straddles (127/128)
        let mut rng = Pcg32::seeded(0xD07);
        for k in (1..=64).chain([127usize, 128]) {
            let a: Vec<i8> =
                (0..k).map(|_| (rng.below(255) as i32 - 127) as i8)
                    .collect();
            let b: Vec<i8> =
                (0..k).map(|_| (rng.below(255) as i32 - 127) as i8)
                    .collect();
            let want = dot_i8_scalar(&a, &b);
            assert_eq!(dot_i8(&a, &b), want, "dot_i8 k={k}");
            let mut acc = vec![0i32; k];
            let mut acc_ref = vec![0i32; k];
            let x = rng.below(255) as i32 - 127;
            axpy_i8_i32(&mut acc, x, &a);
            axpy_i8_i32_scalar(&mut acc_ref, x, &a);
            assert_eq!(acc, acc_ref, "axpy_i8_i32 k={k}");
        }
    }

    #[test]
    fn axpy_f32_bit_identical_to_scalar() {
        let mut rng = Pcg32::seeded(0xF32);
        for k in (1..=32).chain([127usize, 128, 513]) {
            let b = rng.normal_vec(k);
            let x = rng.normal();
            let mut acc = rng.normal_vec(k);
            let mut acc_ref = acc.clone();
            axpy_f32(&mut acc, x, &b);
            axpy_f32_scalar(&mut acc_ref, x, &b);
            assert_eq!(acc, acc_ref, "axpy_f32 k={k}");
        }
    }

    #[test]
    fn dot_f32_parity_bounded_and_tiny_k_exact() {
        let mut rng = Pcg32::seeded(0xD0F);
        for k in [1usize, 2, 3, 8, 9, 32, 127, 128, 513] {
            let a = rng.normal_vec(k);
            let b = rng.normal_vec(k);
            let got = dot_f32(&a, &b) as f64;
            let want = dot_f32_scalar(&a, &b) as f64;
            let denom = a.iter().zip(&b)
                .map(|(x, y)| (x * y).abs() as f64).sum::<f64>()
                .max(1e-9);
            assert!((got - want).abs() / denom < 1e-6,
                    "dot_f32 k={k}: {got} vs {want}");
        }
        // below one SIMD chunk the dispatched dot IS the scalar dot
        let a = rng.normal_vec(3);
        let b = rng.normal_vec(3);
        assert_eq!(dot_f32(&a, &b).to_bits(),
                   dot_f32_scalar(&a, &b).to_bits());
    }

    #[test]
    fn forced_scalar_dispatch_equals_scalar_reference() {
        let mut rng = Pcg32::seeded(0x5CA);
        let a = rng.normal_vec(130);
        let b = rng.normal_vec(130);
        let forced = with_forced_isa(KernelIsa::Scalar,
                                     || dot_f32(&a, &b));
        assert_eq!(forced.to_bits(), dot_f32_scalar(&a, &b).to_bits());
    }
}
