//! Native pure-Rust compute backend: the SLA2 denoise forward on host
//! CPU, no XLA, no artifacts.
//!
//! This backend exists so the WHOLE serving stack — pool, class
//! scheduler, chunked streaming, TCP frontend — runs end-to-end on any
//! host: integration tests stop self-skipping when `make artifacts`
//! has not run, and benches get real (if CPU-scale) numbers.
//!
//! * [`attention`] — the paper's forward math (router, block-sparse
//!   online softmax, linear branch, real-INT8 integer kernels,
//!   alpha mix);
//! * [`simd`] — the runtime-dispatched SIMD kernel layer (AVX2 /
//!   SSE4.1 / NEON with the scalar reference as portable baseline,
//!   selected once per process — docs/KERNELS.md §7);
//! * [`model`] — the DiT forward + canonical parameter layout;
//! * [`NativeBackend`] — the [`ComputeBackend`] implementation:
//!   batch-parallel over the process-wide
//!   [`crate::util::threadpool::shared_map`] pool (head-parallel for
//!   single-sample batches), serves ANY batch size in one launch.
//!
//! Parameters come from `manifest.json` + `params_<cfg>.bin` when an
//! artifacts dir is present (so native and XLA run the SAME weights,
//! which is what the parity tests pin); otherwise from a deterministic
//! seeded init over built-in model configs.
//!
//! The backend implements the closed variant set
//! [`model::SUPPORTED_VARIANTS`] — `full`, the paper's
//! `sla2`/`sla2_noquant`, and the training-free comparison variants
//! `sparge2` (hybrid top-k ∪ top-p, sparse-only) and `svg_ear`
//! (error-aware linear compensation), all sharing one masked
//! sparse+linear core (docs/KERNELS.md, "Variant dispatch").
//!
//! The quantizing variants' INT8 points run in one of three
//! [`QuantMode`]s (`ServeConfig::quant_mode`): `"int8"` (default) is
//! the real integer path — `i8` operand buffers, `i8 x i8 -> i32`
//! GEMMs, per-tile dequant; `"sim"` is the f32 fake-quant simulation
//! kept as the parity oracle; `"off"` disables quantization.  See
//! `docs/KERNELS.md` for the paper-to-code map and the argument for
//! why `"int8"` and `"sim"` agree bit-for-bit on served head shapes.

pub mod attention;
pub mod linalg;
pub mod model;
pub mod simd;

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};
use once_cell::sync::Lazy;

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::threadpool::{shared_map, shared_pool_width};

use super::backend::{BatchSupport, ComputeBackend};
pub use attention::QuantMode;
pub use model::{AttnMode, NativeParams};

/// Process-wide native-kernel counters (all backends in this process
/// share them, like the compile cache) — surfaced in
/// `ServerMetrics::snapshot` under `native_kernels`.
#[derive(Debug, Default)]
pub struct NativeKernelStats {
    /// per-sample DiT forwards
    pub denoise_forwards: AtomicU64,
    /// masked sparse(+linear) head-attention invocations, all
    /// variants combined
    pub attn_heads: AtomicU64,
    /// full-softmax head invocations (dense tier / full variant)
    pub full_heads: AtomicU64,
    /// heads served by the `sla2`/`sla2_noquant` variants (learned
    /// router + alpha mix)
    pub sla2_heads: AtomicU64,
    /// heads served by the `sparge2` variant (top-k ∪ top-p mask,
    /// sparse branch only)
    pub sparge2_heads: AtomicU64,
    /// heads served by the `svg_ear` variant (error-aware routing)
    pub svg_ear_heads: AtomicU64,
    /// `svg_ear` query blocks whose error estimate exceeded the
    /// tolerance and routed their complement through the linear
    /// branch as compensation
    pub ear_compensated_blocks: AtomicU64,
    /// SLA2 heads that ran a quantized sparse path (int8 + sim)
    pub quant_heads: AtomicU64,
    /// quantized heads served by the REAL integer kernels
    /// (`quant_mode = "int8"`)
    pub int8_heads: AtomicU64,
    /// quantized heads served by the f32 fake-quant simulation
    /// (`quant_mode = "sim"`)
    pub sim_heads: AtomicU64,
    /// head invocations that fanned their query blocks across the
    /// shared pool (intra-head parallelism — the long-sequence,
    /// few-heads regime; see docs/KERNELS.md §7)
    pub intra_head_splits: AtomicU64,
    /// (query-block, key-block) tiles routed to the sparse branch
    pub sparse_tiles: AtomicU64,
    /// tiles NOT routed to the sparse branch: linear-branch
    /// compensation for `sla2`/`svg_ear`, dropped outright for
    /// `sparge2` — either way they are the skipped fraction that
    /// [`NativeKernelStats::observed_sparsity`] measures
    pub linear_tiles: AtomicU64,
    /// executes rejected because a sample's output contained NaN/Inf
    /// (the numerical-integrity guard turning garbage into a typed
    /// shard failure instead of streaming it to a client)
    pub nonfinite_outputs: AtomicU64,
}

impl NativeKernelStats {
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as usize;
        Json::obj()
            .push("denoise_forwards", g(&self.denoise_forwards))
            .push("attn_heads", g(&self.attn_heads))
            .push("full_heads", g(&self.full_heads))
            .push("sla2_heads", g(&self.sla2_heads))
            .push("sparge2_heads", g(&self.sparge2_heads))
            .push("svg_ear_heads", g(&self.svg_ear_heads))
            .push("ear_compensated_blocks",
                  g(&self.ear_compensated_blocks))
            .push("quant_heads", g(&self.quant_heads))
            .push("int8_heads", g(&self.int8_heads))
            .push("sim_heads", g(&self.sim_heads))
            .push("intra_head_splits", g(&self.intra_head_splits))
            .push("sparse_tiles", g(&self.sparse_tiles))
            .push("linear_tiles", g(&self.linear_tiles))
            .push("nonfinite_outputs", g(&self.nonfinite_outputs))
            // which kernel ISA this process dispatches to — bench rows
            // and wire metrics are attributable to the code path that
            // actually ran
            .push("isa", simd::active().name())
    }

    /// Achieved block sparsity across every routed tile so far.
    pub fn observed_sparsity(&self) -> f64 {
        let s = self.sparse_tiles.load(Ordering::Relaxed) as f64;
        let l = self.linear_tiles.load(Ordering::Relaxed) as f64;
        if s + l == 0.0 { 0.0 } else { l / (s + l) }
    }
}

static KERNEL_STATS: Lazy<NativeKernelStats> =
    Lazy::new(NativeKernelStats::default);

/// The process-wide native-kernel counters.
pub fn stats() -> &'static NativeKernelStats {
    &KERNEL_STATS
}

/// Built-in model geometries for artifact-free deployments — mirrors
/// `model.py::CONFIGS` (the manifest remains the source of truth when
/// present).
pub fn builtin_config(name: &str) -> Option<ModelConfig> {
    let mk = |name: &str, video: [usize; 4], patch: [usize; 3],
              dim: usize, depth: usize, heads: usize, head_dim: usize,
              b_q: usize, b_k: usize| {
        let n_tokens = (video[0] / patch[0]) * (video[1] / patch[1])
            * (video[2] / patch[2]);
        let mut cfg = ModelConfig {
            name: name.into(), video, patch, dim, depth, heads, head_dim,
            b_q, b_k, n_tokens,
            t_m: n_tokens / b_q,
            t_n: n_tokens / b_k,
            num_classes: 10,
            param_count: 0,
        };
        cfg.param_count = builtin_param_count(&cfg);
        cfg
    };
    match name {
        "dit-tiny" => Some(mk("dit-tiny", [4, 8, 8, 3], [2, 2, 2], 64, 2,
                              2, 32, 8, 4)),
        "dit-small" => Some(mk("dit-small", [8, 16, 16, 3], [2, 2, 2],
                               256, 6, 4, 64, 32, 16)),
        _ => None,
    }
}

/// Exact parameter count of the canonical layout (mirrors
/// `model.param_count` at mlp_ratio 4).
fn builtin_param_count(cfg: &ModelConfig) -> usize {
    let (d, hd) = (cfg.dim, cfg.heads * cfg.head_dim);
    let pd = model::patch_dim(cfg);
    let per_block = 6 * d * d + 6 * d            // ada
        + d * 3 * hd + 3 * hd                    // qkv
        + hd * d + d                             // out
        + d * 4 * d + 4 * d + 4 * d * d + d      // mlp
        + 3 * cfg.head_dim * cfg.head_dim        // proj_q/k/o
        + cfg.t_m;                               // alpha_logit
    pd * d + d                                   // patch
        + 2 * (d * d + d)                        // t mlp
        + (cfg.num_classes + 1) * d              // y_embed
        + d * 2 * d + 2 * d                      // final ada
        + d * pd + pd                            // final proj
        + cfg.depth * per_block
}

/// Default seed for the artifact-free parameter init (the same seed
/// aot.py uses for its PRNG key, for symmetry — the streams differ).
pub const INIT_SEED: u64 = 42;

/// Pure-Rust CPU implementation of [`ComputeBackend`].
pub struct NativeBackend {
    model: ModelConfig,
    params: RefCell<Arc<NativeParams>>,
    executions: Cell<u64>,
    threads: usize,
    /// where the weights came from (logged; pinned by tests)
    params_source: &'static str,
    /// how the `sla2` variant's INT8 points execute
    quant_mode: QuantMode,
}

impl NativeBackend {
    /// Load for `model`: manifest-backed when `artifacts_dir` has one
    /// (shared parse + decode, same weights as the XLA backend),
    /// built-in config + seeded init otherwise.  Quantized serving
    /// defaults to the real integer kernels ([`QuantMode::Int8`]);
    /// use [`NativeBackend::load_with_mode`] to pick another mode.
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str)
                -> Result<NativeBackend> {
        Self::load_with_mode(artifacts_dir, model, QuantMode::Int8)
    }

    /// [`NativeBackend::load`] with an explicit `quant_mode` — the
    /// `ServeConfig::quant_mode` knob lands here via `make_backend`.
    pub fn load_with_mode(artifacts_dir: impl AsRef<Path>, model: &str,
                          quant_mode: QuantMode)
                          -> Result<NativeBackend> {
        let dir = artifacts_dir.as_ref();
        let (cfg, params, source) = if dir.join("manifest.json").exists()
        {
            let manifest = crate::runtime::shared().manifest(dir)?;
            let cfg = manifest.config(model)?.clone();
            let flat = crate::runtime::shared().params(&manifest, model)?;
            let params = NativeParams::from_flat(&cfg, &flat)
                .context("manifest params -> native")?;
            (cfg, params, "manifest")
        } else {
            let cfg = builtin_config(model).with_context(|| format!(
                "no artifacts at {dir:?} and no built-in native config \
                 for model {model:?} (have: dit-tiny, dit-small)"))?;
            let params = NativeParams::init_seeded(&cfg, INIT_SEED);
            (cfg, params, "seeded-init")
        };
        Ok(NativeBackend {
            model: cfg,
            params: RefCell::new(Arc::new(params)),
            executions: Cell::new(0),
            threads: shared_pool_width(),
            params_source: source,
            quant_mode,
        })
    }

    /// `"manifest"` or `"seeded-init"` — where the weights came from.
    pub fn params_source(&self) -> &'static str {
        self.params_source
    }

    /// How this backend executes the `sla2` variant's INT8 points.
    pub fn quant_mode(&self) -> QuantMode {
        self.quant_mode
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        format!("native-cpu ({} threads, params: {}, quant: {}, \
                 isa: {})",
                self.threads, self.params_source,
                self.quant_mode.as_str(), simd::active().name())
    }

    fn model(&self) -> &ModelConfig {
        &self.model
    }

    fn supported_batch_sizes(&self, _variant: &str, _tier: &str)
                             -> BatchSupport {
        BatchSupport::Any
    }

    fn compile(&self, variant: &str, tier: &str, _batch: usize)
               -> Result<()> {
        // nothing to compile — validate the combination resolves
        model::attn_mode(variant, tier, self.quant_mode).map(|_| ())
    }

    fn execute(&self, variant: &str, tier: &str, x: &Tensor, ts: &Tensor,
               ys: &Tensor) -> Result<Tensor> {
        let cfg = &self.model;
        ensure!(x.shape.len() == 5 && x.shape[1..] == cfg.video[..],
                "latent shape {:?} does not match model {} video {:?}",
                x.shape, cfg.name, cfg.video);
        let b = x.shape[0];
        ensure!(b >= 1, "empty batch");
        ensure!(ts.shape == [b] && ys.shape == [b],
                "ts/ys must be ({b},), got {:?}/{:?}", ts.shape,
                ys.shape);
        let mode = model::attn_mode(variant, tier, self.quant_mode)?;
        let xs = x.f32s()?;
        let tss = ts.f32s()?.to_vec();
        let yss = ys.i32s()?.to_vec();
        self.executions.set(self.executions.get() + 1);
        let clip_len = cfg.video_numel();
        let params = Arc::clone(&self.params.borrow());

        let outs: Vec<Result<Vec<f32>>> = if b >= 2 {
            // batch-parallel: one pool job per sample; jobs run the
            // forward with head-parallelism OFF (no nested fan-out)
            let samples: Arc<Vec<Vec<f32>>> = Arc::new(
                xs.chunks_exact(clip_len).map(|s| s.to_vec()).collect());
            let cfg = cfg.clone();
            shared_map(b, move |i| {
                model::denoise_forward(&cfg, &params, &samples[i],
                                       tss[i], yss[i], mode, false)
            })
        } else {
            // single sample: parallelize INSIDE the forward (heads)
            vec![model::denoise_forward(cfg, &params, xs, tss[0],
                                        yss[0], mode, true)]
        };
        let mut data = Vec::with_capacity(b * clip_len);
        for (i, o) in outs.into_iter().enumerate() {
            let o = o?;
            // numerical-integrity guard: never hand garbage up the
            // stack — a NaN/Inf velocity would silently poison the
            // Euler integration and stream a corrupt clip to the
            // client.  Failing the execute turns it into an orderly,
            // contained shard failure instead.
            if let Some(bad) = o.iter().find(|v| !v.is_finite()) {
                KERNEL_STATS.nonfinite_outputs
                    .fetch_add(1, Ordering::Relaxed);
                anyhow::bail!(
                    "non-finite output ({bad}) in sample {i} of \
                     {variant}/{tier} execute (batch {b}): refusing to \
                     emit a corrupt clip");
            }
            data.extend(o);
        }
        let mut shape = vec![b];
        shape.extend_from_slice(&cfg.video);
        Tensor::from_f32(&shape, data)
    }

    fn set_params(&self, params: &[Tensor]) -> Result<()> {
        let np = NativeParams::from_flat(&self.model, params)?;
        *self.params.borrow_mut() = Arc::new(np);
        Ok(())
    }

    fn counters(&self) -> (u64, u64) {
        (0, self.executions.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn loads_builtin_config_without_artifacts() {
        let b = NativeBackend::load("/nonexistent-artifacts", "dit-tiny")
            .unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.params_source(), "seeded-init");
        assert_eq!(b.model().n_tokens, 32);
        assert!(b.model().param_count > 100_000);
        assert_eq!(b.supported_batch_sizes("sla2", "s90"),
                   BatchSupport::Any);
        assert!(NativeBackend::load("/nonexistent", "dit-base").is_err());
    }

    #[test]
    fn quant_mode_defaults_to_int8_and_threads_through() {
        let b = NativeBackend::load("/nonexistent", "dit-tiny").unwrap();
        assert_eq!(b.quant_mode(), QuantMode::Int8);
        assert!(b.platform().contains("quant: int8"));
        let b = NativeBackend::load_with_mode("/nonexistent", "dit-tiny",
                                              QuantMode::Sim).unwrap();
        assert_eq!(b.quant_mode(), QuantMode::Sim);
        assert!(b.platform().contains("quant: sim"));
        // the mode only gates the sla2 variant; full still compiles
        b.compile("full", "dense", 1).unwrap();
    }

    #[test]
    fn execute_validates_shapes_and_counts_executions() {
        let b = NativeBackend::load("/nonexistent", "dit-tiny").unwrap();
        let cfg = b.model().clone();
        let mut rng = Pcg32::seeded(3);
        let x = Tensor::randn(&[2, cfg.video[0], cfg.video[1],
                                cfg.video[2], cfg.video[3]], &mut rng);
        let ts = Tensor::from_f32(&[2], vec![0.5, 0.5]).unwrap();
        let ys = Tensor::from_i32(&[2], vec![1, 2]).unwrap();
        let v = b.execute("sla2", "s90", &x, &ts, &ys).unwrap();
        assert_eq!(v.shape, x.shape);
        assert_eq!(b.counters(), (0, 1));
        // wrong latent shape
        let bad = Tensor::zeros(&[1, 2, 2, 2, 3]);
        let ts1 = Tensor::from_f32(&[1], vec![0.5]).unwrap();
        let ys1 = Tensor::from_i32(&[1], vec![0]).unwrap();
        assert!(b.execute("sla2", "s90", &bad, &ts1, &ys1).is_err());
        // unknown variant: both compile and execute reject it, and
        // the error lists the WHOLE supported set so operators can
        // discover the variants that do exist
        for err in [format!("{:#}", b.compile("vsa", "s95", 2)
                        .unwrap_err()),
                    format!("{:#}", b.execute("vsa", "s95", &x, &ts,
                                              &ys).unwrap_err())] {
            for v in model::SUPPORTED_VARIANTS {
                assert!(err.contains(v),
                        "error must list {v:?}, got: {err}");
            }
        }
    }

    #[test]
    fn batched_execute_equals_per_sample_execute() {
        // the native forward is per-sample independent, so ANY batch
        // split yields identical values — stronger than the XLA
        // backend, where different batch executables may differ in
        // float association
        let b = NativeBackend::load("/nonexistent", "dit-tiny").unwrap();
        let cfg = b.model().clone();
        let mut rng = Pcg32::seeded(4);
        let x3 = Tensor::randn(&[3, cfg.video[0], cfg.video[1],
                                 cfg.video[2], cfg.video[3]], &mut rng);
        let ts3 = Tensor::from_f32(&[3], vec![0.8, 0.5, 0.2]).unwrap();
        let ys3 = Tensor::from_i32(&[3], vec![0, 1, 2]).unwrap();
        let batched = b.execute("sla2_noquant", "s90", &x3, &ts3, &ys3)
            .unwrap();
        let clip_len = cfg.video_numel();
        for i in 0..3 {
            let xi = Tensor::from_f32(
                &[1, cfg.video[0], cfg.video[1], cfg.video[2],
                  cfg.video[3]],
                x3.f32s().unwrap()[i * clip_len..(i + 1) * clip_len]
                    .to_vec()).unwrap();
            let tsi = Tensor::from_f32(
                &[1], vec![ts3.f32s().unwrap()[i]]).unwrap();
            let ysi = Tensor::from_i32(
                &[1], vec![ys3.i32s().unwrap()[i]]).unwrap();
            let vi = b.execute("sla2_noquant", "s90", &xi, &tsi, &ysi)
                .unwrap();
            assert_eq!(vi.f32s().unwrap(),
                       &batched.f32s().unwrap()
                           [i * clip_len..(i + 1) * clip_len],
                       "sample {i} diverged between batch sizes");
        }
    }

    #[test]
    fn nonfinite_outputs_fail_the_execute_and_bump_the_counter() {
        let b = NativeBackend::load("/nonexistent", "dit-tiny").unwrap();
        let cfg = b.model().clone();
        // a NaN in the input latent propagates through the forward
        // (patch embed -> attention -> residuals), so the output
        // contains NaN and the guard must refuse to emit it
        let mut x = Tensor::zeros(&[1, cfg.video[0], cfg.video[1],
                                    cfg.video[2], cfg.video[3]]);
        x.f32s_mut().unwrap()[0] = f32::NAN;
        let ts = Tensor::from_f32(&[1], vec![0.5]).unwrap();
        let ys = Tensor::from_i32(&[1], vec![1]).unwrap();
        let before = stats().nonfinite_outputs.load(Ordering::Relaxed);
        let err = b.execute("sla2", "s90", &x, &ts, &ys).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"),
                "unexpected error: {err:#}");
        assert!(stats().nonfinite_outputs.load(Ordering::Relaxed)
                > before);
        // a clean latent on the same backend still serves
        let ok = Tensor::zeros(&[1, cfg.video[0], cfg.video[1],
                                 cfg.video[2], cfg.video[3]]);
        assert!(b.execute("sla2", "s90", &ok, &ts, &ys).is_ok());
    }

    #[test]
    fn kernel_stats_accumulate() {
        let before = stats().denoise_forwards
            .load(Ordering::Relaxed);
        let b = NativeBackend::load("/nonexistent", "dit-tiny").unwrap();
        let cfg = b.model().clone();
        let mut rng = Pcg32::seeded(5);
        let x = Tensor::randn(&[1, cfg.video[0], cfg.video[1],
                                cfg.video[2], cfg.video[3]], &mut rng);
        let ts = Tensor::from_f32(&[1], vec![0.5]).unwrap();
        let ys = Tensor::from_i32(&[1], vec![1]).unwrap();
        b.execute("sla2", "s90", &x, &ts, &ys).unwrap();
        assert!(stats().denoise_forwards.load(Ordering::Relaxed)
                > before);
        let snap = stats().snapshot();
        assert!(snap.get("sparse_tiles").unwrap().as_usize().unwrap()
                > 0);
        // per-variant counters: each variant's execute bumps its own
        // head counter (process-wide, so assert deltas)
        for (variant, counter) in
            [("sla2", &stats().sla2_heads),
             ("sparge2", &stats().sparge2_heads),
             ("svg_ear", &stats().svg_ear_heads)]
        {
            let before = counter.load(Ordering::Relaxed);
            b.execute(variant, "s90", &x, &ts, &ys).unwrap();
            assert!(counter.load(Ordering::Relaxed) > before,
                    "{variant} execute must bump its head counter");
        }
        for key in ["sla2_heads", "sparge2_heads", "svg_ear_heads",
                    "ear_compensated_blocks", "intra_head_splits"] {
            assert!(stats().snapshot().get(key).is_some(),
                    "snapshot must carry {key}");
        }
        // the snapshot names the dispatched ISA so bench rows and
        // wire metrics are attributable to the path that ran
        assert_eq!(stats().snapshot().get("isa").unwrap().as_str(),
                   Some(simd::active().name()));
        assert!(b.platform().contains("isa: "));
    }
}
