//! Dense-math substrate for the native backend: row-major f32 matmuls
//! (cache-blocked), the `i8 x i8 -> i32` integer GEMMs behind the
//! real-INT8 attention path, and the handful of elementwise ops the
//! DiT forward needs.  Every hot inner loop routes through the
//! runtime-dispatched SIMD primitives in [`super::simd`] (AVX2 /
//! SSE4.1 / NEON, scalar fallback).
//!
//! Numerics mirror the jax source of truth (`python/compile/model.py`,
//! `kernels/ref.py`): layer-norm uses the population variance with eps
//! 1e-6, gelu is the tanh approximation (jax.nn.gelu's default), and
//! softmax subtracts the row max before exponentiating.  [`matmul`]
//! and [`matmul_tn`] accumulate each output element in ascending-`k`
//! order no matter how the loops are blocked OR vectorized (SIMD lanes
//! are independent output columns with unfused mul+add), so neither
//! blocking nor the ISA changes a bit of the result (pinned by
//! `blocked_matmul_is_bit_identical_to_naive` and
//! `f32_matmuls_bit_identical_across_isas` below).  The integer GEMMs
//! are free to reassociate because integer addition is exact.  The
//! horizontal-reduction kernels [`dot`] / [`matmul_nt`] are
//! parity-bounded instead (rel_err < 1e-6 vs scalar; strict
//! sequential below one SIMD chunk) — see `docs/KERNELS.md` §7 for
//! the dispatch table and the f32-exactness argument the INT8 parity
//! tests rely on.

/// Depth of the `b` panel [`matmul`] keeps hot across all `m` rows.
const MATMUL_KC: usize = 128;
/// Width of the `b` panel: a `MATMUL_KC x MATMUL_NC` f32 block is
/// 128 KiB — L2-resident on anything this backend targets.
const MATMUL_NC: usize = 256;
/// Column-panel width for [`gemm_i8_nt`]: the panel of `b` rows reused
/// across every row of `a` stays within L1.
const GEMM_I8_NB: usize = 64;

use super::simd;

/// `a (m, k) @ b (k, n) -> (m, n)`, row-major.  ikj loop order so the
/// inner loop runs over contiguous rows of `b` and `out` (the SIMD
/// [`simd::axpy_f32`] panel); shapes wider than one `KC x NC` panel
/// are cache-blocked over `k` and `n` with bit-identical accumulation
/// order (ascending `k` per output element either way, and the SIMD
/// lanes are independent output columns).
///
/// ```
/// use sla2::runtime::native::linalg::matmul;
/// let a = [1., 2., 3., 4., 5., 6.]; // (2, 3)
/// let b = [7., 8., 9., 10., 11., 12.]; // (3, 2)
/// assert_eq!(matmul(&a, &b, 2, 3, 2), vec![58., 64., 139., 154.]);
/// ```
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
              -> Vec<f32> {
    let mut out = Vec::new();
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// [`matmul`] into a caller-owned buffer (cleared and resized) — the
/// attention hot loops reuse one scratch per shard instead of
/// allocating per (query block, tile) pair.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
                   out: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    out.clear();
    out.resize(m * n, 0.0);
    if k <= MATMUL_KC && n <= MATMUL_NC {
        // single-panel shapes (every attention tile, dit-tiny layers):
        // the straight ikj loop, no blocking overhead
        for i in 0..m {
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
                simd::axpy_f32(orow, av, &b[kk * n..(kk + 1) * n]);
            }
        }
        return;
    }
    // blocked: one KC x NC panel of `b` stays cache-hot across all m
    // rows of `a` (the dit-small MLP walks 1 MiB of weights per call
    // otherwise).  Per output element the adds still run in ascending
    // kk order (nb fixed, kb ascending, kk ascending), so the result
    // is bit-identical to the naive loop above.
    for nb in (0..n).step_by(MATMUL_NC) {
        let ne = (nb + MATMUL_NC).min(n);
        for kb in (0..k).step_by(MATMUL_KC) {
            let ke = (kb + MATMUL_KC).min(k);
            for i in 0..m {
                let orow = &mut out[i * n + nb..i * n + ne];
                for kk in kb..ke {
                    let av = a[i * k + kk];
                    simd::axpy_f32(orow, av,
                                   &b[kk * n + nb..kk * n + ne]);
                }
            }
        }
    }
}

/// `i8` dot product with `i32` accumulation — the inner kernel of
/// [`gemm_i8_nt`], dispatched to the active ISA ([`simd::dot_i8`]:
/// AVX2 `_mm256_madd_epi16`, SSE4.1 `_mm_madd_epi16`, NEON
/// `vmull_s8`, or the unrolled scalar reference).  Integer adds
/// reassociate exactly, so every ISA is bit-identical — unlike the
/// parity-bounded f32 [`dot`].
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot_i8(a, b)
}

/// Integer `a (m, k) @ b (n, k)^T -> (m, n)` with `i32` accumulation —
/// the real-INT8 `Q Kᵀ` product of Alg. 2 (both operands row-major
/// along `k`, like [`matmul_nt`]).  Cache-blocked over `n` so a panel
/// of `b` rows is reused across every row of `a`; the inner kernel is
/// the unrolled [`dot_i8`].  Accumulation is exact (no rounding), so
/// dequantizing the `i32` result with the hoisted scales reproduces
/// the f32 fake-quant path bit-for-bit whenever the f32 path itself
/// is exact (see `docs/KERNELS.md`).
///
/// ```
/// use sla2::runtime::native::linalg::gemm_i8_nt;
/// let a: Vec<i8> = vec![1, 2, 3, 4]; // (2, 2)
/// let b: Vec<i8> = vec![5, 6, 7, 8]; // (2, 2), transposed operand
/// assert_eq!(gemm_i8_nt(&a, &b, 2, 2, 2), vec![17, 23, 39, 53]);
/// ```
pub fn gemm_i8_nt(a: &[i8], b: &[i8], m: usize, k: usize, n: usize)
                  -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    gemm_i8_nt_into(a, b, m, k, n, &mut out);
    out
}

/// [`gemm_i8_nt`] writing into a caller-owned buffer — the attention
/// sparse branch calls this once per (query block, kept tile) pair,
/// so the allocation-free form keeps the hot loop off the allocator.
/// `out` is resized to `m * n` and fully overwritten.
pub fn gemm_i8_nt_into(a: &[i8], b: &[i8], m: usize, k: usize,
                       n: usize, out: &mut Vec<i32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    out.clear();
    out.resize(m * n, 0);
    for jb in (0..n).step_by(GEMM_I8_NB) {
        let je = (jb + GEMM_I8_NB).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in jb..je {
                out[i * n + j] = dot_i8(arow, &b[j * k..(j + 1) * k]);
            }
        }
    }
}

/// Integer `a (m, k) @ b (k, n) -> (m, n)` with `i32` accumulation —
/// the real-INT8 `P V` product of Alg. 2.  ikj loop order: the inner
/// loop ([`simd::axpy_i8_i32`]) widens and multiply-adds contiguous
/// rows of `b` into the `i32` output row.
///
/// ```
/// use sla2::runtime::native::linalg::gemm_i8_i32;
/// let a: Vec<i8> = vec![1, 2, 3, 4]; // (2, 2)
/// let b: Vec<i8> = vec![5, 6, 7, 8]; // (2, 2)
/// assert_eq!(gemm_i8_i32(&a, &b, 2, 2, 2), vec![19, 22, 43, 50]);
/// ```
pub fn gemm_i8_i32(a: &[i8], b: &[i8], m: usize, k: usize, n: usize)
                   -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    gemm_i8_i32_into(a, b, m, k, n, &mut out);
    out
}

/// [`gemm_i8_i32`] writing into a caller-owned buffer (see
/// [`gemm_i8_nt_into`] for why).  `out` is resized to `m * n` and
/// fully overwritten.
pub fn gemm_i8_i32_into(a: &[i8], b: &[i8], m: usize, k: usize,
                        n: usize, out: &mut Vec<i32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    out.clear();
    out.resize(m * n, 0);
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            simd::axpy_i8_i32(orow, av as i32,
                              &b[kk * n..(kk + 1) * n]);
        }
    }
}

/// `a (m, k) @ b (n, k)^T -> (m, n)` — row-by-row dot products
/// (attention scores `Q K^T` without materializing a transpose).
/// Inherits [`dot`]'s SIMD contract: parity-bounded vs scalar for
/// `k` at or above one SIMD chunk, strictly sequential below it.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
                 -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_nt_into(a, b, m, k, n, &mut out);
    out
}

/// [`matmul_nt`] writing into a caller-owned buffer — the sim/off
/// attention score path reuses one buffer per shard instead of
/// allocating per (query block, tile) pair.  `out` is resized to
/// `m * n` and fully overwritten.
pub fn matmul_nt_into(a: &[f32], b: &[f32], m: usize, k: usize,
                      n: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    out.clear();
    out.resize(m * n, 0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = dot(arow, brow);
        }
    }
}

/// `a (k, m)^T @ b (k, n) -> (m, n)` — the linear branch's
/// `phi(K)^T V` tile update.  kij order with the SIMD
/// [`simd::axpy_f32`] inner loop: ascending-`k` per output element,
/// bit-identical across ISAs.
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize)
                 -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            simd::axpy_f32(&mut out[i * n..(i + 1) * n], av, brow);
        }
    }
    out
}

/// f32 dot product, dispatched to the active ISA.  Parity-bounded:
/// the horizontal SIMD reduction reassociates the adds (rel_err
/// < 1e-6 vs the sequential scalar sum); inputs shorter than one SIMD
/// chunk keep the strict sequential order.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot_f32(a, b)
}

/// `x (m, n) + bias (n,)` broadcast over rows, in place.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_exact_mut(bias.len()) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Row-wise softmax over the last dimension, in place.
pub fn softmax_rows(x: &mut [f32], n_cols: usize) {
    for row in x.chunks_exact_mut(n_cols) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            denom += *v;
        }
        for v in row.iter_mut() {
            *v /= denom;
        }
    }
}

/// Parameter-free layer norm per row (population variance, eps 1e-6 —
/// mirrors `model.py::_layer_norm`).
pub fn layer_norm_rows(x: &[f32], n_cols: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.len());
    for row in x.chunks_exact(n_cols) {
        let mu = row.iter().sum::<f32>() / n_cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
            / n_cols as f32;
        let inv = 1.0 / (var + 1e-6).sqrt();
        out.extend(row.iter().map(|v| (v - mu) * inv));
    }
    out
}

/// AdaLN modulation `x * (1 + scale) + shift`, shift/scale broadcast
/// over rows, in place.
pub fn modulate_rows(x: &mut [f32], shift: &[f32], scale: &[f32]) {
    for row in x.chunks_exact_mut(shift.len()) {
        for ((v, sh), sc) in row.iter_mut().zip(shift).zip(scale) {
            *v = *v * (1.0 + sc) + sh;
        }
    }
}

/// jax.nn.gelu default (approximate=True): the tanh form.
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_against_hand_result() {
        // (2,3) @ (3,2)
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        assert_eq!(matmul(&a, &b, 2, 3, 2), vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transposed_variants_agree_with_plain_matmul() {
        let a: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        let b: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        // a (3,4) @ bt (3,4)^T == a @ transpose(bt)
        let mut bt_t = vec![0.0; 12];
        for r in 0..3 {
            for c in 0..4 {
                bt_t[c * 3 + r] = b[r * 4 + c];
            }
        }
        assert_eq!(matmul_nt(&a, &b, 3, 4, 3), matmul(&a, &bt_t, 3, 4, 3));
        // at (4,3): a^T @ b (4,3) == transpose(a) @ b
        let mut a_t = vec![0.0; 12];
        for r in 0..4 {
            for c in 0..3 {
                a_t[c * 4 + r] = a[r * 3 + c];
            }
        }
        assert_eq!(matmul_tn(&a, &b, 4, 3, 3), matmul(&a_t, &b, 3, 4, 3));
    }

    #[test]
    fn blocked_matmul_is_bit_identical_to_naive() {
        // shapes straddling the KC/NC panel boundaries, including
        // non-multiples — the blocked path must reproduce the naive
        // ikj accumulation order EXACTLY (no rel_err tolerance)
        for (m, k, n) in [(3, 300, 70), (5, 129, 257), (2, 128, 256),
                          (1, 400, 513), (7, 131, 300)] {
            let a: Vec<f32> = (0..m * k)
                .map(|i| ((i * 2654435761usize) as f32).sin())
                .collect();
            let b: Vec<f32> = (0..k * n)
                .map(|i| ((i * 40503usize) as f32).cos())
                .collect();
            let mut naive = vec![0.0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let av = a[i * k + kk];
                    for j in 0..n {
                        naive[i * n + j] += av * b[kk * n + j];
                    }
                }
            }
            assert_eq!(matmul(&a, &b, m, k, n), naive,
                       "blocked matmul diverged at ({m},{k},{n})");
        }
    }

    #[test]
    fn integer_gemms_match_naive_i32_references() {
        let mut state = 0x243F_6A88u32;
        let mut next_i8 = || {
            state = state.wrapping_mul(1664525).wrapping_add(1013904223);
            (state >> 24) as i8 // full [-128, 127] range
        };
        for (m, k, n) in [(1, 1, 1), (2, 3, 2), (32, 64, 16),
                          (5, 7, 130), (8, 16, 64)] {
            let a: Vec<i8> = (0..m * k).map(|_| next_i8()).collect();
            let bt: Vec<i8> = (0..n * k).map(|_| next_i8()).collect();
            let b: Vec<i8> = (0..k * n).map(|_| next_i8()).collect();
            let mut want_nt = vec![0i32; m * n];
            let mut want = vec![0i32; m * n];
            for i in 0..m {
                for j in 0..n {
                    for kk in 0..k {
                        want_nt[i * n + j] +=
                            a[i * k + kk] as i32 * bt[j * k + kk] as i32;
                        want[i * n + j] +=
                            a[i * k + kk] as i32 * b[kk * n + j] as i32;
                    }
                }
            }
            assert_eq!(gemm_i8_nt(&a, &bt, m, k, n), want_nt,
                       "gemm_i8_nt diverged at ({m},{k},{n})");
            assert_eq!(gemm_i8_i32(&a, &b, m, k, n), want,
                       "gemm_i8_i32 diverged at ({m},{k},{n})");
        }
    }

    #[test]
    fn dot_i8_handles_remainders_and_sign() {
        assert_eq!(dot_i8(&[], &[]), 0);
        assert_eq!(dot_i8(&[3], &[-4]), -12);
        let a: Vec<i8> = vec![127; 9];
        let b: Vec<i8> = vec![-128; 9];
        assert_eq!(dot_i8(&a, &b), 9 * 127 * -128);
    }

    #[test]
    fn into_variants_match_allocating_gemms_and_reuse_buffers() {
        let mut rng = crate::util::rng::Pcg32::seeded(77);
        let mut i32_buf = Vec::new();
        let mut f32_buf = Vec::new();
        // descending sizes prove the buffers are truncated, not just
        // grown — stale tail elements would poison the next tile
        for (m, k, n) in [(8usize, 64usize, 16usize), (4, 32, 8),
                          (2, 7, 3)] {
            let a: Vec<i8> = (0..m * k)
                .map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let bt: Vec<i8> = (0..n * k)
                .map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            gemm_i8_nt_into(&a, &bt, m, k, n, &mut i32_buf);
            assert_eq!(i32_buf, gemm_i8_nt(&a, &bt, m, k, n));
            gemm_i8_i32_into(&a, &b, m, k, n, &mut i32_buf);
            assert_eq!(i32_buf, gemm_i8_i32(&a, &b, m, k, n));
            let af = rng.normal_vec(m * k);
            let bf = rng.normal_vec(n * k);
            matmul_nt_into(&af, &bf, m, k, n, &mut f32_buf);
            assert_eq!(f32_buf, matmul_nt(&af, &bf, m, k, n));
            let bf2 = rng.normal_vec(k * n);
            matmul_into(&af, &bf2, m, k, n, &mut f32_buf);
            assert_eq!(f32_buf, matmul(&af, &bf2, m, k, n));
        }
    }

    #[test]
    fn integer_kernels_bit_identical_across_isas() {
        // proptest over random i8 operands at remainder-heavy k:
        // whatever ISA dispatch picked must reproduce the forced-
        // scalar result bit-for-bit (exact integer arithmetic)
        use crate::runtime::native::simd::{with_forced_isa, KernelIsa};
        use crate::util::proptest;
        proptest::check(
            "int8-gemm-isa-bit-identity", 64,
            |rng| {
                let k = *[1usize, 3, 7, 15, 16, 17, 31, 33, 63, 64,
                          127, 128][rng.below(12) as usize];
                let m = 1 + rng.below(6) as usize;
                let n = 1 + rng.below(6) as usize;
                let a: Vec<i8> = (0..m * k)
                    .map(|_| (rng.below(255) as i32 - 127) as i8)
                    .collect();
                let bt: Vec<i8> = (0..n * k)
                    .map(|_| (rng.below(255) as i32 - 127) as i8)
                    .collect();
                let b: Vec<i8> = (0..k * n)
                    .map(|_| (rng.below(255) as i32 - 127) as i8)
                    .collect();
                (m, k, n, a, bt, b)
            },
            |(m, k, n, a, bt, b)| {
                let (m, k, n) = (*m, *k, *n);
                let scalar = with_forced_isa(KernelIsa::Scalar, || {
                    (gemm_i8_nt(a, bt, m, k, n),
                     gemm_i8_i32(a, b, m, k, n),
                     dot_i8(&a[..k], &bt[..k]))
                });
                if gemm_i8_nt(a, bt, m, k, n) != scalar.0 {
                    return Err(format!("gemm_i8_nt ({m},{k},{n})"));
                }
                if gemm_i8_i32(a, b, m, k, n) != scalar.1 {
                    return Err(format!("gemm_i8_i32 ({m},{k},{n})"));
                }
                if dot_i8(&a[..k], &bt[..k]) != scalar.2 {
                    return Err(format!("dot_i8 k={k}"));
                }
                Ok(())
            });
    }

    #[test]
    fn f32_matmuls_bit_identical_across_isas() {
        // matmul / matmul_tn vectorize over output columns with
        // unfused mul+add, so the active ISA must reproduce forced
        // scalar EXACTLY — same pin the blocked-vs-naive test makes
        use crate::runtime::native::simd::{with_forced_isa, KernelIsa};
        let mut rng = crate::util::rng::Pcg32::seeded(99);
        for (m, k, n) in [(3usize, 300usize, 70usize), (5, 129, 257),
                          (2, 17, 9), (1, 4, 3), (7, 131, 300)] {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let at = rng.normal_vec(k * m);
            let (want, want_tn) =
                with_forced_isa(KernelIsa::Scalar, || {
                    (matmul(&a, &b, m, k, n),
                     matmul_tn(&at, &b, k, m, n))
                });
            assert_eq!(matmul(&a, &b, m, k, n), want,
                       "matmul ISA-diverged at ({m},{k},{n})");
            assert_eq!(matmul_tn(&at, &b, k, m, n), want_tn,
                       "matmul_tn ISA-diverged at ({m},{k},{n})");
        }
    }

    #[test]
    fn f32_dot_kernels_parity_bounded_across_isas() {
        // dot / matmul_nt reassociate under SIMD: bounded, not exact
        use crate::runtime::native::simd::{with_forced_isa, KernelIsa};
        let mut rng = crate::util::rng::Pcg32::seeded(101);
        for (m, k, n) in [(4usize, 8usize, 4usize), (3, 32, 5),
                          (2, 127, 3), (5, 128, 7), (1, 513, 2)] {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(n * k);
            let got = matmul_nt(&a, &b, m, k, n);
            let want = with_forced_isa(KernelIsa::Scalar,
                                       || matmul_nt(&a, &b, m, k, n));
            let num: f64 = got.iter().zip(&want)
                .map(|(x, y)| ((x - y) as f64).powi(2)).sum();
            let den: f64 = want.iter()
                .map(|y| (*y as f64).powi(2)).sum();
            let rel = num.sqrt() / (den.sqrt() + 1e-12);
            assert!(rel < 1e-6,
                    "matmul_nt ISA rel_err {rel} at ({m},{k},{n})");
        }
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks_exact(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let y = layer_norm_rows(&x, 4);
        for row in y.chunks_exact(4) {
            let mu = row.iter().sum::<f32>() / 4.0;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
                / 4.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gelu_matches_known_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
    }
}
