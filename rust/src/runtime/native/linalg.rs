//! Small dense-math substrate for the native backend: row-major f32
//! matmuls and the handful of elementwise ops the DiT forward needs.
//!
//! Numerics mirror the jax source of truth (`python/compile/model.py`,
//! `kernels/ref.py`): layer-norm uses the population variance with eps
//! 1e-6, gelu is the tanh approximation (jax.nn.gelu's default), and
//! softmax subtracts the row max before exponentiating.

/// `a (m, k) @ b (k, n) -> (m, n)`, row-major.  ikj loop order so the
/// inner loop runs over contiguous rows of `b` and `out`
/// (auto-vectorizes; no blocking — the serving models are small).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
              -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `a (m, k) @ b (n, k)^T -> (m, n)` — row-by-row dot products
/// (attention scores `Q K^T` without materializing a transpose).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
                 -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = dot(arow, brow);
        }
    }
    out
}

/// `a (k, m)^T @ b (k, n) -> (m, n)` — the linear branch's
/// `phi(K)^T V` tile update.
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize)
                 -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `x (m, n) + bias (n,)` broadcast over rows, in place.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_exact_mut(bias.len()) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Row-wise softmax over the last dimension, in place.
pub fn softmax_rows(x: &mut [f32], n_cols: usize) {
    for row in x.chunks_exact_mut(n_cols) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            denom += *v;
        }
        for v in row.iter_mut() {
            *v /= denom;
        }
    }
}

/// Parameter-free layer norm per row (population variance, eps 1e-6 —
/// mirrors `model.py::_layer_norm`).
pub fn layer_norm_rows(x: &[f32], n_cols: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.len());
    for row in x.chunks_exact(n_cols) {
        let mu = row.iter().sum::<f32>() / n_cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
            / n_cols as f32;
        let inv = 1.0 / (var + 1e-6).sqrt();
        out.extend(row.iter().map(|v| (v - mu) * inv));
    }
    out
}

/// AdaLN modulation `x * (1 + scale) + shift`, shift/scale broadcast
/// over rows, in place.
pub fn modulate_rows(x: &mut [f32], shift: &[f32], scale: &[f32]) {
    for row in x.chunks_exact_mut(shift.len()) {
        for ((v, sh), sc) in row.iter_mut().zip(shift).zip(scale) {
            *v = *v * (1.0 + sc) + sh;
        }
    }
}

/// jax.nn.gelu default (approximate=True): the tanh form.
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_against_hand_result() {
        // (2,3) @ (3,2)
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        assert_eq!(matmul(&a, &b, 2, 3, 2), vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transposed_variants_agree_with_plain_matmul() {
        let a: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        let b: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        // a (3,4) @ bt (3,4)^T == a @ transpose(bt)
        let mut bt_t = vec![0.0; 12];
        for r in 0..3 {
            for c in 0..4 {
                bt_t[c * 3 + r] = b[r * 4 + c];
            }
        }
        assert_eq!(matmul_nt(&a, &b, 3, 4, 3), matmul(&a, &bt_t, 3, 4, 3));
        // at (4,3): a^T @ b (4,3) == transpose(a) @ b
        let mut a_t = vec![0.0; 12];
        for r in 0..4 {
            for c in 0..3 {
                a_t[c * 4 + r] = a[r * 3 + c];
            }
        }
        assert_eq!(matmul_tn(&a, &b, 4, 3, 3), matmul(&a_t, &b, 3, 4, 3));
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks_exact(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let y = layer_norm_rows(&x, 4);
        for row in y.chunks_exact(4) {
            let mu = row.iter().sum::<f32>() / 4.0;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
                / 4.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gelu_matches_known_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
    }
}
