//! The PJRT executor: compile-on-demand cache + validated execution.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifact::Manifest;
use super::compile_cache;
use crate::tensor::{Data, Tensor};

/// Single-threaded PJRT runtime (PjRtClient is `Rc`-based, `!Send`).
///
/// The manifest is process-shared (`Arc` via
/// [`compile_cache::SharedArtifacts`]): N pool shards parse
/// `manifest.json` once.  Executables stay per-runtime — they are
/// `Rc`-based and cannot cross threads — but each compile runs inside
/// the process-wide single-flight gate so identical cold-start
/// compiles on sibling shards serialize instead of racing.
pub struct Runtime {
    client: PjRtClient,
    manifest: Arc<Manifest>,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative (compiles, executions) — surfaced in metrics
    counters: RefCell<(usize, usize)>,
}

impl Runtime {
    /// Load the manifest (shared across runtimes in this process) and
    /// connect the PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = compile_cache::shared().manifest(artifacts_dir)?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            counters: RefCell::new((0, 0)),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact's executable.
    ///
    /// Cold path holds the process-wide single-flight ticket for the
    /// artifact name, so two shards that both need `name` right now
    /// run ONE compile at a time (the second starts only after the
    /// first finished, on cores the first is no longer saturating)
    /// instead of racing identical lowering pipelines.
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&spec.file);
        let _ticket = compile_cache::shared().begin_compile(name);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        crate::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.counters.borrow_mut().0 += 1;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact with host tensors; validates shapes/dtypes
    /// against the manifest before handing buffers to XLA.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(),
                  inputs.len());
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if !s.matches(t) {
                bail!("{name}: input {i} mismatch: artifact wants \
                       {:?}/{}, got {:?}/{}",
                      s.shape, s.dtype, t.shape, t.dtype_str());
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<Literal> = inputs.iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        self.counters.borrow_mut().1 += 1;
        let result = exe.execute::<Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let root = result
            .into_iter().next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("{name}: empty result"))?;
        let root = root.to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name}: to_literal: {e}"))?;
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let elems = root.to_tuple()
            .map_err(|e| anyhow::anyhow!("{name}: untuple: {e}"))?;
        if elems.len() != spec.outputs.len() {
            bail!("{name}: manifest declares {} outputs, runtime \
                   returned {}", spec.outputs.len(), elems.len());
        }
        elems.iter().map(literal_to_tensor).collect()
    }

    /// Hot-path variant: execute with pre-converted literals.  `prefix`
    /// (typically the model parameters) is reused across calls so the
    /// per-step cost is only the small dynamic tensors.  Count is
    /// validated against the manifest; shapes are trusted (they were
    /// validated when the prefix was built).
    pub fn execute_literals_with_prefix(&self, name: &str,
                                        prefix: &[Literal],
                                        rest: &[Literal])
                                        -> Result<Vec<Tensor>> {
        let refs: Vec<&Literal> = rest.iter().collect();
        self.execute_literal_refs_with_prefix(name, prefix, &refs)
    }

    /// Like [`Self::execute_literals_with_prefix`] but `rest` is taken
    /// by reference, so hot loops can reuse long-lived literals (e.g.
    /// the per-batch label tensor) across steps without cloning them.
    pub fn execute_literal_refs_with_prefix(&self, name: &str,
                                            prefix: &[Literal],
                                            rest: &[&Literal])
                                            -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        let total = prefix.len() + rest.len();
        if total != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {} (prefix {} + {})",
                  spec.inputs.len(), total, prefix.len(), rest.len());
        }
        let n_outputs = spec.outputs.len();
        let exe = self.executable(name)?;
        let refs: Vec<&Literal> =
            prefix.iter().chain(rest.iter().copied()).collect();
        self.counters.borrow_mut().1 += 1;
        let result = exe.execute::<&Literal>(&refs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let root = result
            .into_iter().next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("{name}: empty result"))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name}: to_literal: {e}"))?;
        let elems = root.to_tuple()
            .map_err(|e| anyhow::anyhow!("{name}: untuple: {e}"))?;
        // same output-count validation as `execute`: the hot path must
        // not silently hand back a tuple the manifest never declared
        if elems.len() != n_outputs {
            bail!("{name}: manifest declares {n_outputs} outputs, \
                   runtime returned {}", elems.len());
        }
        elems.iter().map(literal_to_tensor).collect()
    }

    /// (compiles, executions) so far — cheap observability hook.
    pub fn counters(&self) -> (usize, usize) {
        *self.counters.borrow()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Host tensor -> XLA literal (public: engines pre-convert hot inputs).
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => Literal::vec1(v),
        Data::I32(v) => Literal::vec1(v),
    };
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("literal reshape {:?}: {e}", t.shape))
}

fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
    let shape = lit.array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match shape.ty() {
        ElementType::F32 => {
            let v = lit.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))?;
            Tensor::from_f32(&dims, v)
        }
        ElementType::S32 => {
            let v = lit.to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("to_vec i32: {e}"))?;
            Tensor::from_i32(&dims, v)
        }
        other => bail!("unsupported output element type {other:?}"),
    }
    .context("literal -> tensor")
}
