//! Structural audit of HLO text artifacts.
//!
//! Interpret-mode wall-clock on CPU says nothing about TPU/GPU cost,
//! but the lowered HLO's *structure* does: if the SLA2 artifact ever
//! contained a dense `f32[N,N]` score matmul outside the tile
//! conditionals, the kernel would have silently degraded to full
//! attention.  This module parses `dot` ops and their output shapes
//! from HLO text so tests and the perf pass can pin the structure
//! down (DESIGN.md §8: "no dense N x N fallback anywhere").

use anyhow::Result;

/// One `dot` instruction's output shape (elements, dims).
#[derive(Debug, Clone, PartialEq)]
pub struct DotOp {
    pub dims: Vec<usize>,
}

impl DotOp {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Extract every `dot(` instruction's output shape from HLO text.
///
/// HLO text lines look like
/// `%dot.5 = f32[256,128]{1,0} dot(%a, %b), lhs_contracting_dims=...`;
/// we scan for `= <type>[dims]` immediately preceding ` dot(`.
pub fn parse_dots(hlo_text: &str) -> Vec<DotOp> {
    let mut out = Vec::new();
    for line in hlo_text.lines() {
        let Some(dot_pos) = line.find(" dot(") else { continue };
        let head = &line[..dot_pos];
        // find the last "= f32[...]" (or other dtype) before " dot("
        let Some(eq) = head.rfind('=') else { continue };
        let decl = head[eq + 1..].trim();
        let Some(lb) = decl.find('[') else { continue };
        let Some(rb) = decl[lb..].find(']') else { continue };
        let dims_str = &decl[lb + 1..lb + rb];
        let dims: Option<Vec<usize>> = if dims_str.is_empty() {
            Some(Vec::new())
        } else {
            dims_str.split(',').map(|d| d.trim().parse().ok()).collect()
        };
        if let Some(dims) = dims {
            out.push(DotOp { dims });
        }
    }
    out
}

/// Largest dot output (in elements) in the module.
pub fn max_dot_elems(hlo_text: &str) -> usize {
    parse_dots(hlo_text).iter().map(|d| d.elems()).max().unwrap_or(0)
}

/// Does the module contain a dot whose output has >= 2 dims of at
/// least `n` each (the dense N x N score-matrix signature)?
pub fn has_square_dot(hlo_text: &str, n: usize) -> bool {
    parse_dots(hlo_text).iter().any(|d| {
        d.dims.iter().filter(|&&x| x >= n).count() >= 2
    })
}

/// Audit summary for an artifact file.
pub fn audit_file(path: &std::path::Path) -> Result<(usize, usize, bool)> {
    let text = std::fs::read_to_string(path)?;
    let dots = parse_dots(&text);
    Ok((dots.len(), max_dot_elems(&text), has_square_dot(&text, 256)))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
ENTRY %main {
  %p0 = f32[256,64]{1,0} parameter(0)
  %dot.1 = f32[256,256]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}
  %dot.2 = f32[32,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}
  %dot.s = f32[] dot(%x, %y), lhs_contracting_dims={0}
  %add.1 = f32[256,256]{1,0} add(%dot.1, %dot.1)
}";

    #[test]
    fn parses_shapes() {
        let dots = parse_dots(SAMPLE);
        assert_eq!(dots.len(), 3);
        assert_eq!(dots[0].dims, vec![256, 256]);
        assert_eq!(dots[1].dims, vec![32, 16]);
        assert_eq!(dots[2].dims, Vec::<usize>::new());
    }

    #[test]
    fn max_and_square() {
        assert_eq!(max_dot_elems(SAMPLE), 256 * 256);
        assert!(has_square_dot(SAMPLE, 256));
        assert!(!has_square_dot(SAMPLE, 257));
    }

    #[test]
    fn add_is_not_a_dot() {
        // the add on an [256,256] buffer must not count
        let only_small = "%dot.2 = f32[32,16]{1,0} dot(%a, %b)\n\
                          %add = f32[999,999]{1,0} add(%c, %d)";
        assert_eq!(max_dot_elems(only_small), 512);
        assert!(!has_square_dot(only_small, 256));
    }
}
