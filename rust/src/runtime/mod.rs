//! Execution backends: PJRT/XLA artifact replay and the native
//! pure-Rust SLA2 implementation, behind one [`ComputeBackend`] trait.
//!
//! [`backend`] defines the trait and the [`XlaBackend`] wrapper;
//! [`native`] is the artifact-free CPU implementation; the rest of
//! this module is the PJRT substrate ([`Runtime`], manifest parsing,
//! the shared compile cache).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), so a
//! [`Runtime`] is confined to one thread — the coordinator runs it on a
//! dedicated engine thread and talks to it over channels
//! (`coordinator::engine`).
//!
//! Artifact flow: `manifest.json` → [`Manifest`] → lazy
//! compile-and-cache per artifact → [`Runtime::execute`] with
//! [`Tensor`] I/O (spec-validated so a Rust-side shape bug surfaces as
//! a readable error, not an XLA crash).
//!
//! Cross-shard sharing: the `Send + Sync` halves of artifact loading
//! (manifest parse, parameter decode) live in a process-wide
//! [`compile_cache::SharedArtifacts`] so a sharded pool pays them
//! once, and per-artifact compiles are single-flighted across shards.

mod artifact;
pub mod backend;
pub mod compile_cache;
mod executor;
pub mod hlo_audit;
pub mod native;

pub use artifact::{ArtifactSpec, Manifest, ParamsLayout, TensorSpec};
pub use backend::{denoise_artifact_name, make_backend,
                  manifest_batch_sizes, BatchSupport, ComputeBackend,
                  FaultyBackend, XlaBackend};
pub use compile_cache::{shared, CacheStats, SharedArtifacts};
pub use executor::{tensor_to_literal, Runtime};
pub use native::NativeBackend;
