//! Process-wide shared artifact state for the sharded engine pool.
//!
//! Every pool shard owns its own PJRT runtime (the `xla` client and
//! its executables are `Rc`-based and can never cross threads), but a
//! lot of per-shard startup work is plain `Send + Sync` data that N
//! shards used to redo N times:
//!
//! * **Manifest** — `manifest.json` parse, shared as `Arc<Manifest>`;
//! * **Parameters** — the `params_<cfg>.bin` read + f32 decode +
//!   tensor build (the dominant non-compile startup cost), shared as
//!   `Arc<Vec<Tensor>>` (each shard still converts to its own XLA
//!   literals — those are `Rc`-based);
//! * **Compile gate** — a per-artifact single-flight guard: when two
//!   shards need the same executable at the same time, the second
//!   blocks until the first finishes instead of racing an identical
//!   compile on the same cores.  The compiled executable itself stays
//!   per-shard (it cannot be shared, and the pinned `xla` version
//!   exposes no serialize/deserialize pair to ship bytes across) —
//!   *steady-state* dedup comes from the dispatcher's warm-shard
//!   affinity; the gate bounds the cold-start thundering herd.
//!
//! Loads are single-flighted by doing the file I/O under the map
//! mutex: a second shard asking for the same dir/config blocks on the
//! lock and then hits the cache.  That serializes loads of *different*
//! dirs too, which is fine — real deployments have one artifacts dir.
//!
//! Failed loads are NOT cached (a missing file can be fixed and
//! retried); the [`CacheStats`] counters are surfaced in
//! `ServerMetrics::snapshot` as the compile-dedup observability hook.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::sync::Arc;

use anyhow::Result;
use once_cell::sync::Lazy;

use super::artifact::Manifest;
use crate::tensor::Tensor;

/// Lock-free counters for cache effectiveness (cumulative since
/// process start).
#[derive(Debug, Default)]
pub struct CacheStats {
    /// manifest.json actually read + parsed
    pub manifest_loads: AtomicU64,
    /// manifest requests served from the shared Arc
    pub manifest_hits: AtomicU64,
    /// params_<cfg>.bin actually read + decoded
    pub params_loads: AtomicU64,
    /// params requests served from the shared Arc
    pub params_hits: AtomicU64,
    /// single-flight compile sections entered — one per compile
    /// ATTEMPT, so a failed parse/compile that is retried later
    /// counts again
    pub compile_attempts: AtomicU64,
    /// times a shard blocked on a sibling's in-flight identical compile
    pub singleflight_waits: AtomicU64,
}

impl CacheStats {
    pub fn snapshot(&self) -> CacheStatsSnapshot {
        CacheStatsSnapshot {
            manifest_loads: self.manifest_loads.load(Ordering::Relaxed),
            manifest_hits: self.manifest_hits.load(Ordering::Relaxed),
            params_loads: self.params_loads.load(Ordering::Relaxed),
            params_hits: self.params_hits.load(Ordering::Relaxed),
            compile_attempts:
                self.compile_attempts.load(Ordering::Relaxed),
            singleflight_waits:
                self.singleflight_waits.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStatsSnapshot {
    pub manifest_loads: u64,
    pub manifest_hits: u64,
    pub params_loads: u64,
    pub params_hits: u64,
    pub compile_attempts: u64,
    pub singleflight_waits: u64,
}

/// The process-wide cache (see module docs).
pub struct SharedArtifacts {
    manifests: Mutex<HashMap<PathBuf, Arc<Manifest>>>,
    params: Mutex<HashMap<(PathBuf, String), Arc<Vec<Tensor>>>>,
    inflight: Mutex<HashSet<String>>,
    cv: Condvar,
    stats: CacheStats,
}

static SHARED: Lazy<SharedArtifacts> = Lazy::new(|| SharedArtifacts {
    manifests: Mutex::new(HashMap::new()),
    params: Mutex::new(HashMap::new()),
    inflight: Mutex::new(HashSet::new()),
    cv: Condvar::new(),
    stats: CacheStats::default(),
});

/// The process-wide instance every shard shares.
pub fn shared() -> &'static SharedArtifacts {
    &SHARED
}

impl SharedArtifacts {
    /// Load (or fetch) the manifest for an artifacts dir.  The first
    /// caller parses; every later shard gets the same `Arc`.
    pub fn manifest(&self, dir: impl AsRef<Path>) -> Result<Arc<Manifest>> {
        let dir = dir.as_ref().to_path_buf();
        let mut g = self.manifests.lock().unwrap();
        if let Some(m) = g.get(&dir) {
            self.stats.manifest_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(m));
        }
        let m = Arc::new(Manifest::load(&dir)?);
        self.stats.manifest_loads.fetch_add(1, Ordering::Relaxed);
        g.insert(dir, Arc::clone(&m));
        Ok(m)
    }

    /// Load (or fetch) a model's initial parameter tensors.  One file
    /// read + decode per (dir, config) per process, however many
    /// shards start.
    pub fn params(&self, manifest: &Manifest, config: &str)
                  -> Result<Arc<Vec<Tensor>>> {
        let key = (manifest.dir.clone(), config.to_string());
        let mut g = self.params.lock().unwrap();
        if let Some(p) = g.get(&key) {
            self.stats.params_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(p));
        }
        let p = Arc::new(manifest.load_params(config)?);
        self.stats.params_loads.fetch_add(1, Ordering::Relaxed);
        g.insert(key, Arc::clone(&p));
        Ok(p)
    }

    /// Enter the single-flight compile section for `key` (the
    /// artifact name).  Blocks while another thread holds the same
    /// key; the returned ticket releases the slot on drop — including
    /// on panic, so a failed compile never wedges its siblings.
    pub fn begin_compile(&self, key: &str) -> CompileTicket<'_> {
        let mut g = self.inflight.lock().unwrap();
        let mut counted_wait = false;
        while g.contains(key) {
            if !counted_wait {
                self.stats.singleflight_waits
                    .fetch_add(1, Ordering::Relaxed);
                counted_wait = true;
            }
            g = self.cv.wait(g).unwrap();
        }
        g.insert(key.to_string());
        self.stats.compile_attempts.fetch_add(1, Ordering::Relaxed);
        CompileTicket { cache: self, key: key.to_string() }
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }
}

/// RAII guard for a single-flight compile section.
pub struct CompileTicket<'a> {
    cache: &'a SharedArtifacts,
    key: String,
}

impl Drop for CompileTicket<'_> {
    fn drop(&mut self) {
        let mut g = self.cache.inflight.lock().unwrap();
        g.remove(&self.key);
        self.cache.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn mini_manifest_json() -> &'static str {
        r#"{
  "version": 1,
  "artifacts": [],
  "params": [
    {"config": "m", "file": "params_m.bin",
     "tensors": [{"name": "w", "shape": [2, 2], "offset": 0, "size": 4}]}
  ],
  "configs": {
    "m": {"video":[4,8,8,3],"patch":[2,2,2],"dim":64,"depth":2,
          "heads":2,"head_dim":32,"b_q":8,"b_k":4,"n_tokens":32,
          "t_m":4,"t_n":8,"num_classes":10,"param_count":4}
  }
}"#
    }

    fn write_fixture(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest_json())
            .unwrap();
        let floats: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let bytes: Vec<u8> =
            floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("params_m.bin"), bytes).unwrap();
        dir
    }

    #[test]
    fn manifest_is_shared_across_callers() {
        let dir = write_fixture("sla2_shared_manifest");
        let a = shared().manifest(&dir).unwrap();
        let b = shared().manifest(&dir).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load must reuse the Arc");
        assert_eq!(a.config("m").unwrap().depth, 2);
    }

    #[test]
    fn params_are_shared_across_callers() {
        let dir = write_fixture("sla2_shared_params");
        let m = shared().manifest(&dir).unwrap();
        let p1 = shared().params(&m, "m").unwrap();
        let p2 = shared().params(&m, "m").unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(p1.len(), 1);
        assert_eq!(p1[0].shape, vec![2, 2]);
        assert_eq!(p1[0].f32s().unwrap(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn missing_params_error_is_not_cached() {
        let dir = write_fixture("sla2_shared_params_miss");
        let m = shared().manifest(&dir).unwrap();
        assert!(shared().params(&m, "nope").is_err());
        // the failure did not poison the slot for the good config
        assert!(shared().params(&m, "m").is_ok());
    }

    #[test]
    fn single_flight_blocks_second_compiler() {
        // thread A holds the ticket; thread B must block until A
        // drops it, and the wait must be counted exactly once.
        let waits_before =
            shared().stats().singleflight_waits.load(Ordering::Relaxed);
        let ticket = shared().begin_compile("sf_test_artifact");
        let entered = Arc::new(AtomicUsize::new(0));
        let entered2 = Arc::clone(&entered);
        let h = std::thread::spawn(move || {
            let _t = shared().begin_compile("sf_test_artifact");
            entered2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(entered.load(Ordering::SeqCst), 0,
                   "second compile entered while the first was in \
                    flight");
        drop(ticket);
        h.join().unwrap();
        assert_eq!(entered.load(Ordering::SeqCst), 1);
        let waits_after =
            shared().stats().singleflight_waits.load(Ordering::Relaxed);
        assert!(waits_after >= waits_before + 1);
    }

    #[test]
    fn distinct_artifacts_compile_concurrently() {
        let _a = shared().begin_compile("sf_distinct_a");
        // must not block: different key
        let _b = shared().begin_compile("sf_distinct_b");
    }

    #[test]
    fn ticket_releases_on_panic() {
        let r = std::panic::catch_unwind(|| {
            let _t = shared().begin_compile("sf_panic_artifact");
            panic!("compile failed");
        });
        assert!(r.is_err());
        // slot must be free again: this would deadlock otherwise
        let _t = shared().begin_compile("sf_panic_artifact");
    }
}
