//! Manifest parsing: the I/O contract between aot.py and the runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::{Data, Tensor};
use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j.req("shape")?.as_usize_vec().context("spec shape")?,
            dtype: j.req("dtype")?.as_str().context("spec dtype")?.into(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn matches(&self, t: &Tensor) -> bool {
        t.shape == self.shape && t.dtype_str() == self.dtype
    }

    /// A zero-filled tensor of this spec (placeholder inputs).
    pub fn zeros(&self) -> Tensor {
        match self.dtype.as_str() {
            "int32" => Tensor {
                shape: self.shape.clone(),
                data: Data::I32(vec![0; self.numel()]),
            },
            _ => Tensor::zeros(&self.shape),
        }
    }
}

/// One exported HLO computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.as_f64())
    }
}

/// Layout of a `params_<cfg>.bin` file.
#[derive(Debug, Clone)]
pub struct ParamsLayout {
    pub config: String,
    pub file: String,
    /// (name, shape, offset-in-floats)
    pub tensors: Vec<(String, Vec<usize>, usize)>,
}

impl ParamsLayout {
    pub fn total_floats(&self) -> usize {
        self.tensors.iter()
            .map(|(_, s, _)| s.iter().product::<usize>())
            .sum()
    }
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: BTreeMap<String, ParamsLayout>,
    pub configs: BTreeMap<String, ModelConfig>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.  Callers that may run many times
    /// per process (pool shards) should go through
    /// [`crate::runtime::shared`] instead, which memoizes the parse
    /// behind an `Arc` — this constructor always re-reads the file.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make \
                                      artifacts` first"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: PathBuf, j: &Json) -> Result<Manifest> {
        let mut artifacts = BTreeMap::new();
        for a in j.req("artifacts")?.as_arr().context("artifacts array")? {
            let name = a.req("name")?.as_str().context("name")?.to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.req(key)?.as_arr().context("specs")?.iter()
                    .map(TensorSpec::from_json).collect()
            };
            artifacts.insert(name.clone(), ArtifactSpec {
                name,
                file: a.req("file")?.as_str().context("file")?.into(),
                kind: a.req("kind")?.as_str().context("kind")?.into(),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                meta: a.get("meta").cloned().unwrap_or(Json::obj()),
            });
        }
        let mut params = BTreeMap::new();
        for p in j.req("params")?.as_arr().context("params array")? {
            let config = p.req("config")?.as_str().context("cfg")?
                .to_string();
            let tensors = p.req("tensors")?.as_arr().context("tensors")?
                .iter()
                .map(|t| -> Result<_> {
                    Ok((t.req("name")?.as_str().context("n")?.to_string(),
                        t.req("shape")?.as_usize_vec().context("s")?,
                        t.req("offset")?.as_usize().context("o")?))
                })
                .collect::<Result<Vec<_>>>()?;
            params.insert(config.clone(), ParamsLayout {
                config,
                file: p.req("file")?.as_str().context("file")?.into(),
                tensors,
            });
        }
        let mut configs = BTreeMap::new();
        for (name, cj) in j.req("configs")?.as_obj().context("configs")? {
            configs.insert(name.clone(), ModelConfig::from_json(name, cj)?);
        }
        Ok(Manifest { dir, artifacts, params, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>())
        })
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs.get(name)
            .ok_or_else(|| anyhow::anyhow!("config {name:?} not in \
                                            manifest"))
    }

    /// Load the initial parameter tensors for a model, in the canonical
    /// flatten order (the order every train/denoise artifact expects).
    ///
    /// Returns an OWNED copy (the trainer mutates its set); serving
    /// shards that only read params should use
    /// [`crate::runtime::shared`]'s memoized `params` instead.
    pub fn load_params(&self, config: &str) -> Result<Vec<Tensor>> {
        let layout = self.params.get(config).ok_or_else(|| {
            anyhow::anyhow!("no params for config {config:?}")
        })?;
        let path = self.dir.join(&layout.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        if floats.len() != layout.total_floats() {
            bail!("params file {} has {} floats, layout wants {}",
                  layout.file, floats.len(), layout.total_floats());
        }
        layout.tensors.iter()
            .map(|(_, shape, offset)| {
                let n: usize = shape.iter().product();
                Tensor::from_f32(shape, floats[*offset..offset + n].to_vec())
            })
            .collect()
    }

    /// All artifacts of a kind (for bench sweeps).
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> Json {
        Json::parse(
            r#"{
  "version": 1,
  "artifacts": [
    {"name": "f", "file": "f.hlo.txt", "kind": "attn",
     "inputs": [{"shape": [2, 3], "dtype": "float32"},
                 {"shape": [], "dtype": "int32"}],
     "outputs": [{"shape": [2, 3], "dtype": "float32"}],
     "meta": {"variant": "sla2", "k_pct": 0.05}}
  ],
  "params": [
    {"config": "m", "file": "params_m.bin",
     "tensors": [{"name": "w", "shape": [2, 2], "offset": 0, "size": 4},
                  {"name": "b", "shape": [2], "offset": 4, "size": 2}]}
  ],
  "configs": {
    "m": {"video":[4,8,8,3],"patch":[2,2,2],"dim":64,"depth":2,
          "heads":2,"head_dim":32,"b_q":8,"b_k":4,"n_tokens":32,
          "t_m":4,"t_n":8,"num_classes":10,"param_count":6}
  }
}"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_manifest() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &mini_manifest())
            .unwrap();
        let a = m.artifact("f").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, "int32");
        assert_eq!(a.meta_str("variant"), Some("sla2"));
        assert_eq!(a.meta_f64("k_pct"), Some(0.05));
        assert!(m.artifact("missing").is_err());
        assert_eq!(m.config("m").unwrap().depth, 2);
    }

    #[test]
    fn spec_matching() {
        let s = TensorSpec { shape: vec![2, 3], dtype: "float32".into() };
        assert!(s.matches(&Tensor::zeros(&[2, 3])));
        assert!(!s.matches(&Tensor::zeros(&[3, 2])));
        let z = TensorSpec { shape: vec![2], dtype: "int32".into() }.zeros();
        assert_eq!(z.i32s().unwrap(), &[0, 0]);
    }

    #[test]
    fn params_layout_roundtrip() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &mini_manifest())
            .unwrap();
        let layout = &m.params["m"];
        assert_eq!(layout.total_floats(), 6);
        // write a fake bin and load it back
        let dir = std::env::temp_dir().join("sla2_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let floats: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: Vec<u8> =
            floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("params_m.bin"), bytes).unwrap();
        let m2 = Manifest::from_json(dir, &mini_manifest()).unwrap();
        let ps = m2.load_params("m").unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].shape, vec![2, 2]);
        assert_eq!(ps[1].f32s().unwrap(), &[4.0, 5.0]);
    }

    #[test]
    fn by_kind_filters() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &mini_manifest())
            .unwrap();
        assert_eq!(m.by_kind("attn").len(), 1);
        assert_eq!(m.by_kind("train_step").len(), 0);
    }
}
