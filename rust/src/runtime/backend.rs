//! The compute-backend abstraction: one denoise forward, any engine.
//!
//! [`ComputeBackend`] is the seam between the serving coordinator and
//! whatever actually evaluates the DiT velocity: the engine's sampling
//! loop owns noise init / Euler integration / batching and calls
//! [`ComputeBackend::execute`] once per denoise step.  Two
//! implementations exist:
//!
//! * [`XlaBackend`] — the original path: AOT HLO artifacts executed
//!   through PJRT ([`super::Runtime`]).  Static shapes, so each batch
//!   size is its own executable ([`BatchSupport::Exact`]).
//! * [`crate::runtime::native::NativeBackend`] — a pure-Rust CPU
//!   implementation of the SLA2 forward math (router, block-sparse
//!   softmax, linear branch, alpha mix) with REAL integer INT8
//!   kernels for the quantized sparse branch (`i8` operand buffers,
//!   `i8 x i8 -> i32` GEMMs, per-tile dequant — see
//!   `docs/KERNELS.md`).  No artifacts, no compiles, any batch size
//!   in one launch ([`BatchSupport::Any`]).
//!
//! `ServeConfig::backend` ("xla" | "native") picks the implementation
//! via [`make_backend`]; everything downstream of the engine (pool,
//! scheduler, streaming, TCP) is backend-agnostic.
//! `ServeConfig::quant_mode` ("int8" | "sim" | "off") additionally
//! picks how the native backend executes the `sla2` variant's
//! quantization points; the XLA backend ignores it — its artifacts
//! bake the (simulated) quantization into the lowered HLO.

use std::cell::RefCell;

use anyhow::{Context, Result};
use xla::Literal;

use crate::config::{ModelConfig, ServeConfig};
use crate::tensor::Tensor;
use crate::util::faults::{FaultAction, FaultInjector};

use super::executor::{tensor_to_literal, Runtime};

/// How a backend constrains the batch sizes it can serve for one
/// (variant, tier) combination.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchSupport {
    /// Only these exact sizes run (static-shape XLA executables; one
    /// artifact per size).  Empty = the combination is unavailable.
    Exact(Vec<usize>),
    /// Any batch size runs in a single launch (the native backend).
    Any,
}

/// A compute backend evaluates ONE denoise forward pass; the engine
/// owns everything around it (sampling loop, batching, reply path).
///
/// Implementations may be `!Send` (the PJRT client is `Rc`-based);
/// like the engine that owns them, backends are built on their shard's
/// thread and never migrate.  Interior mutability covers caches and
/// counters, so every method takes `&self`.
pub trait ComputeBackend {
    /// Short stable identifier: `"xla"` or `"native"` (surfaced in
    /// metrics and logs).
    fn name(&self) -> &'static str;

    /// Human-readable execution platform (e.g. PJRT's platform name,
    /// or the native thread-pool width).
    fn platform(&self) -> String;

    /// The model geometry this backend was loaded for.
    fn model(&self) -> &ModelConfig;

    /// Batch sizes servable for (variant, tier).
    fn supported_batch_sizes(&self, variant: &str, tier: &str)
                             -> BatchSupport;

    /// Warm whatever the backend needs for this shape (XLA: compile
    /// the executable).  Optional — `execute` warms lazily too.
    fn compile(&self, variant: &str, tier: &str, batch: usize)
               -> Result<()>;

    /// One denoise forward: `x` is the stacked latent `(b, T, H, W,
    /// C)`, `ts` the per-request timestep `(b,)` f32, `ys` the class
    /// labels `(b,)` i32.  Returns the velocity prediction, shaped
    /// like `x`.
    fn execute(&self, variant: &str, tier: &str, x: &Tensor, ts: &Tensor,
               ys: &Tensor) -> Result<Tensor>;

    /// Replace the parameter set (canonical flatten order — the order
    /// `manifest.params` records and the trainer emits).
    fn set_params(&self, params: &[Tensor]) -> Result<()>;

    /// Cumulative (compiles, executions) for the metrics rollup.
    fn counters(&self) -> (u64, u64);
}

/// Build the backend `serve.backend` names.  `artifacts_dir` is
/// required for `"xla"`; `"native"` uses it when a manifest is present
/// (shared config + params) and falls back to its built-in model
/// configs + seeded parameters otherwise.  `serve.quant_mode` and
/// `serve.kernel_isa` are validated here for the native backend (an
/// unknown mode or an ISA this host cannot run fails loudly at
/// startup, not at the first sla2 request).
pub fn make_backend(artifacts_dir: &str, serve: &ServeConfig)
                    -> Result<Box<dyn ComputeBackend>> {
    match serve.backend.as_str() {
        "xla" => Ok(Box::new(XlaBackend::load(artifacts_dir,
                                              &serve.model)?)),
        "native" => {
            let mode = super::native::QuantMode::parse(
                &serve.quant_mode)?;
            super::native::simd::request(&serve.kernel_isa)?;
            Ok(Box::new(super::native::NativeBackend::load_with_mode(
                artifacts_dir, &serve.model, mode)?))
        }
        other => anyhow::bail!(
            "unknown backend {other:?} (expected \"xla\" or \"native\")"),
    }
}

/// A [`ComputeBackend`] decorator that injects deterministic faults
/// at the execute site (chaos testing; see [`crate::util::faults`]).
/// A `panic` clause unwinds out of `execute` exactly like a real
/// shard bug would, so the pool's `catch_unwind` containment, retry
/// and quarantine paths are exercised end to end; a `slow` clause
/// stalls before delegating.  Everything else passes straight
/// through.  The injector's fault stream is deterministic per
/// (plan, seed, shard), so a failing chaos run replays exactly.
pub struct FaultyBackend {
    inner: Box<dyn ComputeBackend>,
    injector: RefCell<FaultInjector>,
}

impl FaultyBackend {
    pub fn new(inner: Box<dyn ComputeBackend>, injector: FaultInjector)
               -> FaultyBackend {
        FaultyBackend { inner, injector: RefCell::new(injector) }
    }
}

impl ComputeBackend for FaultyBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn platform(&self) -> String {
        format!("{} (fault-injected)", self.inner.platform())
    }

    fn model(&self) -> &ModelConfig {
        self.inner.model()
    }

    fn supported_batch_sizes(&self, variant: &str, tier: &str)
                             -> BatchSupport {
        self.inner.supported_batch_sizes(variant, tier)
    }

    fn compile(&self, variant: &str, tier: &str, batch: usize)
               -> Result<()> {
        self.inner.compile(variant, tier, batch)
    }

    fn execute(&self, variant: &str, tier: &str, x: &Tensor, ts: &Tensor,
               ys: &Tensor) -> Result<Tensor> {
        let action = self.injector.borrow_mut().check();
        match action {
            FaultAction::Panic => {
                panic!("injected fault: panic at execute site");
            }
            FaultAction::Slow(d) => std::thread::sleep(d),
            // a wedged execute: stall forever.  The thread is
            // unrecoverable by design — the pool's watchdog detects
            // the stale heartbeat, fences this shard's generation and
            // abandons the thread, so the loop never returns.
            FaultAction::Hang => loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            },
            // net-site clauses never reach an execute-site injector
            // (the plan parser pins them to the net site)
            FaultAction::DropConn
            | FaultAction::SlowClient(_)
            | FaultAction::None => {}
        }
        self.inner.execute(variant, tier, x, ts, ys)
    }

    fn set_params(&self, params: &[Tensor]) -> Result<()> {
        self.inner.set_params(params)
    }

    fn counters(&self) -> (u64, u64) {
        self.inner.counters()
    }
}

/// The artifact name for a (model, variant, tier, batch) combination —
/// single source of naming truth, mirrored by aot.py.
pub fn denoise_artifact_name(model: &str, variant: &str, tier: &str,
                             batch: usize) -> String {
    format!("denoise_{model}_{variant}_{tier}_b{batch}")
}

/// Batch sizes the manifest carries for (model, variant, tier).
pub fn manifest_batch_sizes(manifest: &super::Manifest, model: &str,
                            variant: &str, tier: &str) -> Vec<usize> {
    let prefix = format!("denoise_{model}_{variant}_{tier}_b");
    let mut sizes: Vec<usize> = manifest
        .artifacts
        .keys()
        .filter_map(|name| name.strip_prefix(&prefix))
        .filter_map(|suffix| suffix.parse().ok())
        .collect();
    sizes.sort_unstable();
    sizes
}

/// The PJRT/XLA implementation of [`ComputeBackend`]: wraps a
/// [`Runtime`] plus the model parameters pre-converted to literals, so
/// the per-step cost is only the conversion of the tensors that
/// actually changed (`x`, `ts`) — the artifact name and the label
/// literal are cached across the steps of a sub-batch (the sampling
/// loop calls `execute` with identical `ys` every step; re-converting
/// it per step would regress the engine's old label-literal hoist).
pub struct XlaBackend {
    runtime: Runtime,
    model: ModelConfig,
    /// model parameters as literals (hot-loop reuse across every step
    /// of every request)
    params: RefCell<Vec<Literal>>,
    /// per-sub-batch invariants, reused while (variant, tier, batch,
    /// labels) stay the same
    step_cache: RefCell<Option<StepCache>>,
}

struct StepCache {
    variant: String,
    tier: String,
    batch: usize,
    ys: Vec<i32>,
    ys_lit: Literal,
    artifact: String,
}

impl XlaBackend {
    pub fn load(artifacts_dir: &str, model: &str) -> Result<XlaBackend> {
        let runtime = Runtime::load(artifacts_dir)?;
        let model = runtime.manifest().config(model)?.clone();
        // host-side parameter tensors are process-shared: the file
        // read + f32 decode happens once, not once per shard; only
        // the (Rc-based, thread-confined) literal conversion is ours
        let params = super::shared()
            .params(runtime.manifest(), &model.name)?;
        let params = params.iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()
            .context("params -> literals")?;
        Ok(XlaBackend {
            runtime,
            model,
            params: RefCell::new(params),
            step_cache: RefCell::new(None),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn platform(&self) -> String {
        self.runtime.platform()
    }

    fn model(&self) -> &ModelConfig {
        &self.model
    }

    fn supported_batch_sizes(&self, variant: &str, tier: &str)
                             -> BatchSupport {
        BatchSupport::Exact(manifest_batch_sizes(
            self.runtime.manifest(), &self.model.name, variant, tier))
    }

    fn compile(&self, variant: &str, tier: &str, batch: usize)
               -> Result<()> {
        let name = denoise_artifact_name(&self.model.name, variant, tier,
                                         batch);
        self.runtime.executable(&name).map(|_| ())
    }

    fn execute(&self, variant: &str, tier: &str, x: &Tensor, ts: &Tensor,
               ys: &Tensor) -> Result<Tensor> {
        let batch = *x.shape.first().context("x must be batched")?;
        let labels = ys.i32s()?;
        let mut cache = self.step_cache.borrow_mut();
        let hit = matches!(&*cache, Some(c) if c.batch == batch
                           && c.ys == labels && c.variant == variant
                           && c.tier == tier);
        if !hit {
            *cache = Some(StepCache {
                variant: variant.to_string(),
                tier: tier.to_string(),
                batch,
                ys: labels.to_vec(),
                ys_lit: tensor_to_literal(ys)?,
                artifact: denoise_artifact_name(&self.model.name,
                                                variant, tier, batch),
            });
        }
        let c = cache.as_ref().expect("populated above");
        let x_lit = tensor_to_literal(x)?;
        let ts_lit = tensor_to_literal(ts)?;
        self.runtime
            .execute_literal_refs_with_prefix(
                &c.artifact, &self.params.borrow(),
                &[&x_lit, &ts_lit, &c.ys_lit])?
            .into_iter()
            .next()
            .with_context(|| format!("{}: denoise returned nothing",
                                     c.artifact))
    }

    fn set_params(&self, params: &[Tensor]) -> Result<()> {
        *self.params.borrow_mut() = params.iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    fn counters(&self) -> (u64, u64) {
        let (compiles, executions) = self.runtime.counters();
        (compiles as u64, executions as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_naming() {
        assert_eq!(denoise_artifact_name("dit-tiny", "sla2", "s90", 2),
                   "denoise_dit-tiny_sla2_s90_b2");
    }

    #[test]
    fn make_backend_rejects_unknown_name() {
        let serve = ServeConfig {
            backend: "cuda".into(),
            ..ServeConfig::default()
        };
        let err = make_backend("/nonexistent", &serve).unwrap_err();
        assert!(format!("{err:#}").contains("unknown backend"));
    }
}
