//! Rust-driven two-stage SLA2 training (paper Alg. 1).
//!
//! The exported `train_*` / `stage1_*` HLOs contain the full update
//! (loss, gradients, Adam) — this driver owns the parameter buffers
//! and the data stream, so training works with Python long gone:
//!
//!  * **Stage 1** — sample (Q, K, V) from the model's attention layers
//!    (`collect_qkv_*` artifact) and fit the router + alpha against
//!    full attention (SoftTop-k inside the HLO);
//!  * **Stage 2** — merge the trained router back and fine-tune the
//!    whole model end-to-end (hard Top-k + QAT forward inside the
//!    Pallas-lowered HLO), on synthetic video batches.

use anyhow::{Context, Result};

use crate::config::{ModelConfig, TrainConfig};
use crate::runtime::Runtime;
use crate::tensor::{Data, Tensor};
use crate::util::rng::Pcg32;
use crate::video::synth;

/// Parameters + Adam moments + step counter, in artifact input order.
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: Tensor, // i32 scalar
}

impl TrainState {
    pub fn fresh(params: Vec<Tensor>) -> TrainState {
        let zeros =
            |ps: &[Tensor]| ps.iter().map(|p| Tensor::zeros(&p.shape))
                .collect::<Vec<_>>();
        TrainState { m: zeros(&params), v: zeros(&params), params,
                     step: Tensor::scalar_i32(0) }
    }

    fn flat_inputs(&self) -> Vec<Tensor> {
        let mut v: Vec<Tensor> = Vec::with_capacity(3 * self.params.len()
                                                    + 1);
        v.extend(self.params.iter().cloned());
        v.extend(self.m.iter().cloned());
        v.extend(self.v.iter().cloned());
        v.push(self.step.clone());
        v
    }

    /// Rebuild from a train-step output tuple: params, m, v, step, loss.
    fn absorb(&mut self, mut outs: Vec<Tensor>) -> Result<f64> {
        let n = self.params.len();
        anyhow::ensure!(outs.len() == 3 * n + 2,
                        "train step returned {} outputs, want {}",
                        outs.len(), 3 * n + 2);
        let loss_t = outs.pop().unwrap();
        let loss = loss_t.f32s()?[0] as f64;
        self.step = outs.pop().unwrap();
        self.v = outs.split_off(2 * n);
        self.m = outs.split_off(n);
        self.params = outs;
        Ok(loss)
    }
}

pub struct Trainer {
    pub runtime: Runtime,
    pub model: ModelConfig,
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(artifacts_dir: &str, cfg: TrainConfig) -> Result<Trainer> {
        let runtime = Runtime::load(artifacts_dir)?;
        let model = runtime.manifest().config(&cfg.model)?.clone();
        Ok(Trainer { runtime, model, cfg })
    }

    pub fn init_state(&self) -> Result<TrainState> {
        Ok(TrainState::fresh(
            self.runtime.manifest().load_params(&self.cfg.model)?))
    }

    fn stage2_artifact(&self) -> String {
        format!("train_{}_{}_{}_b{}", self.cfg.model, self.cfg.variant,
                self.cfg.tier, self.cfg.batch)
    }

    /// One Stage-2 step on a synthetic batch; returns the loss.
    pub fn stage2_step(&self, state: &mut TrainState, rng: &mut Pcg32,
                       seed: i32) -> Result<f64> {
        let (x0s, ys) = synth::synthetic_batch(&self.model, self.cfg.batch,
                                               rng);
        let ys = Tensor::from_i32(&[self.cfg.batch], ys)?;
        let mut inputs = state.flat_inputs();
        inputs.push(x0s);
        inputs.push(ys);
        inputs.push(Tensor::scalar_i32(seed));
        let outs = self.runtime.execute(&self.stage2_artifact(), &inputs)?;
        state.absorb(outs)
    }

    /// Run Stage 2 for `steps` steps; returns the loss curve.
    pub fn run_stage2<F: FnMut(usize, f64)>(
        &self, state: &mut TrainState, steps: usize, mut on_log: F)
        -> Result<Vec<f64>> {
        let mut rng = Pcg32::seeded(self.cfg.seed);
        let mut losses = Vec::with_capacity(steps);
        for i in 0..steps {
            let loss = self.stage2_step(state, &mut rng, i as i32)
                .with_context(|| format!("stage-2 step {i}"))?;
            losses.push(loss);
            if i % self.cfg.log_every == 0 || i + 1 == steps {
                on_log(i, loss);
            }
        }
        Ok(losses)
    }

    // ------------------------------------------------------------------
    // Stage 1
    // ------------------------------------------------------------------

    /// Indices of (alpha_logit, proj_k, proj_q) per block inside the
    /// canonical params order — jax's flatten sorts dict keys, so the
    /// Stage-1 pytree `[{alpha_logit, proj_k, proj_q}; depth]` flattens
    /// in exactly this per-block key order.
    fn router_indices(&self) -> Result<Vec<usize>> {
        let layout = self.runtime.manifest().params
            .get(&self.cfg.model)
            .context("params layout")?;
        let find = |name: &str| -> Result<usize> {
            layout.tensors.iter().position(|(n, _, _)| n == name)
                .with_context(|| format!("param {name} not in layout"))
        };
        let mut idx = Vec::with_capacity(3 * self.model.depth);
        for b in 0..self.model.depth {
            idx.push(find(&format!("blocks/{b}/attn_alpha_logit"))?);
            idx.push(find(&format!("blocks/{b}/attn_proj_k"))?);
            idx.push(find(&format!("blocks/{b}/attn_proj_q"))?);
        }
        Ok(idx)
    }

    /// Sample one (L, heads, 3, N, d) QKV stack via `collect_qkv_*`
    /// (Alg. 1 line 2): noise a synthetic clip to a random t and run
    /// the full-attention forward, capturing attention inputs.
    pub fn collect_qkv(&self, params: &[Tensor], rng: &mut Pcg32)
                       -> Result<Tensor> {
        let label = rng.below(self.model.num_classes as u32) as usize;
        let x0 = synth::synthetic_clip(&self.model, label, rng);
        let eps = Tensor::randn(&x0.shape, rng);
        let t = Tensor::scalar_f32(0.1 + 0.8 * rng.f32());
        let y = Tensor::scalar_i32(label as i32);
        let mut inputs: Vec<Tensor> = params.to_vec();
        inputs.extend([x0, y, t, eps]);
        let outs = self.runtime.execute(
            &format!("collect_qkv_{}", self.cfg.model), &inputs)?;
        outs.into_iter().next().context("collect_qkv output")
    }

    /// Run Stage 1: fit router + alpha on freshly sampled QKV stacks.
    /// Returns (updated router state merged into `state.params`,
    /// loss curve).
    pub fn run_stage1<F: FnMut(usize, f64)>(
        &self, state: &mut TrainState, steps: usize, mut on_log: F)
        -> Result<Vec<f64>> {
        let idx = self.router_indices()?;
        let mut rparams: Vec<Tensor> =
            idx.iter().map(|&i| state.params[i].clone()).collect();
        let mut m: Vec<Tensor> =
            rparams.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let mut v = m.clone();
        let mut step = Tensor::scalar_i32(0);
        let artifact = format!("stage1_{}_{}", self.cfg.model, self.cfg.tier);
        let mut rng = Pcg32::seeded(self.cfg.seed ^ 0x51a2);
        let mut losses = Vec::with_capacity(steps);
        // a small pool of QKV stacks, refreshed round-robin (the paper
        // trains on a fixed sampled dataset D)
        let pool: Vec<Tensor> = (0..4)
            .map(|_| self.collect_qkv(&state.params, &mut rng))
            .collect::<Result<_>>()?;
        for i in 0..steps {
            let qkv = &pool[i % pool.len()];
            let mut inputs: Vec<Tensor> = rparams.clone();
            inputs.extend(m.iter().cloned());
            inputs.extend(v.iter().cloned());
            inputs.push(step.clone());
            inputs.push(qkv.clone());
            let mut outs = self.runtime.execute(&artifact, &inputs)
                .with_context(|| format!("stage-1 step {i}"))?;
            let n = rparams.len();
            anyhow::ensure!(outs.len() == 3 * n + 2);
            let loss = outs.pop().unwrap().f32s()?[0] as f64;
            step = outs.pop().unwrap();
            v = outs.split_off(2 * n);
            m = outs.split_off(n);
            rparams = outs;
            losses.push(loss);
            if i % self.cfg.log_every == 0 || i + 1 == steps {
                on_log(i, loss);
            }
        }
        // merge back (Alg. 1: Stage 2 starts from the fitted router)
        for (&i, rp) in idx.iter().zip(&rparams) {
            state.params[i] = rp.clone();
        }
        Ok(losses)
    }

    /// Mean sigmoid(alpha_logit) over blocks — observability for the
    /// learnable mixing ratio.
    pub fn mean_alpha(&self, state: &TrainState) -> Result<f64> {
        let idx = self.router_indices()?;
        let mut acc = 0.0;
        let mut n = 0usize;
        for chunk in idx.chunks(3) {
            let logits = state.params[chunk[0]].f32s()?;
            for &l in logits {
                acc += 1.0 / (1.0 + (-l as f64).exp());
                n += 1;
            }
        }
        Ok(acc / n as f64)
    }
}

/// Quick structural check used by tests: every tensor in a state is
/// finite (guards against NaN blowups in long runs).
pub fn state_is_finite(state: &TrainState) -> bool {
    state.params.iter().chain(&state.m).chain(&state.v).all(|t| {
        match &t.data {
            Data::F32(v) => v.iter().all(|x| x.is_finite()),
            Data::I32(_) => true,
        }
    })
}
