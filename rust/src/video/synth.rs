//! Deterministic moving-Gaussian-blob video generator.
//!
//! Rust mirror of `python/compile/train.py::synthetic_video` (not
//! bit-identical — each side uses its own RNG — but the same family:
//! one Gaussian blob per clip, class label sets the motion direction,
//! speed/start position randomized per sample).  This gives the
//! training and serving workloads real temporal structure so motion /
//! consistency proxies measure something.

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// One clip of shape `cfg.video = (T, H, W, C)`, values ~ [-0.5, 1.5].
pub fn synthetic_clip(cfg: &ModelConfig, label: usize,
                      rng: &mut Pcg32) -> Tensor {
    let [t, h, w, c] = cfg.video;
    let angle = 2.0 * std::f32::consts::PI * label as f32
        / cfg.num_classes as f32;
    let speed = 0.25 + 0.5 * rng.f32();
    let cx0 = 0.25 + 0.5 * rng.f32();
    let cy0 = 0.25 + 0.5 * rng.f32();
    let mut data = vec![0.0f32; t * h * w * c];
    for ti in 0..t {
        let tf = ti as f32 / t as f32;
        let cx = (cx0 + speed * tf * angle.cos()).rem_euclid(1.0);
        let cy = (cy0 + speed * tf * angle.sin()).rem_euclid(1.0);
        for yi in 0..h {
            let y = yi as f32 / h as f32;
            for xi in 0..w {
                let x = xi as f32 / w as f32;
                let d2 = (y - cy) * (y - cy) + (x - cx) * (x - cx);
                let blob = (-d2 / 0.02).exp();
                for ci in 0..c {
                    let chan = blob * (0.5 + 0.5 * (angle + ci as f32).cos());
                    data[((ti * h + yi) * w + xi) * c + ci] =
                        2.0 * chan - 0.5;
                }
            }
        }
    }
    Tensor::from_f32(&[t, h, w, c], data).unwrap()
}

/// A batch of clips + labels: `((B, T, H, W, C), Vec<label>)`.
pub fn synthetic_batch(cfg: &ModelConfig, batch: usize,
                       rng: &mut Pcg32) -> (Tensor, Vec<i32>) {
    let labels: Vec<i32> = (0..batch)
        .map(|_| rng.below(cfg.num_classes as u32) as i32)
        .collect();
    let clips: Vec<Tensor> = labels.iter()
        .map(|&l| synthetic_clip(cfg, l as usize, rng))
        .collect();
    let refs: Vec<&Tensor> = clips.iter().collect();
    (Tensor::stack(&refs).unwrap(), labels)
}

/// Blob centroid per frame — used by the class-consistency proxy.
pub fn frame_centroids(clip: &Tensor) -> Vec<(f32, f32)> {
    let [t, h, w, c] = [clip.shape[0], clip.shape[1], clip.shape[2],
                        clip.shape[3]];
    let data = clip.f32s().unwrap();
    (0..t).map(|ti| {
        let (mut sx, mut sy, mut sw) = (0.0f64, 0.0f64, 0.0f64);
        for yi in 0..h {
            for xi in 0..w {
                let mut v = 0.0f32;
                for ci in 0..c {
                    v += data[((ti * h + yi) * w + xi) * c + ci];
                }
                let wgt = (v.max(0.0)) as f64; // energy above background
                sx += wgt * xi as f64;
                sy += wgt * yi as f64;
                sw += wgt;
            }
        }
        if sw > 1e-9 {
            ((sx / sw / w as f64) as f32, (sy / sw / h as f64) as f32)
        } else {
            (0.5, 0.5)
        }
    }).collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::json::Json;

    pub(crate) fn tiny_cfg() -> ModelConfig {
        let j = Json::parse(
            r#"{"video":[4,8,8,3],"patch":[2,2,2],"dim":64,"depth":2,
                "heads":2,"head_dim":32,"b_q":8,"b_k":4,"n_tokens":32,
                "t_m":4,"t_n":8,"num_classes":10,"param_count":0}"#,
        ).unwrap();
        ModelConfig::from_json("dit-tiny", &j).unwrap()
    }

    #[test]
    fn clip_shape_and_range() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(0);
        let clip = synthetic_clip(&cfg, 3, &mut rng);
        assert_eq!(clip.shape, vec![4, 8, 8, 3]);
        let d = clip.f32s().unwrap();
        assert!(d.iter().all(|v| (-0.6..=1.6).contains(v)));
        assert!(clip.max_abs().unwrap() > 0.1); // not all background
    }

    #[test]
    fn batch_shapes_and_labels() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(1);
        let (xs, ys) = synthetic_batch(&cfg, 3, &mut rng);
        assert_eq!(xs.shape, vec![3, 4, 8, 8, 3]);
        assert_eq!(ys.len(), 3);
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn blob_moves_over_time() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(2);
        let clip = synthetic_clip(&cfg, 2, &mut rng);
        let cents = frame_centroids(&clip);
        let (x0, y0) = cents[0];
        let (x3, y3) = cents[3];
        let dist = ((x3 - x0).powi(2) + (y3 - y0).powi(2)).sqrt();
        assert!(dist > 0.01, "centroid barely moved: {dist}");
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = tiny_cfg();
        let a = synthetic_clip(&cfg, 1, &mut Pcg32::seeded(7));
        let b = synthetic_clip(&cfg, 1, &mut Pcg32::seeded(7));
        assert_eq!(a, b);
    }
}
