//! Synthetic video workload + proxy quality metrics.
//!
//! Substitutes the paper's private 3k-video dataset and VBench /
//! VisionReward judges (DESIGN.md §2): [`synth`] generates
//! deterministic moving-blob clips (class label = motion direction),
//! and [`metrics`] scores generations on proxies that target the same
//! failure modes as the paper's quality columns.

pub mod metrics;
pub mod synth;

pub use metrics::QualityReport;
pub use synth::{synthetic_clip, synthetic_batch};
