//! Proxy quality metrics (the VBench / VisionReward substitution).
//!
//! Each proxy targets the failure mode of its Table 1 counterpart:
//!
//! | paper metric            | proxy here                                |
//! |-------------------------|-------------------------------------------|
//! | Imaging Quality (IQ)    | spatial sharpness (mean gradient energy)  |
//! | Aesthetic Quality (AQ)  | PSNR vs. the full-attention rollout       |
//! | Motion Smoothness (MS)  | inverse temporal jerk                     |
//! | Subject Consistency (SC)| frame-to-frame correlation                |
//! | Overall Consistency (OC)| SSIM (global) vs. full-attention rollout  |
//! | VisionReward (VR)       | attention-output relative error (negated) |
//!
//! Absolute values are NOT comparable to VBench scores; Table 1/2
//! claims are about *ordering across methods*, which these preserve.

use std::sync::Arc;

use crate::tensor::Tensor;
use crate::util::threadpool::shared_map;

#[derive(Debug, Clone)]
pub struct QualityReport {
    pub sharpness: f64,
    pub psnr_vs_ref: f64,
    pub ssim_vs_ref: f64,
    pub motion_smoothness: f64,
    pub subject_consistency: f64,
}

/// Below this many elements the thread-pool handoff costs more than
/// the frame pass itself; run serially.
const PARALLEL_THRESHOLD: usize = 4096;

/// A parallel job must also carry at least this much per-frame work,
/// or many-tiny-frame clips would fan out jobs whose channel/Arc
/// handoff dwarfs the pass itself.
const MIN_FRAME_ELEMS: usize = 256;

/// Fan `f(ti)` out over the process-wide shared pool
/// (`util::threadpool::shared_map`), one job per frame index; results
/// come back in frame order.  `f` must own (Arc) whatever slice data
/// it reads — the callers below wrap their clip copies.  The nested
/// fan-out prohibition and panic surfacing live with the shared
/// helper.
fn frame_map<R, F>(t: usize, f: F) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(usize) -> R + Send + Sync + 'static,
{
    shared_map(t, f)
}

/// Should a `t`-frame pass over `n` elements fan out?  Below the
/// thresholds the pool handoff costs more than the pass itself.
fn worth_parallelizing(t: usize, n: usize) -> bool {
    t >= 2 && n >= PARALLEL_THRESHOLD && n / t >= MIN_FRAME_ELEMS
}

/// Run `f(data, ti)` for every frame index, in parallel for clips big
/// enough to amortize the handoff.
///
/// The parallel path copies the clip once into an `Arc<[f32]>` (pool
/// jobs need `'static` data); callers doing several passes over one
/// clip pay that copy per pass — acceptable next to the O(n) passes
/// themselves, revisit if a profile says otherwise.
fn per_frame_pass<F>(t: usize, data: &[f32], f: F) -> Vec<f64>
where
    F: Fn(&[f32], usize) -> f64 + Send + Sync + 'static,
{
    if !worth_parallelizing(t, data.len()) {
        return (0..t).map(|ti| f(data, ti)).collect();
    }
    let shared: Arc<[f32]> = Arc::from(data);
    frame_map(t, move |ti| f(&shared, ti))
}

/// Mean spatial gradient magnitude (sharpness / imaging-quality proxy).
///
/// Flat slice pass (row-offset indexing, no per-element index
/// arithmetic), parallelized over frames.
pub fn sharpness(clip: &Tensor) -> f64 {
    let [t, h, w, c] = dims4(clip);
    let d = clip.f32s().unwrap();
    let frame = h * w * c;
    let row = w * c;
    let per_frame = per_frame_pass(t, d, move |all, ti| {
        let fr = &all[ti * frame..(ti + 1) * frame];
        let mut acc = 0.0f64;
        for yi in 0..h - 1 {
            let base = yi * row;
            for xi in 0..w - 1 {
                let p = base + xi * c;
                for ci in 0..c {
                    let v = fr[p + ci] as f64;
                    let gx = fr[p + c + ci] as f64 - v;
                    let gy = fr[p + row + ci] as f64 - v;
                    acc += (gx * gx + gy * gy).sqrt();
                }
            }
        }
        acc
    });
    let n = t * (h - 1) * (w - 1) * c;
    per_frame.iter().sum::<f64>() / n as f64
}

/// PSNR in dB against a reference clip (range taken as the reference's
/// dynamic range).
pub fn psnr(clip: &Tensor, reference: &Tensor) -> f64 {
    let mse = clip.mse(reference).unwrap();
    let r = reference.f32s().unwrap();
    let (lo, hi) = r.iter().fold((f32::MAX, f32::MIN),
                                 |(l, h), &v| (l.min(v), h.max(v)));
    let range = ((hi - lo) as f64).max(1e-6);
    if mse < 1e-20 {
        return 99.0;
    }
    10.0 * (range * range / mse).log10()
}

/// Global SSIM (single window over the whole clip — a coarse but
/// monotone structural-similarity proxy).
///
/// Big same-shape 4-D pairs run as two frame-parallel passes over the
/// shared pool (per-frame sums, then per-frame moments against the
/// global means — the same two-pass moment computation as the serial
/// path, chunked by frame); everything else takes the serial path.
pub fn ssim_global(a: &Tensor, b: &Tensor) -> f64 {
    let x = a.f32s().unwrap();
    let y = b.f32s().unwrap();
    let parallel = a.shape.len() == 4 && a.shape == b.shape
        && worth_parallelizing(a.shape[0], x.len());
    if !parallel {
        return ssim_serial(x, y);
    }
    let t = a.shape[0];
    let frame = x.len() / t;
    let n = x.len() as f64;
    let xs: Arc<[f32]> = Arc::from(x);
    let ys: Arc<[f32]> = Arc::from(y);
    let sums = {
        let (xs, ys) = (Arc::clone(&xs), Arc::clone(&ys));
        frame_map(t, move |ti| {
            let (mut sx, mut sy) = (0.0f64, 0.0f64);
            for j in ti * frame..(ti + 1) * frame {
                sx += xs[j] as f64;
                sy += ys[j] as f64;
            }
            (sx, sy)
        })
    };
    let mx = sums.iter().map(|s| s.0).sum::<f64>() / n;
    let my = sums.iter().map(|s| s.1).sum::<f64>() / n;
    let moments = frame_map(t, move |ti| {
        let (mut vx, mut vy, mut cov) = (0.0f64, 0.0f64, 0.0f64);
        for j in ti * frame..(ti + 1) * frame {
            let dx = xs[j] as f64 - mx;
            let dy = ys[j] as f64 - my;
            vx += dx * dx;
            vy += dy * dy;
            cov += dx * dy;
        }
        (vx, vy, cov)
    });
    let vx = moments.iter().map(|m| m.0).sum::<f64>() / n;
    let vy = moments.iter().map(|m| m.1).sum::<f64>() / n;
    let cov = moments.iter().map(|m| m.2).sum::<f64>() / n;
    ssim_formula(mx, my, vx, vy, cov)
}

/// The original single-threaded SSIM pass (also the parity oracle).
fn ssim_serial(x: &[f32], y: &[f32]) -> f64 {
    let n = x.len() as f64;
    let mx = x.iter().map(|v| *v as f64).sum::<f64>() / n;
    let my = y.iter().map(|v| *v as f64).sum::<f64>() / n;
    let (mut vx, mut vy, mut cov) = (0.0, 0.0, 0.0);
    for (xi, yi) in x.iter().zip(y) {
        let dx = *xi as f64 - mx;
        let dy = *yi as f64 - my;
        vx += dx * dx;
        vy += dy * dy;
        cov += dx * dy;
    }
    ssim_formula(mx, my, vx / n, vy / n, cov / n)
}

fn ssim_formula(mx: f64, my: f64, vx: f64, vy: f64, cov: f64) -> f64 {
    let (c1, c2) = (0.0001, 0.0009);
    ((2.0 * mx * my + c1) * (2.0 * cov + c2))
        / ((mx * mx + my * my + c1) * (vx + vy + c2))
}

/// Inverse temporal jerk: 1 / (1 + mean |x[t+1] - 2 x[t] + x[t-1]|).
/// Smooth motion (constant velocity) scores ~1; flicker scores low.
///
/// Flat slice pass parallelized over interior frames like sharpness /
/// subject_consistency; the boundary frames contribute nothing, so
/// their jobs return 0.  Accumulation order within each frame matches
/// the scalar reference; only the cross-frame association differs.
pub fn motion_smoothness(clip: &Tensor) -> f64 {
    let [t, h, w, c] = dims4(clip);
    if t < 3 {
        return 1.0;
    }
    let d = clip.f32s().unwrap();
    let frame = h * w * c;
    let per_frame = per_frame_pass(t, d, move |all, ti| {
        if ti == 0 || ti + 1 >= t {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for i in 0..frame {
            let jerk = all[(ti + 1) * frame + i] as f64
                - 2.0 * all[ti * frame + i] as f64
                + all[(ti - 1) * frame + i] as f64;
            acc += jerk.abs();
        }
        acc
    });
    let acc: f64 = per_frame.iter().sum();
    1.0 / (1.0 + acc / ((t - 2) * frame) as f64 * 10.0)
}

/// Mean correlation of every frame with frame 0 (subject persistence).
///
/// Flat slice pass parallelized over frames; frame-0 statistics are
/// computed once and captured by value.  Accumulation order within
/// each frame matches the scalar reference, so values are identical.
pub fn subject_consistency(clip: &Tensor) -> f64 {
    let [t, h, w, c] = dims4(clip);
    if t < 2 {
        return 1.0; // a single frame is trivially self-consistent
    }
    let d = clip.f32s().unwrap();
    let frame = h * w * c;
    let mut m0 = 0.0f64;
    for v in &d[..frame] {
        m0 += *v as f64;
    }
    m0 /= frame as f64;
    let mut s0 = 0.0f64;
    for v in &d[..frame] {
        let dv = *v as f64 - m0;
        s0 += dv * dv;
    }
    let s0 = s0.sqrt();
    let per_frame = per_frame_pass(t, d, move |all, ti| {
        if ti == 0 {
            return 0.0;
        }
        let f0 = &all[..frame];
        let ft = &all[ti * frame..(ti + 1) * frame];
        let mut mt = 0.0f64;
        for v in ft {
            mt += *v as f64;
        }
        mt /= frame as f64;
        let mut st = 0.0f64;
        let mut cov = 0.0f64;
        for j in 0..frame {
            let dt = ft[j] as f64 - mt;
            st += dt * dt;
            cov += (f0[j] as f64 - m0) * dt;
        }
        cov / (s0 * st.sqrt() + 1e-12)
    });
    per_frame[1..].iter().sum::<f64>() / (t - 1) as f64
}

/// Full report for a generated clip against its full-attention
/// reference rollout.
pub fn report(clip: &Tensor, reference: &Tensor) -> QualityReport {
    QualityReport {
        sharpness: sharpness(clip),
        psnr_vs_ref: psnr(clip, reference),
        ssim_vs_ref: ssim_global(clip, reference),
        motion_smoothness: motion_smoothness(clip),
        subject_consistency: subject_consistency(clip),
    }
}

fn dims4(t: &Tensor) -> [usize; 4] {
    assert_eq!(t.shape.len(), 4, "expected (T,H,W,C), got {:?}", t.shape);
    [t.shape[0], t.shape[1], t.shape[2], t.shape[3]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::video::synth::{synthetic_clip, tests::tiny_cfg};

    #[test]
    fn psnr_identity_is_high_and_noise_lowers_it() {
        let cfg = tiny_cfg();
        let clip = synthetic_clip(&cfg, 1, &mut Pcg32::seeded(0));
        assert!(psnr(&clip, &clip) > 90.0);
        let mut noisy = clip.clone();
        let mut rng = Pcg32::seeded(1);
        for v in noisy.f32s_mut().unwrap() {
            *v += 0.1 * rng.normal();
        }
        let p = psnr(&noisy, &clip);
        assert!(p > 5.0 && p < 40.0, "psnr {p}");
        let mut worse = clip.clone();
        let mut rng = Pcg32::seeded(2);
        for v in worse.f32s_mut().unwrap() {
            *v += 0.5 * rng.normal();
        }
        assert!(psnr(&worse, &clip) < p);
    }

    #[test]
    fn ssim_bounds() {
        let cfg = tiny_cfg();
        let a = synthetic_clip(&cfg, 1, &mut Pcg32::seeded(3));
        let b = synthetic_clip(&cfg, 6, &mut Pcg32::seeded(4));
        assert!((ssim_global(&a, &a) - 1.0).abs() < 1e-9);
        let cross = ssim_global(&a, &b);
        assert!(cross < 0.999, "distinct clips should not be identical");
    }

    #[test]
    fn smooth_motion_beats_flicker() {
        let cfg = tiny_cfg();
        let clip = synthetic_clip(&cfg, 2, &mut Pcg32::seeded(5));
        let smooth = motion_smoothness(&clip);
        let mut flicker = clip.clone();
        {
            let d = flicker.f32s_mut().unwrap();
            let frame = d.len() / 4;
            for (i, v) in d.iter_mut().enumerate() {
                if (i / frame) % 2 == 1 {
                    *v = -*v; // invert alternating frames
                }
            }
        }
        assert!(motion_smoothness(&flicker) < smooth);
    }

    #[test]
    fn subject_consistency_detects_subject_swap() {
        let cfg = tiny_cfg();
        let a = synthetic_clip(&cfg, 1, &mut Pcg32::seeded(6));
        let sc_same = subject_consistency(&a);
        // splice a different clip's frames into the tail
        let b = synthetic_clip(&cfg, 6, &mut Pcg32::seeded(7));
        let mut spliced = a.clone();
        {
            let frame = a.numel() / 4;
            let src = b.f32s().unwrap()[2 * frame..].to_vec();
            spliced.f32s_mut().unwrap()[2 * frame..]
                .copy_from_slice(&src);
        }
        assert!(subject_consistency(&spliced) < sc_same);
    }

    #[test]
    fn sharpness_prefers_structure_over_blur() {
        let cfg = tiny_cfg();
        let clip = synthetic_clip(&cfg, 3, &mut Pcg32::seeded(8));
        let flat = Tensor::zeros(&clip.shape);
        assert!(sharpness(&clip) > sharpness(&flat));
    }

    /// Verbatim pre-rewrite implementations: the parity oracle for the
    /// flat/parallel passes.
    mod reference {
        use crate::tensor::Tensor;
        use super::super::dims4;

        pub fn sharpness(clip: &Tensor) -> f64 {
            let [t, h, w, c] = dims4(clip);
            let d = clip.f32s().unwrap();
            let at = |ti: usize, yi: usize, xi: usize, ci: usize| {
                d[((ti * h + yi) * w + xi) * c + ci] as f64
            };
            let mut acc = 0.0;
            let mut n = 0usize;
            for ti in 0..t {
                for yi in 0..h - 1 {
                    for xi in 0..w - 1 {
                        for ci in 0..c {
                            let gx = at(ti, yi, xi + 1, ci)
                                - at(ti, yi, xi, ci);
                            let gy = at(ti, yi + 1, xi, ci)
                                - at(ti, yi, xi, ci);
                            acc += (gx * gx + gy * gy).sqrt();
                            n += 1;
                        }
                    }
                }
            }
            acc / n as f64
        }

        pub fn subject_consistency(clip: &Tensor) -> f64 {
            let [t, h, w, c] = dims4(clip);
            let d = clip.f32s().unwrap();
            let frame = h * w * c;
            let f0: Vec<f64> =
                d[..frame].iter().map(|v| *v as f64).collect();
            let m0 = f0.iter().sum::<f64>() / frame as f64;
            let s0: f64 = f0.iter()
                .map(|v| (v - m0) * (v - m0)).sum::<f64>().sqrt();
            let mut acc = 0.0;
            for ti in 1..t {
                let ft = &d[ti * frame..(ti + 1) * frame];
                let mt = ft.iter().map(|v| *v as f64).sum::<f64>()
                    / frame as f64;
                let st: f64 = ft.iter()
                    .map(|v| (*v as f64 - mt) * (*v as f64 - mt))
                    .sum::<f64>()
                    .sqrt();
                let cov: f64 = f0.iter().zip(ft)
                    .map(|(a, b)| (a - m0) * (*b as f64 - mt))
                    .sum();
                acc += cov / (s0 * st + 1e-12);
            }
            acc / (t - 1) as f64
        }

        pub fn motion_smoothness(clip: &Tensor) -> f64 {
            let [t, h, w, c] = dims4(clip);
            if t < 3 {
                return 1.0;
            }
            let d = clip.f32s().unwrap();
            let frame = h * w * c;
            let mut acc = 0.0;
            for ti in 1..t - 1 {
                for i in 0..frame {
                    let jerk = d[(ti + 1) * frame + i] as f64
                        - 2.0 * d[ti * frame + i] as f64
                        + d[(ti - 1) * frame + i] as f64;
                    acc += jerk.abs();
                }
            }
            1.0 / (1.0 + acc / ((t - 2) * frame) as f64 * 10.0)
        }

        pub fn ssim_global(a: &Tensor, b: &Tensor) -> f64 {
            let x = a.f32s().unwrap();
            let y = b.f32s().unwrap();
            let n = x.len() as f64;
            let mx = x.iter().map(|v| *v as f64).sum::<f64>() / n;
            let my = y.iter().map(|v| *v as f64).sum::<f64>() / n;
            let (mut vx, mut vy, mut cov) = (0.0, 0.0, 0.0);
            for (xi, yi) in x.iter().zip(y) {
                let dx = *xi as f64 - mx;
                let dy = *yi as f64 - my;
                vx += dx * dx;
                vy += dy * dy;
                cov += dx * dy;
            }
            vx /= n;
            vy /= n;
            cov /= n;
            let (c1, c2) = (0.0001, 0.0009);
            ((2.0 * mx * my + c1) * (2.0 * cov + c2))
                / ((mx * mx + my * my + c1) * (vx + vy + c2))
        }
    }

    fn assert_close(a: f64, b: f64, what: &str) {
        let tol = 1e-12 * b.abs().max(1.0);
        assert!((a - b).abs() <= tol, "{what}: {a} vs reference {b}");
    }

    #[test]
    fn rewritten_kernels_match_reference_serial_path() {
        // small clip: stays on the serial flat pass
        let cfg = tiny_cfg();
        for seed in 0..4u64 {
            let clip = synthetic_clip(&cfg, seed as usize,
                                      &mut Pcg32::seeded(20 + seed));
            assert_close(sharpness(&clip), reference::sharpness(&clip),
                         "sharpness");
            // identical accumulation order per frame: exact equality
            assert_eq!(subject_consistency(&clip),
                       reference::subject_consistency(&clip));
            // per-frame partials reassociate the cross-frame sum:
            // equal within reassociation error
            assert_close(motion_smoothness(&clip),
                         reference::motion_smoothness(&clip),
                         "motion_smoothness");
            let other = synthetic_clip(&cfg, 9, &mut Pcg32::seeded(40));
            // small pairs run the verbatim serial pass: exact equality
            assert_eq!(ssim_global(&clip, &other),
                       reference::ssim_global(&clip, &other));
        }
    }

    #[test]
    fn rewritten_kernels_match_reference_parallel_path() {
        // big enough to cross PARALLEL_THRESHOLD and fan out frames
        let clip = Tensor::randn(&[8, 16, 16, 3], &mut Pcg32::seeded(31));
        assert!(clip.numel() >= super::PARALLEL_THRESHOLD);
        assert_close(sharpness(&clip), reference::sharpness(&clip),
                     "sharpness");
        assert_eq!(subject_consistency(&clip),
                   reference::subject_consistency(&clip));
    }

    #[test]
    fn motion_smoothness_parallel_matches_reference() {
        // per-frame accumulation matches the scalar reference within
        // cross-frame summation reassociation error
        let clip = Tensor::randn(&[8, 16, 16, 3], &mut Pcg32::seeded(32));
        assert!(clip.numel() >= super::PARALLEL_THRESHOLD);
        let (got, want) = (motion_smoothness(&clip),
                           reference::motion_smoothness(&clip));
        let tol = 1e-9 * want.abs().max(1.0);
        assert!((got - want).abs() <= tol,
                "motion_smoothness: {got} vs reference {want}");
        // and a boundary-sized clip (t=3: single interior frame)
        let clip3 = Tensor::randn(&[3, 24, 24, 3],
                                  &mut Pcg32::seeded(33));
        let (g3, w3) = (motion_smoothness(&clip3),
                        reference::motion_smoothness(&clip3));
        assert!((g3 - w3).abs() <= 1e-9 * w3.abs().max(1.0),
                "motion_smoothness t=3: {g3} vs {w3}");
    }

    #[test]
    fn ssim_parallel_matches_reference() {
        let a = Tensor::randn(&[8, 16, 16, 3], &mut Pcg32::seeded(34));
        let mut b = a.clone();
        let mut rng = Pcg32::seeded(35);
        for v in b.f32s_mut().unwrap() {
            *v += 0.05 * rng.normal();
        }
        assert!(a.numel() >= super::PARALLEL_THRESHOLD);
        let (got, want) = (ssim_global(&a, &b),
                           reference::ssim_global(&a, &b));
        let tol = 1e-9 * want.abs().max(1.0);
        assert!((got - want).abs() <= tol,
                "ssim_global: {got} vs reference {want}");
        // identity still scores ~1 through the parallel path
        assert!((ssim_global(&a, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn report_is_complete() {
        let cfg = tiny_cfg();
        let clip = synthetic_clip(&cfg, 0, &mut Pcg32::seeded(9));
        let r = report(&clip, &clip);
        assert!(r.psnr_vs_ref > 90.0);
        assert!((r.ssim_vs_ref - 1.0).abs() < 1e-9);
        assert!(r.motion_smoothness > 0.0 && r.motion_smoothness <= 1.0);
    }
}
