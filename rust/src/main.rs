//! `sla2` — the leader binary: CLI over the serving + training stack.
//!
//! Subcommands:
//!   info                      list artifacts / configs / platform
//!   generate                  run one batched generation synchronously
//!   serve-demo                start the server, fire a request wave,
//!                             print latency/throughput metrics
//!   serve-net                 start the server with the TCP frontend
//!                             and keep serving until killed
//!   train                     two-stage SLA2 fine-tune (Alg. 1)
//!   costmodel                 print the paper-calibrated Fig.4/Fig.5
//!                             curves without touching PJRT

use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::Result;

use sla2::config::{ServeConfig, TrainConfig};
use sla2::coordinator::Server;
use sla2::costmodel::{device, e2e, flops};
use sla2::runtime::Runtime;
use sla2::trainer::Trainer;
use sla2::util::bench::Table;
use sla2::util::cli::Args;
use sla2::util::rng::Pcg32;

const USAGE: &str = "\
usage: sla2 <command> [--artifacts DIR] [--backend xla|native] [flags]

every serving command takes --backend: \"xla\" (default) replays the
AOT HLO artifacts through PJRT; \"native\" runs the pure-Rust SLA2
forward on the CPU — no artifacts needed (weights come from the
manifest when present, a seeded init otherwise).  The native backend
also takes --quant-mode int8|sim|off: \"int8\" (default) serves the
sla2 variant through real i8 x i8 -> i32 integer kernels, \"sim\" is
the f32 fake-quant simulation (parity/measurement baseline), \"off\"
disables quantization.  --kernel-isa auto|avx2|sse41|neon|scalar pins
the SIMD dispatch (default \"auto\" = runtime detection; \"scalar\" is
the portable reference); the SLA2_FORCE_SCALAR env var overrides
everything.  See docs/KERNELS.md.

fault tolerance (every serving command; docs/ARCHITECTURE.md):
  --default-deadline-ms N   per-request deadline when the client sets
                            none (0 = unlimited); expired requests get
                            a typed deadline_exceeded
  --shed-watermark F        shed above F x queue_capacity queued
                            requests with a typed `overloaded` +
                            retry_after_ms (1.0 = never shed)
  --work-watermark W        also shed when estimated queued work
                            (dense=1.0/request, sNN cheaper) exceeds W
                            (0 = off)
  --retry-budget N          requeues after a shard panic before the
                            request fails (default 2)
  --retry-backoff-ms B      base of the jittered exponential retry
                            backoff (default 20)
  --quarantine-failures K   K panics inside --quarantine-window-ms
                            quarantine a shard: it is routed around,
                            its backend rebuilt, and re-admitted after
                            --quarantine-cooldown-ms (K=0 disables)
  --fault-plan SPEC         deterministic fault injection, e.g.
                            \"panic:shard=1:nth=3,slow:ms=200:rate=0.1,\
drop-conn:rate=0.05,hang:shard=0:nth=2\" (see util::faults)
  --fault-seed S            RNG seed for the plan's rate draws

liveness (every serving command; docs/ARCHITECTURE.md):
  --stall-threshold-ms N    watchdog: a shard whose progress beat is
                            older than N ms is fenced, its batch
                            failed with retryable shard_stalled, and a
                            replacement worker spawned (0 = off)
  --drain-timeout-ms N      graceful-drain budget used by SIGTERM /
                            ctrl-c / the wire `drain` verb (default
                            5000)
  --net-send-queue N        per-connection bounded outbound frame
                            queue (default 64)
  --write-stall-ms N        a client that keeps its outbound queue
                            full this long is declared slow: its
                            streams are cancelled and the connection
                            dropped (default 2000)

transport (serve-net; docs/ARCHITECTURE.md wire spec):
  --net-workers N           reactor I/O threads multiplexing all
                            connections (default 4; threads are
                            O(workers), never O(connections))
  --auth-token TOK          require every connection to open with a
                            `hello` frame carrying TOK (constant-time
                            compare; empty = auth off)
  --rate-limit R            per-connection submit budget, submits/sec
                            (token bucket, burst max(1, R); rejected
                            submits get typed rate_limited +
                            retry_after_ms; 0 = unlimited)

commands:
  info          show manifest contents and runtime platform
  generate      --model dit-tiny --variant sla2 --tier s90 --steps 8
                --count 2 — generate clips synchronously
  serve-demo    --model dit-tiny --requests 6 --max-batch 2
                --num-shards N — run the sharded batching server
                against a synthetic request wave (default shards:
                cores - 1)
  serve-net     --listen-addr 127.0.0.1:7341 --chunk-frames 1
                --duration-s 0 — serve the wire protocol (v0 JSON /
                v1 binary, negotiated per connection: submit /
                streaming chunks / cancel / metrics); talk to it with
                the sla2-stream-client binary.  duration 0 = run
                until killed
  train         --model dit-tiny --tier s90 --stage1-steps 20
                --stage2-steps 60 — two-stage fine-tune (Alg. 1)
  costmodel     print paper-calibrated kernel/e2e curves (no PJRT)
";

fn main() -> Result<()> {
    let args = Args::parse();
    let artifacts = args.str("artifacts", "artifacts");
    match args.subcommand() {
        Some("info") => info(&artifacts),
        Some("generate") => generate(&artifacts, &args),
        Some("serve-demo") => serve_demo(&artifacts, &args),
        Some("serve-net") => serve_net(&artifacts, &args),
        Some("train") => train(&artifacts, &args),
        Some("costmodel") => {
            costmodel_report();
            Ok(())
        }
        Some("perf") => perf(&artifacts, &args),
        Some("loadtest") => loadtest(&artifacts, &args),
        _ => {
            eprint!("{USAGE}");
            Ok(())
        }
    }
}

fn info(artifacts: &str) -> Result<()> {
    let rt = Runtime::load(artifacts)?;
    println!("platform: {}", rt.platform());
    let m = rt.manifest();
    println!("configs:");
    for (name, c) in &m.configs {
        println!("  {name}: {:.1}M params, N={}, {}x{} blocks, video {:?}",
                 c.param_count as f64 / 1e6, c.n_tokens, c.t_m, c.t_n,
                 c.video);
    }
    println!("artifacts ({}):", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!("  {:<42} {:<12} in={:<3} out={}", name, a.kind,
                 a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

fn generate(artifacts: &str, args: &Args) -> Result<()> {
    let serve = ServeConfig::from_args(args);
    let count = args.usize("count", 2);
    let server = Server::start(artifacts, serve.clone())?;
    println!("generating {count} clips (model={}, variant={}, tier={}, \
              steps={})", serve.model, serve.variant, serve.tier,
             serve.sample_steps);
    let rxs: Vec<_> = (0..count)
        .map(|i| server.submit_default(i as i32 % 10, 1000 + i as u64))
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()??;
        println!("  clip {i}: shape {:?}, compute {:.1} ms (batch {})",
                 resp.clip.shape, resp.metrics.compute_ms,
                 resp.metrics.batch_size);
    }
    println!("{}", server.metrics_snapshot());
    server.shutdown();
    Ok(())
}

fn serve_demo(artifacts: &str, args: &Args) -> Result<()> {
    let serve = ServeConfig::from_args(args);
    let n = args.usize("requests", 6);
    let server = Server::start(artifacts, serve)?;
    let mut rng = Pcg32::seeded(7);
    let rxs: Vec<_> = (0..n)
        .filter_map(|i| {
            server.submit_default(rng.below(10) as i32, i as u64).ok()
        })
        .collect();
    println!("accepted {} / {n} requests", rxs.len());
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    println!("completed {ok}");
    println!("{}", server.metrics_snapshot());
    server.shutdown();
    Ok(())
}

/// Process shutdown latch: set by SIGINT/SIGTERM, polled by the
/// serve-net loop.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Install SIGINT/SIGTERM handlers that flip [`SHUTDOWN`].  The crate
/// deliberately carries no libc dependency, so this binds the classic
/// `signal(2)` entry point directly — a store to a static atomic is
/// async-signal-safe, and the serve loop does the actual work.
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::Relaxed);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Network serving: bind the TCP frontend and serve until SIGTERM /
/// ctrl-c / a wire `drain` verb / `--duration-s`, then drain
/// gracefully and exit.
/// `sla2 serve-net --listen-addr 127.0.0.1:7341 --model dit-tiny`
fn serve_net(artifacts: &str, args: &Args) -> Result<()> {
    let mut serve = ServeConfig::from_args(args);
    if serve.listen_addr.is_empty() {
        serve.listen_addr = "127.0.0.1:7341".into();
    }
    let server = Server::start(artifacts, serve)?;
    let addr = server.local_addr().expect("listener configured above");
    println!("serving on {addr} — try:");
    println!("  cargo run --release --bin sla2-stream-client -- \
              --addr {addr} --steps 4");
    install_signal_handlers();
    let duration_s = args.u64("duration-s", 0);
    let deadline = (duration_s > 0).then(|| {
        std::time::Instant::now()
            + std::time::Duration::from_secs(duration_s)
    });
    loop {
        if SHUTDOWN.load(Ordering::Relaxed) {
            println!("signal received; draining");
            break;
        }
        if server.is_draining() {
            // a client sent the `drain` verb: finish the job locally
            println!("drain requested over the wire");
            break;
        }
        if deadline.is_some_and(|d| std::time::Instant::now() >= d) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    if server.drain() {
        println!("drain complete");
    } else {
        println!("drain timed out with work still in flight");
    }
    println!("{}", server.metrics_snapshot());
    server.shutdown();
    Ok(())
}

fn train(artifacts: &str, args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args);
    let trainer = Trainer::new(artifacts, cfg.clone())?;
    let mut state = trainer.init_state()?;
    if cfg.stage1_steps > 0 {
        println!("== Stage 1: router + alpha init ({} steps) ==",
                 cfg.stage1_steps);
        trainer.run_stage1(&mut state, cfg.stage1_steps,
                           |i, l| println!("  stage1[{i:>4}] loss {l:.6}"))?;
        println!("mean alpha after stage 1: {:.3}",
                 trainer.mean_alpha(&state)?);
    } else {
        println!("(stage 1 skipped)");
    }
    println!("== Stage 2: end-to-end fine-tune ({} steps) ==",
             cfg.stage2_steps);
    trainer.run_stage2(&mut state, cfg.stage2_steps,
                       |i, l| println!("  stage2[{i:>4}] loss {l:.6}"))?;
    Ok(())
}

/// Open-loop Poisson load test against the serving stack:
/// `sla2 loadtest --model dit-tiny --rps 6 --requests 24 --steps 2
///  [--deadline-ms 500] [--allow-degrade true] [--shed-watermark 0.5]`
fn loadtest(artifacts: &str, args: &Args) -> Result<()> {
    use sla2::coordinator::{run_trace, TraceConfig};
    let serve = ServeConfig::from_args(args);
    let trace = TraceConfig {
        rps: args.f64("rps", 4.0),
        n_requests: args.usize("requests", 16),
        tiers: vec![serve.tier.clone()],
        steps: args.usize("steps", serve.sample_steps),
        seed: args.u64("seed", 17),
        deadline_ms: args.u64("deadline-ms", 0),
        allow_degrade: args.bool("allow-degrade", false),
    };
    println!("load test: {} requests at {} rps (Poisson), model {}, \
              tier {}, {} steps, max_batch {}",
             trace.n_requests, trace.rps, serve.model, serve.tier,
             trace.steps, serve.max_batch);
    let server = Server::start(artifacts, serve)?;
    // warm the executable so the trace measures steady state
    let _ = server.submit(0, 1, trace.steps, &trace.tiers[0])
        .map_err(|e| anyhow::anyhow!("{e}"))?.recv()??;
    let report = run_trace(&server, &trace)?;
    println!("{}", report.to_json());
    println!("server: {}", server.metrics_snapshot());
    server.shutdown();
    Ok(())
}

/// L3 overhead measurement (EXPERIMENTS.md §Perf): per-request latency
/// through the full coordinator (queue -> batcher -> engine -> euler)
/// vs the bare HLO execution it wraps, at 1 sampling step so the
/// coordinator's fixed costs are maximally visible.
fn perf(artifacts: &str, args: &Args) -> Result<()> {
    use sla2::runtime::{tensor_to_literal, Runtime};
    use sla2::tensor::Tensor;
    let model = args.str("model", "dit-tiny");
    let tier = args.str("tier", "s90");
    let n = args.usize("iters", 50);

    // --- bare HLO call (params pre-converted, like the engine) -------
    let rt = Runtime::load(artifacts)?;
    let cfg = rt.manifest().config(&model)?.clone();
    let params: Vec<xla::Literal> = rt.manifest().load_params(&model)?
        .iter().map(|t| tensor_to_literal(t).unwrap()).collect();
    let artifact = format!("denoise_{model}_sla2_{tier}_b1");
    let mut rng = Pcg32::seeded(1);
    let x = Tensor::randn(&[1, cfg.video[0], cfg.video[1], cfg.video[2],
                            cfg.video[3]], &mut rng);
    let rest = [tensor_to_literal(&x)?,
                tensor_to_literal(&Tensor::from_f32(&[1], vec![0.5])?)?,
                tensor_to_literal(&Tensor::from_i32(&[1], vec![1])?)?];
    rt.execute_literals_with_prefix(&artifact, &params, &rest)?; // warm
    let b = sla2::util::bench::run(&artifact, 3, n, || {
        rt.execute_literals_with_prefix(&artifact, &params, &rest)
            .unwrap();
    });
    println!("bare HLO denoise call: mean {:.3} ms (p99 {:.3})",
             b.mean_ms(), b.summary.p99 * 1e3);
    drop(rt);

    // --- through the full coordinator at steps=1 ---------------------
    let serve = ServeConfig {
        model: model.clone(), variant: "sla2".into(), tier: tier.clone(),
        sample_steps: 1, max_batch: 1, batch_window_ms: 0,
        queue_capacity: 8, num_shards: 1,
        ..ServeConfig::default()
    };
    let server = Server::start(artifacts, serve)?;
    let _ = server.submit(1, 7, 1, &tier).unwrap().recv()??; // warm
    let mut samples = Vec::with_capacity(n);
    for i in 0..n {
        let t0 = std::time::Instant::now();
        let _ = server.submit(1, 7 + i as u64, 1, &tier)
            .map_err(|e| anyhow::anyhow!("{e}"))?.recv()??;
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = sla2::util::stats::Summary::of(&samples);
    println!("through coordinator (1 step): mean {:.3} ms (p99 {:.3})",
             s.mean * 1e3, s.p99 * 1e3);
    let overhead = s.mean * 1e3 - b.mean_ms();
    println!("L3 overhead: {:.3} ms/request = {:.1}% of a single \
              denoise step", overhead, 100.0 * overhead / b.mean_ms());
    server.shutdown();
    Ok(())
}

fn costmodel_report() {
    let dev = device::Device::rtx5090();
    println!("== Fig. 4: kernel speed (paper-calibrated model) ==");
    let mut t = Table::new(&["method", "sparsity", "time (us)",
                             "eff. TOPS", "speedup vs FA2"]);
    let g = |keep| flops::AttnGeometry { keep, ..flops::FIG4_GEOM };
    let fa2 = device::kernel_time_default(&dev, flops::AttnKind::Full,
                                          &g(1.0));
    {
        let mut row = |name: &str, kt: device::KernelTime, sp: f64| {
            t.row(vec![name.into(), format!("{:.0}%", sp * 100.0),
                       format!("{:.1}", kt.seconds * 1e6),
                       format!("{:.0}", kt.effective_tops),
                       format!("{:.1}x", fa2.seconds / kt.seconds)]);
        };
        row("FlashAttn2", fa2, 0.0);
        for (tier, keep) in [("90", 0.10), ("95", 0.05), ("97", 0.03)] {
            let kt = device::kernel_time_default(
                &dev, flops::AttnKind::Sla2 { quant: true }, &g(keep));
            row(&format!("SLA2 @{tier}%"), kt, 1.0 - keep);
        }
        let vsa = device::kernel_time_default(
            &dev, flops::AttnKind::SparseOnly, &g(0.05));
        row("VSA @95%", vsa, 0.95);
        let vmoba = device::kernel_time(&dev, flops::AttnKind::SparseOnly,
                                        &g(0.05), device::vmoba_profile());
        row("VMoBA @95%", vmoba, 0.95);
    }
    t.print();

    println!("== Fig. 5: end-to-end latency (50 steps) ==");
    let mut t = Table::new(&["model", "method", "attn (s)", "other (s)",
                             "total (s)", "speedup"]);
    for model in [&flops::WAN_1_3B, &flops::WAN_14B] {
        let full = e2e::estimate(&dev, model, flops::AttnKind::Full, 1.0,
                                 50, false);
        let sla2 = e2e::estimate(&dev, model,
                                 flops::AttnKind::Sla2 { quant: true },
                                 0.03, 50, false);
        for (name, e) in [("Full", &full), ("SLA2 @97%", &sla2)] {
            t.row(vec![model.name.into(), name.into(),
                       format!("{:.1}", e.attention_s),
                       format!("{:.1}", e.other_s),
                       format!("{:.1}", e.total_s()),
                       format!("{:.2}x", full.total_s() / e.total_s())]);
        }
    }
    t.print();
}
