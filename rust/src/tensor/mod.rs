//! Host tensor type bridging Rust data and XLA literals.
//!
//! Deliberately small: shape + flat data (f32 or i32), row-major.  All
//! heavy math runs inside the compiled HLO; host-side ops are limited
//! to what the coordinator needs (noise generation, metric reductions,
//! batch assembly).

use anyhow::{bail, Result};

use crate::util::rng::Pcg32;

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    // ---- constructors --------------------------------------------------

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(),
                 data: Data::F32(vec![0.0; shape.iter().product()]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", shape, n,
                  data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data: Data::F32(data) })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", shape, n,
                  data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data: Data::I32(data) })
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor { shape: vec![], data: Data::I32(vec![v]) }
    }

    /// Standard-normal tensor (noise latents, synthetic QKV, ...).
    pub fn randn(shape: &[usize], rng: &mut Pcg32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Data::F32(rng.normal_vec(n)) }
    }

    // ---- accessors -----------------------------------------------------

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, Data::F32(_))
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self.data {
            Data::F32(_) => "float32",
            Data::I32(_) => "int32",
        }
    }

    // ---- host-side ops -------------------------------------------------

    pub fn reshaped(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.numel() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Stack same-shaped tensors along a new axis 0 (batch assembly).
    pub fn stack(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty"))?;
        let mut data = Vec::with_capacity(first.numel() * parts.len());
        for p in parts {
            if p.shape != first.shape {
                bail!("stack shape mismatch {:?} vs {:?}", p.shape,
                      first.shape);
            }
            data.extend_from_slice(p.f32s()?);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&first.shape);
        Tensor::from_f32(&shape, data)
    }

    /// Split axis 0 back into per-sample tensors (batch disassembly).
    pub fn unstack(&self) -> Result<Vec<Tensor>> {
        let b = *self.shape.first()
            .ok_or_else(|| anyhow::anyhow!("unstack scalar"))?;
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let stride: usize = inner.iter().product();
        let data = self.f32s()?;
        (0..b)
            .map(|i| Tensor::from_f32(
                &inner, data[i * stride..(i + 1) * stride].to_vec()))
            .collect()
    }

    pub fn mse(&self, other: &Tensor) -> Result<f64> {
        let a = self.f32s()?;
        let b = other.f32s()?;
        if a.len() != b.len() {
            bail!("mse length mismatch");
        }
        Ok(a.iter().zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>() / a.len() as f64)
    }

    /// Frobenius relative error — mirrors ref.attention_relative_error.
    pub fn rel_err(&self, reference: &Tensor) -> Result<f64> {
        let a = self.f32s()?;
        let b = reference.f32s()?;
        let num: f64 = a.iter().zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
        let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum::<f64>();
        Ok((num.sqrt()) / (den.sqrt() + 1e-9))
    }

    pub fn mean(&self) -> Result<f64> {
        let a = self.f32s()?;
        Ok(a.iter().map(|x| *x as f64).sum::<f64>() / a.len().max(1) as f64)
    }

    pub fn max_abs(&self) -> Result<f64> {
        Ok(self.f32s()?.iter().fold(0.0f64, |m, x| m.max(x.abs() as f64)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape_check() {
        assert!(Tensor::from_f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_f32(&[2, 3], vec![0.0; 5]).is_err());
        let t = Tensor::zeros(&[4, 5]);
        assert_eq!(t.numel(), 20);
        assert_eq!(t.dtype_str(), "float32");
    }

    #[test]
    fn i32_roundtrip() {
        let t = Tensor::from_i32(&[3], vec![1, -2, 3]).unwrap();
        assert_eq!(t.i32s().unwrap(), &[1, -2, 3]);
        assert!(t.f32s().is_err());
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_f32(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        let s = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(s.shape, vec![2, 2, 2]);
        let parts = s.unstack().unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn stack_rejects_mismatch() {
        let a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        assert!(Tensor::stack(&[&a, &b]).is_err());
    }

    #[test]
    fn metrics() {
        let a = Tensor::from_f32(&[4], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_f32(&[4], vec![1., 2., 3., 5.]).unwrap();
        assert!((a.mse(&b).unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(a.mse(&a).unwrap(), 0.0);
        assert!(a.rel_err(&a).unwrap() < 1e-9);
        assert!((a.mean().unwrap() - 2.5).abs() < 1e-12);
        assert_eq!(a.max_abs().unwrap(), 4.0);
    }

    #[test]
    fn randn_is_deterministic_per_seed() {
        let mut r1 = Pcg32::seeded(9);
        let mut r2 = Pcg32::seeded(9);
        assert_eq!(Tensor::randn(&[8], &mut r1), Tensor::randn(&[8], &mut r2));
    }

    #[test]
    fn reshape() {
        let t = Tensor::zeros(&[2, 6]).reshaped(&[3, 4]).unwrap();
        assert_eq!(t.shape, vec![3, 4]);
        assert!(Tensor::zeros(&[2, 6]).reshaped(&[5]).is_err());
    }
}
