//! # SLA2 — Sparse-Linear Attention with Learnable Routing and QAT
//!
//! Three-layer reproduction of the SLA2 paper (Zhang et al., 2026):
//!
//! * **L1** — Pallas attention kernels (Alg. 2/3), authored in
//!   `python/compile/kernels/` and AOT-lowered to HLO text;
//! * **L2** — a video Diffusion Transformer + two-stage training
//!   pipeline (`python/compile/`), also AOT-lowered;
//! * **L3** — this crate: the Rust coordinator that loads the HLO
//!   artifacts through PJRT (`xla` crate) and owns serving (request
//!   routing, dynamic batching, the diffusion sampling loop) and
//!   training (the Alg. 1 two-stage driver).  Python never runs on the
//!   request path.
//!
//! The crate is dependency-light by necessity (offline build): JSON,
//! RNG, CLI, statistics, thread pool, property testing and the bench
//! harness are first-party substrates under [`util`].
//!
//! ```no_run
//! use sla2::runtime::Runtime;
//! let rt = Runtime::load("artifacts").unwrap();
//! let exe = rt.executable("denoise_dit-tiny_sla2_s90_b1").unwrap();
//! ```

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod diffusion;
pub mod runtime;
pub mod tensor;
pub mod trainer;
pub mod util;
pub mod video;

pub use config::ModelConfig;
pub use tensor::Tensor;
