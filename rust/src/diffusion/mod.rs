//! Rust half of the rectified-flow diffusion substrate.
//!
//! The exported HLO only evaluates the velocity at ONE timestep; the
//! coordinator owns the sampling loop, so the sigma schedule and Euler
//! integrator are mirrored here (the python source of truth is
//! `python/compile/diffusion.py`).

use crate::tensor::Tensor;

/// The t-grid a sampler walks: 1.0 -> 0.0 inclusive, `steps` intervals.
pub fn timestep_grid(steps: usize) -> Vec<f32> {
    assert!(steps > 0);
    (0..=steps)
        .map(|i| 1.0 - i as f32 / steps as f32)
        .collect()
}

/// One Euler step of `dx/dt = v` from `t` down to `t_next` (in place).
pub fn euler_step(x: &mut Tensor, vel: &Tensor, t: f32, t_next: f32) {
    let dt = t_next - t;
    let xs = x.f32s_mut().expect("latent is f32");
    let vs = vel.f32s().expect("velocity is f32");
    assert_eq!(xs.len(), vs.len(), "euler step shape mismatch");
    for (xi, vi) in xs.iter_mut().zip(vs) {
        *xi += dt * vi;
    }
}

/// Rectified-flow forward process: `x_t = (1 - t) x0 + t eps`.
pub fn noise_to(x0: &Tensor, eps: &Tensor, t: f32) -> Tensor {
    let a = x0.f32s().expect("x0 f32");
    let b = eps.f32s().expect("eps f32");
    let data = a.iter().zip(b).map(|(x, e)| (1.0 - t) * x + t * e).collect();
    Tensor::from_f32(&x0.shape, data).unwrap()
}

/// Exact-velocity sanity target: `v = eps - x0`.
pub fn velocity_target(x0: &Tensor, eps: &Tensor) -> Tensor {
    let a = x0.f32s().unwrap();
    let b = eps.f32s().unwrap();
    let data = a.iter().zip(b).map(|(x, e)| e - x).collect();
    Tensor::from_f32(&x0.shape, data).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn grid_endpoints_and_monotone() {
        let g = timestep_grid(8);
        assert_eq!(g.len(), 9);
        assert_eq!(g[0], 1.0);
        assert_eq!(*g.last().unwrap(), 0.0);
        assert!(g.windows(2).all(|w| w[1] < w[0]));
    }

    #[test]
    fn euler_exact_on_linear_flow() {
        // with the exact velocity, one step from eps at t=1 lands on x0
        let mut rng = Pcg32::seeded(1);
        let x0 = Tensor::randn(&[4, 4], &mut rng);
        let eps = Tensor::randn(&[4, 4], &mut rng);
        let v = velocity_target(&x0, &eps);
        let mut x = eps.clone();
        euler_step(&mut x, &v, 1.0, 0.0);
        assert!(x.rel_err(&x0).unwrap() < 1e-6);
    }

    #[test]
    fn multi_step_euler_also_exact_for_linear_flow() {
        let mut rng = Pcg32::seeded(2);
        let x0 = Tensor::randn(&[8], &mut rng);
        let eps = Tensor::randn(&[8], &mut rng);
        let v = velocity_target(&x0, &eps);
        let mut x = eps.clone();
        let grid = timestep_grid(10);
        for w in grid.windows(2) {
            euler_step(&mut x, &v, w[0], w[1]);
        }
        assert!(x.rel_err(&x0).unwrap() < 1e-5);
    }

    #[test]
    fn noise_endpoints() {
        let x0 = Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap();
        let eps = Tensor::from_f32(&[2], vec![-1.0, 0.5]).unwrap();
        assert_eq!(noise_to(&x0, &eps, 0.0), x0);
        assert_eq!(noise_to(&x0, &eps, 1.0), eps);
    }
}
