//! `sla2-stream-client` — reference client for the SLA2 wire
//! protocol (`sla2 serve-net`), speaking either the debug-readable
//! JSON v0 or the binary v1 codec (`--wire v0|v1`, default v1).
//!
//! Submits one streaming generation, prints every chunk as it
//! arrives (with its frame range and time-since-submit), reassembles
//! the clip, then re-submits the same seed one-shot and verifies the
//! two clips are byte-identical — the end-to-end proof that chunked
//! delivery loses nothing.
//!
//! ```bash
//! cargo run --release -- serve-net --listen-addr 127.0.0.1:7341 &
//! cargo run --release --bin sla2-stream-client -- \
//!     --addr 127.0.0.1:7341 --class 3 --seed 42 --steps 4 --tier s90
//! ```
//!
//! Transport flags: `--wire v0|v1` selects the codec, `--auth-token
//! TOK` opens the connection with a `hello` frame carrying TOK (for
//! servers started with `--auth-token`), `--compress` asks the
//! server to zrle-compress v1 tensor payloads.

use std::time::Instant;

use anyhow::Result;
use sla2::coordinator::net::ClientOpts;
use sla2::coordinator::{NetClient, WireFormat};
use sla2::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let addr = args.str("addr", "127.0.0.1:7341");
    let class = args.usize("class", 3) as i32;
    let seed = args.u64("seed", 42);
    let steps = args.usize("steps", 4);
    let tier = args.str("tier", "s90");
    let wire = WireFormat::parse(&args.str("wire", "v1"))?;
    let token = args.str("auth-token", "");
    let compress = args.bool("compress", false);

    println!("connecting to {addr} ({}) ...", wire.as_str());
    let opts = ClientOpts {
        wire,
        token: if token.is_empty() { None } else { Some(token) },
        compress,
    };
    let mut client = NetClient::connect_with(&addr, opts)?;

    // --- streaming submit -------------------------------------------
    let t0 = Instant::now();
    let id = client.submit(class, seed, steps, &tier, true)?;
    println!("stream {id} accepted (class={class} seed={seed} \
              steps={steps} tier={tier})");
    let mut chunks = 0usize;
    let streamed = client.collect_stream_with(id, |c| {
        chunks += 1;
        println!("  chunk {:>2}: frames [{:>2}, {:>2}) of {} | \
                  +{:>7.1} ms{}",
                 c.seq, c.frame_start, c.frame_end, c.total_frames,
                 t0.elapsed().as_secs_f64() * 1e3,
                 if c.last { " (last)" } else { "" });
    })?;
    let stream_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("stream complete: {} chunks, clip {:?}, {:.1} ms \
              end-to-end (compute {:.1} ms, batch {})",
             chunks, streamed.clip.shape, stream_ms,
             streamed.metrics.compute_ms, streamed.metrics.batch_size);

    // --- one-shot with the same seed: must match bit-for-bit --------
    let oneshot_id = client.submit(class, seed, steps, &tier, false)?;
    let oneshot = client.collect_clip(oneshot_id)?;
    if oneshot.clip == streamed.clip {
        println!("one-shot resubmit matches the reassembled stream \
                  byte-for-byte ✓");
    } else {
        anyhow::bail!("MISMATCH: reassembled stream differs from the \
                       one-shot clip for seed {seed}");
    }

    // --- server-side streaming metrics ------------------------------
    let snap = client.metrics_snapshot()?;
    if let Some(streaming) = snap.get("streaming") {
        println!("server streaming metrics: {streaming}");
    }
    Ok(())
}
