//! Leveled, timestamped stderr logging (no `log`/`env_logger` offline).
//!
//! Level comes from `SLA2_LOG` (error|warn|info|debug|trace), default
//! `info`.  Macros mirror the `log` crate's so call sites read normally.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: Lazy<Instant> = Lazy::new(Instant::now);

fn init_level() -> u8 {
    let lvl = match std::env::var("SLA2_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl as u8
}

pub fn enabled(level: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == u8::MAX { init_level() } else { cur };
    (level as u8) <= cur
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>9.3}s {tag} {target}] {msg}", t.as_secs_f64());
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
