//! Minimal-but-complete JSON parser/writer (RFC 8259 subset we emit).
//!
//! Used for `artifacts/manifest.json`, run configs and bench reports.
//! Object key order is preserved (insertion order) so emitted files
//! diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1,2,3]` for shape lists.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- builders -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), val.into()));
        }
        self
    }

    /// [`Json::push`] only when `val` is `Some` — for fields that
    /// should be absent (not null) when there is nothing to report.
    pub fn push_opt(self, key: &str,
                    val: Option<impl Into<Json>>) -> Json {
        match val {
            Some(v) => self.push(key, v),
            None => self,
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<&[usize]> for Json {
    fn from(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::from(*x)).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(v: BTreeMap<String, Json>) -> Json {
        Json::Obj(v.into_iter().collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(kv)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char).to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multibyte utf-8 from the source slice
                    let start = self.pos - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    if let Ok(chunk) = std::str::from_utf8(&self.b[start..end])
                    {
                        s.push_str(chunk);
                    }
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(kv) => {
            out.push('{');
            for (i, (k, x)) in kv.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#)
            .unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(),
                   Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-3}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integers_stay_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn builder() {
        let j = Json::obj().push("a", 1usize).push("b", "x");
        assert_eq!(j.to_string(), r#"{"a":1,"b":"x"}"#);
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![2, 3, 4]));
        assert_eq!(Json::parse("[2, -1]").unwrap().as_usize_vec(), None);
    }
}
