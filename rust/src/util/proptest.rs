//! proptest-lite: randomized property testing with failure reporting.
//!
//! The real `proptest` crate is not in the offline registry; this
//! substrate covers what the coordinator-invariant tests need:
//! deterministic case generation from a seed, N cases per property,
//! and a panic message that pins down the failing seed + case index so
//! a failure is reproducible with `check_seeded`.

use super::rng::Pcg32;

pub const DEFAULT_CASES: usize = 128;

/// Run `prop` on `cases` generated inputs; panic with the seed and case
/// index on the first failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check_seeded(name, 0x5eed_cafe, cases, &mut gen, &mut prop);
}

pub fn check_seeded<T, G, P>(name: &str, seed: u64, cases: usize,
                             gen: &mut G, prop: &mut P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Pcg32::new(seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse-involution", 64,
              |r| (0..r.below(20)).map(|_| r.next_u32()).collect::<Vec<_>>(),
              |v| {
                  let mut w = v.clone();
                  w.reverse();
                  w.reverse();
                  if w == *v { Ok(()) } else { Err("mismatch".into()) }
              });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn failing_property_panics_with_context() {
        check("always-fails", 8, |r| r.next_u32(), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_generation() {
        let mut a = Vec::new();
        check("collect-a", 4, |r| r.next_u32(), |x| {
            a.push(*x);
            Ok(())
        });
        let mut b = Vec::new();
        check("collect-b", 4, |r| r.next_u32(), |x| {
            b.push(*x);
            Ok(())
        });
        assert_eq!(a, b);
    }
}
