//! Fixed-size thread pool over std mpsc (tokio is not in the offline
//! registry; the coordinator's event loop is thread + channel based),
//! plus the process-wide [`shared_map`] fan-out helper that the video
//! metric passes and the native compute backend both build on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use once_cell::sync::Lazy;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    submitted: AtomicUsize,
}

/// Decrements the pending count on drop, so a panicking job can never
/// leak a pending slot and deadlock `wait_idle()`.
struct PendingGuard<'a>(&'a (Mutex<usize>, Condvar));

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cv) = self.0;
        let mut n = lock.lock().unwrap();
        *n -= 1;
        cv.notify_all();
    }
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("sla2-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let _guard = PendingGuard(&pending);
                                // contain panics: the worker survives
                                // and the guard still decrements
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job));
                                if r.is_err() {
                                    crate::warn_!(
                                        "thread-pool job panicked");
                                }
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending,
                     submitted: AtomicUsize::new(0) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// ONE process-wide pool for data-parallel fan-outs (metric frame
/// passes, native denoise batches/heads) — `Mutex`-wrapped because
/// `ThreadPool` holds an mpsc sender (`!Sync`); the lock is only held
/// while enqueueing jobs, never while they run.
static SHARED_POOL: Lazy<Mutex<ThreadPool>> =
    Lazy::new(|| Mutex::new(ThreadPool::new(shared_pool_width())));

/// Worker count of [`shared_map`]'s pool (also a sizing hint for
/// callers deciding whether fanning out is worth it — e.g. the native
/// model fans query-block chunks WITHIN each head when there are
/// fewer heads than workers, instead of one job per head; see
/// `runtime::native::model::denoise_forward`).
pub fn shared_pool_width() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

/// Fan `f(i)` for `i in 0..count` out over the shared pool; results
/// come back in index order, so reductions over them are
/// deterministic regardless of completion order.  `f` must own (Arc)
/// whatever it reads — jobs are `'static`.
///
/// Do NOT call from a job already running on this pool: the caller
/// blocks on the result channel, and nested fan-out can occupy every
/// worker with blocked parents (classic pool deadlock).  A panicking
/// job is surfaced as a panic here, not a silently missing result.
pub fn shared_map<R, F>(count: usize, f: F) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(usize) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (tx, rx) = channel::<(usize, R)>();
    {
        let pool = SHARED_POOL.lock().unwrap();
        for i in 0..count {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            pool.submit(move || {
                let v = (*f)(i);
                let _ = tx.send((i, v));
            });
        }
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..count).map(|_| None).collect();
    let mut received = 0usize;
    for (i, v) in rx {
        out[i] = Some(v);
        received += 1;
    }
    assert_eq!(received, count,
               "shared fan-out lost {} result(s) — a job panicked",
               count - received);
    out.into_iter().map(|o| o.expect("indexed result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
        assert_eq!(pool.submitted(), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn panicking_job_neither_deadlocks_nor_kills_the_pool() {
        let pool = ThreadPool::new(2);
        let sum = Arc::new(AtomicU64::new(0));
        pool.submit(|| panic!("boom"));
        for i in 1..=10u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        // regression: before the drop-guard, the panicking job skipped
        // the pending decrement and this wait_idle() hung forever
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::Relaxed), 55);
        // and the pool still serves new work afterwards
        let s = Arc::clone(&sum);
        pool.submit(move || {
            s.fetch_add(100, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::Relaxed), 155);
        assert_eq!(pool.submitted(), 12);
    }

    #[test]
    fn shared_map_orders_results_and_runs_everything() {
        let out = shared_map(16, |i| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
        // reentrant top-level calls are fine (only nesting inside a
        // job is forbidden)
        let out2 = shared_map(3, |i| shared_pool_width() + i);
        assert_eq!(out2.len(), 3);
        assert_eq!(shared_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicU64::new(0));
        let f = Arc::clone(&flag);
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            f.store(1, Ordering::Relaxed);
        });
        drop(pool); // must block until the job ran
        assert_eq!(flag.load(Ordering::Relaxed), 1);
    }
}
