//! Deterministic, seeded fault injection for chaos testing.
//!
//! A [`FaultPlan`] is parsed from a compact spec string (the
//! `--fault-plan` flag / `SLA2_FAULT_PLAN` env var):
//!
//! ```text
//! panic:shard=1:nth=3,slow:ms=200:rate=0.1,drop-conn:rate=0.05
//! ```
//!
//! Comma-separated fault clauses; each clause is a kind followed by
//! `key=value` modifiers:
//!
//! | kind          | site            | modifiers                          |
//! |---------------|-----------------|------------------------------------|
//! | `panic`       | backend execute | `shard=K` (only shard K), `nth=N` (the N-th execute at that site, 1-based), `rate=P` (each execute, prob P) |
//! | `slow`        | backend execute | `ms=D` (sleep D ms; required), plus `shard`/`nth`/`rate` |
//! | `hang`        | backend execute | `shard`/`nth`/`rate` — stall INDEFINITELY (not a bounded `slow`); only the watchdog's fenced replacement recovers the shard |
//! | `drop-conn`   | net framing     | `nth=N`, `rate=P`                  |
//! | `slow-client` | net writer      | `ms=D` (stall the connection writer D ms; required), plus `nth`/`rate` — models a slow-loris client that stops draining its socket |
//!
//! A clause with neither `nth` nor `rate` fires on EVERY event at its
//! site.  Determinism: every probabilistic draw comes from a
//! [`Pcg32`] seeded from `(plan seed, site stream)`, and `nth`
//! counters are per-injector — so a given (plan, seed, shard id,
//! event order) always injects the same faults.  That is what lets
//! the chaos suite assert exact invariants per seed.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg32;

/// What a fault check decided at a given event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// proceed normally
    None,
    /// panic (the harness expects `catch_unwind` containment upstream)
    Panic,
    /// sleep this long, then proceed
    Slow(Duration),
    /// stall indefinitely (execute site only) — the injected analogue
    /// of a wedged PJRT call; recovery is the watchdog's job, not the
    /// injector's
    Hang,
    /// drop the connection (net framing site only)
    DropConn,
    /// stall the connection's WRITER this long (net site only) — a
    /// slow-loris client that stops draining its socket
    SlowClient(Duration),
}

/// Where a fault clause applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Site {
    Execute,
    Net,
}

#[derive(Debug, Clone, PartialEq)]
struct Clause {
    site: Site,
    /// action when the clause fires (Panic / Slow / DropConn)
    action: ClauseAction,
    /// restrict to one shard (Execute site only)
    shard: Option<usize>,
    /// fire on exactly the N-th event (1-based) at the site
    nth: Option<u64>,
    /// fire with this probability per event
    rate: Option<f64>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ClauseAction {
    Panic,
    Slow(u64),
    Hang,
    DropConn,
    SlowClient(u64),
}

/// A parsed fault plan plus its seed.  Cheap to clone; spawn one
/// [`FaultInjector`] per site (per shard backend, per connection).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    clauses: Vec<Clause>,
    seed: u64,
}

impl FaultPlan {
    /// Parse the spec string.  Empty (or whitespace) spec = no faults.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut clauses = Vec::new();
        for raw in spec.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let mut parts = raw.split(':');
            let kind = parts.next().unwrap();
            let (mut shard, mut nth, mut rate, mut ms) =
                (None, None, None, None);
            for kv in parts {
                let (k, v) = kv.split_once('=').with_context(
                    || format!("fault clause {raw:?}: modifier {kv:?} \
                                is not key=value"))?;
                match k {
                    "shard" => shard = Some(v.parse::<usize>().with_context(
                        || format!("fault clause {raw:?}: bad shard {v:?}"))?),
                    "nth" => {
                        let n: u64 = v.parse().with_context(
                            || format!("fault clause {raw:?}: bad nth \
                                        {v:?}"))?;
                        if n == 0 {
                            bail!("fault clause {raw:?}: nth is 1-based");
                        }
                        nth = Some(n);
                    }
                    "rate" => {
                        let r: f64 = v.parse().with_context(
                            || format!("fault clause {raw:?}: bad rate \
                                        {v:?}"))?;
                        if !(0.0..=1.0).contains(&r) {
                            bail!("fault clause {raw:?}: rate {r} not \
                                   in [0, 1]");
                        }
                        rate = Some(r);
                    }
                    "ms" => ms = Some(v.parse::<u64>().with_context(
                        || format!("fault clause {raw:?}: bad ms {v:?}"))?),
                    other => bail!("fault clause {raw:?}: unknown \
                                    modifier {other:?}"),
                }
            }
            let (site, action) = match kind {
                "panic" => (Site::Execute, ClauseAction::Panic),
                "slow" => (Site::Execute, ClauseAction::Slow(
                    ms.with_context(|| format!(
                        "fault clause {raw:?}: slow needs ms=<dur>"))?)),
                "hang" => {
                    if ms.is_some() {
                        bail!("fault clause {raw:?}: hang takes no ms= \
                               (it stalls indefinitely; use slow for a \
                               bounded stall)");
                    }
                    (Site::Execute, ClauseAction::Hang)
                }
                "drop-conn" => (Site::Net, ClauseAction::DropConn),
                "slow-client" => (Site::Net, ClauseAction::SlowClient(
                    ms.with_context(|| format!(
                        "fault clause {raw:?}: slow-client needs \
                         ms=<dur>"))?)),
                other => bail!("unknown fault kind {other:?} (expected \
                                panic | slow | hang | drop-conn | \
                                slow-client)"),
            };
            if site == Site::Net && shard.is_some() {
                bail!("fault clause {raw:?}: shard= does not apply to \
                       net faults");
            }
            clauses.push(Clause { site, action, shard, nth, rate });
        }
        Ok(FaultPlan { clauses, seed })
    }

    /// A plan that injects nothing (what an empty `--fault-plan`
    /// resolves to).
    pub fn none() -> FaultPlan {
        FaultPlan { clauses: Vec::new(), seed: 0 }
    }

    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// True if any clause targets backend execute (panic / slow /
    /// hang).
    pub fn has_execute_faults(&self) -> bool {
        self.clauses.iter().any(|c| c.site == Site::Execute)
    }

    /// True if any clause targets the net site (drop-conn /
    /// slow-client).
    pub fn has_net_faults(&self) -> bool {
        self.clauses.iter().any(|c| c.site == Site::Net)
    }

    /// Injector for shard `shard`'s backend-execute site.
    pub fn execute_injector(&self, shard: usize) -> FaultInjector {
        FaultInjector::new(self, Site::Execute, Some(shard),
                           // distinct RNG stream per shard
                           0x45_5845u64 ^ ((shard as u64) << 8))
    }

    /// Injector for one connection's framing site.  `conn` should be a
    /// stable per-connection ordinal so plans replay deterministically.
    pub fn net_injector(&self, conn: u64) -> FaultInjector {
        FaultInjector::new(self, Site::Net, None, 0x4e_4554u64 ^ (conn << 8))
    }
}

/// Per-site fault decision stream.  NOT shared across threads: each
/// shard / connection owns its own injector so `nth` counters and RNG
/// draws are ordered by that site's own event sequence.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    clauses: Vec<Clause>,
    rng: Pcg32,
    shard: Option<usize>,
    count: u64,
}

impl FaultInjector {
    fn new(plan: &FaultPlan, site: Site, shard: Option<usize>,
           stream: u64) -> FaultInjector {
        let clauses = plan.clauses.iter()
            .filter(|c| c.site == site)
            .filter(|c| match (c.shard, shard) {
                (Some(want), Some(have)) => want == have,
                (Some(_), None) => false,
                (None, _) => true,
            })
            .cloned()
            .collect();
        FaultInjector {
            clauses,
            rng: Pcg32::new(plan.seed, stream),
            shard,
            count: 0,
        }
    }

    /// An injector that never fires (for sites with no plan).
    pub fn inert() -> FaultInjector {
        FaultInjector { clauses: Vec::new(), rng: Pcg32::seeded(0),
                        shard: None, count: 0 }
    }

    pub fn is_inert(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Which shard this injector watches (None for net injectors).
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }

    /// Record one event at this site and decide the fault action.
    /// First matching clause wins (plan order).  Every rate clause
    /// draws from the RNG on every event regardless of earlier
    /// matches, keeping the decision stream independent of clause
    /// order side effects.
    pub fn check(&mut self) -> FaultAction {
        if self.clauses.is_empty() {
            return FaultAction::None;
        }
        self.count += 1;
        let mut fired: Option<ClauseAction> = None;
        for c in &self.clauses {
            let hit = match (c.nth, c.rate) {
                (Some(n), _) => self.count == n,
                (None, Some(p)) => self.rng.f64() < p,
                (None, None) => true,
            };
            if hit && fired.is_none() {
                fired = Some(c.action);
            }
        }
        match fired {
            None => FaultAction::None,
            Some(ClauseAction::Panic) => FaultAction::Panic,
            Some(ClauseAction::Slow(ms)) =>
                FaultAction::Slow(Duration::from_millis(ms)),
            Some(ClauseAction::Hang) => FaultAction::Hang,
            Some(ClauseAction::DropConn) => FaultAction::DropConn,
            Some(ClauseAction::SlowClient(ms)) =>
                FaultAction::SlowClient(Duration::from_millis(ms)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let plan = FaultPlan::parse("", 7).unwrap();
        assert!(plan.is_empty());
        assert!(!plan.has_execute_faults());
        let mut inj = plan.execute_injector(0);
        for _ in 0..100 {
            assert_eq!(inj.check(), FaultAction::None);
        }
    }

    #[test]
    fn nth_panic_targets_one_shard_and_one_event() {
        let plan = FaultPlan::parse("panic:shard=1:nth=3", 1).unwrap();
        let mut s0 = plan.execute_injector(0);
        let mut s1 = plan.execute_injector(1);
        for _ in 0..10 {
            assert_eq!(s0.check(), FaultAction::None);
        }
        assert_eq!(s1.check(), FaultAction::None);
        assert_eq!(s1.check(), FaultAction::None);
        assert_eq!(s1.check(), FaultAction::Panic);
        assert_eq!(s1.check(), FaultAction::None);
    }

    #[test]
    fn rate_draws_are_deterministic_per_seed() {
        let plan = FaultPlan::parse("slow:ms=5:rate=0.3", 42).unwrap();
        let run = |p: &FaultPlan| {
            let mut inj = p.execute_injector(2);
            (0..64).map(|_| inj.check() != FaultAction::None)
                   .collect::<Vec<bool>>()
        };
        let a = run(&plan);
        let b = run(&plan);
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x), "rate 0.3 over 64 events fired 0x");
        assert!(!a.iter().all(|&x| x), "rate 0.3 fired every time");
        // a different seed gives a different decision stream
        let other = FaultPlan::parse("slow:ms=5:rate=0.3", 43).unwrap();
        assert_ne!(run(&other), a);
    }

    #[test]
    fn slow_carries_its_duration() {
        let plan = FaultPlan::parse("slow:ms=200:nth=1", 0).unwrap();
        let mut inj = plan.execute_injector(0);
        assert_eq!(inj.check(),
                   FaultAction::Slow(Duration::from_millis(200)));
        assert_eq!(inj.check(), FaultAction::None);
    }

    #[test]
    fn drop_conn_lives_on_the_net_site() {
        let plan = FaultPlan::parse(
            "panic:shard=1:nth=3,drop-conn:nth=2", 9).unwrap();
        assert!(plan.has_execute_faults());
        assert!(plan.has_net_faults());
        let mut net = plan.net_injector(0);
        assert_eq!(net.check(), FaultAction::None);
        assert_eq!(net.check(), FaultAction::DropConn);
        // the panic clause does not leak into the net site
        for _ in 0..20 {
            assert_eq!(net.check(), FaultAction::None);
        }
    }

    #[test]
    fn full_example_plan_parses() {
        let plan = FaultPlan::parse(
            "panic:shard=1:nth=3,slow:ms=200:rate=0.1,drop-conn:rate=0.05",
            17).unwrap();
        assert_eq!(plan.clauses.len(), 3);
        assert!(plan.has_execute_faults() && plan.has_net_faults());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["explode", "panic:nth=0", "slow:nth=1",
                    "panic:rate=1.5", "panic:shard", "slow:ms=abc",
                    "drop-conn:shard=1", "panic:bogus=1",
                    "hang:ms=5", "slow-client", "slow-client:shard=1:ms=5"] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad}");
        }
    }

    #[test]
    fn hang_is_an_execute_fault_distinct_from_slow() {
        let plan = FaultPlan::parse("hang:shard=0:nth=2", 3).unwrap();
        assert!(plan.has_execute_faults());
        assert!(!plan.has_net_faults());
        let mut s0 = plan.execute_injector(0);
        assert_eq!(s0.check(), FaultAction::None);
        assert_eq!(s0.check(), FaultAction::Hang);
        assert_eq!(s0.check(), FaultAction::None);
        // other shards never see a shard-pinned hang
        let mut s1 = plan.execute_injector(1);
        for _ in 0..5 {
            assert_eq!(s1.check(), FaultAction::None);
        }
    }

    #[test]
    fn slow_client_stalls_the_net_writer_site() {
        let plan = FaultPlan::parse(
            "slow-client:ms=40:nth=2,hang:nth=1", 11).unwrap();
        assert!(plan.has_net_faults());
        let mut net = plan.net_injector(0);
        assert_eq!(net.check(), FaultAction::None);
        assert_eq!(net.check(),
                   FaultAction::SlowClient(Duration::from_millis(40)));
        // the hang clause stays on the execute site
        assert_eq!(net.check(), FaultAction::None);
    }

    #[test]
    fn slow_client_rate_draws_replay_per_seed() {
        let plan = FaultPlan::parse("slow-client:ms=5:rate=0.4", 21)
            .unwrap();
        let run = |p: &FaultPlan| {
            let mut inj = p.net_injector(3);
            (0..64).map(|_| inj.check() != FaultAction::None)
                   .collect::<Vec<bool>>()
        };
        let a = run(&plan);
        assert_eq!(a, run(&plan), "same plan+seed must replay exactly");
        assert!(a.iter().any(|&x| x) && !a.iter().all(|&x| x));
    }

    #[test]
    fn clause_with_no_modifier_always_fires() {
        let plan = FaultPlan::parse("panic", 0).unwrap();
        let mut inj = plan.execute_injector(5);
        assert_eq!(inj.check(), FaultAction::Panic);
        assert_eq!(inj.check(), FaultAction::Panic);
    }
}
