//! First-party utility substrates.
//!
//! The offline cargo registry only carries `xla`/`anyhow`/`thiserror`/
//! `once_cell`, so everything a framework normally pulls from crates.io
//! lives here instead: JSON ([`json`]), a PCG RNG ([`rng`]), CLI
//! parsing ([`cli`]), descriptive statistics ([`stats`]), a thread pool
//! ([`threadpool`]), leveled logging ([`logging`]), a property-testing
//! mini-framework ([`proptest`]), the criterion-style bench harness
//! ([`bench`]) and deterministic fault injection for chaos tests
//! ([`faults`]).

pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
