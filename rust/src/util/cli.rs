//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and typed getters with defaults.  Subcommand dispatch is
//! just the first positional.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit token list (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(key.to_string(), v);
                } else {
                    args.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.flags.get(key)
            .map(|v| v == "true" || v == "1" || v == "yes")
            .unwrap_or(default)
    }

    /// The `--json <path>` convention for bench report emission:
    /// absent -> `default`, `--json <path>` -> that path, and
    /// `--json none|off|false` -> disabled.
    pub fn json_path(&self, default: &str) -> Option<String> {
        let v = self.str("json", default);
        match v.as_str() {
            "none" | "off" | "false" | "" => None,
            // a bare `--json` parses as "true": use the default path
            "true" => Some(default.to_string()),
            _ => Some(v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("serve --model dit-small --steps 20 input.json");
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.positional, vec!["serve", "input.json"]);
        assert_eq!(a.str("model", "x"), "dit-small");
        assert_eq!(a.usize("steps", 0), 20);
    }

    #[test]
    fn eq_form_and_bools() {
        let a = parse("--k=0.05 --quant --no-x false");
        assert_eq!(a.f64("k", 0.0), 0.05);
        assert!(a.bool("quant", false));
        assert!(!a.bool("no-x", true));
    }

    #[test]
    fn trailing_flag_is_bool() {
        let a = parse("run --verbose");
        assert!(a.bool("verbose", false));
    }

    #[test]
    fn json_path_flag() {
        assert_eq!(parse("bench").json_path("BENCH_x.json"),
                   Some("BENCH_x.json".into()));
        assert_eq!(parse("bench --json out.json").json_path("d.json"),
                   Some("out.json".into()));
        assert_eq!(parse("bench --json none").json_path("d.json"), None);
        assert_eq!(parse("bench --json off").json_path("d.json"), None);
        // bare flag (parses as "true") falls back to the default path
        assert_eq!(parse("bench --json").json_path("d.json"),
                   Some("d.json".into()));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.str("missing", "d"), "d");
        assert_eq!(a.usize("missing", 7), 7);
        assert!(!a.has("missing"));
    }
}
