//! Criterion-style bench harness (criterion is not in the offline
//! registry).  Warmup + timed iterations + summary stats, plus a
//! markdown-ish table printer shared by all paper-table benches.

use std::time::Instant;

use super::stats::Summary;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// per-iteration wall time in seconds
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                       mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Adaptive variant: keep iterating until `min_time_s` of measurement
/// or `max_iters`, whichever first (good for multi-second HLO steps).
pub fn run_for<F: FnMut()>(name: &str, warmup: usize, min_time_s: f64,
                           max_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters
        && (samples.len() < 3 || start.elapsed().as_secs_f64() < min_time_s)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let r = run("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.summary.n, 10);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn run_for_respects_max_iters() {
        let r = run_for("fast", 0, 10.0, 5, || {});
        assert_eq!(r.summary.n, 5);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["name", "ms"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "10.25".into()]);
        let s = t.to_string();
        assert!(s.contains("| long-name |"));
        assert!(s.lines().count() == 4);
    }
}
