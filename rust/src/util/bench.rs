//! Criterion-style bench harness (criterion is not in the offline
//! registry).  Warmup + timed iterations + summary stats, plus a
//! markdown-ish table printer shared by all paper-table benches and a
//! JSON report writer for the perf-trajectory files
//! (`BENCH_<name>.json`).

use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// per-iteration wall time in seconds
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .push("name", self.name.as_str())
            .push("n", self.summary.n)
            .push("mean_ms", self.summary.mean * 1e3)
            .push("p50_ms", self.summary.p50 * 1e3)
            .push("p90_ms", self.summary.p90 * 1e3)
            .push("p99_ms", self.summary.p99 * 1e3)
            .push("min_ms", self.summary.min * 1e3)
            .push("max_ms", self.summary.max * 1e3)
    }
}

/// Assemble a bench report: `{"bench": <name>, "results": [...]}`.
pub fn report(bench: &str, results: Vec<Json>) -> Json {
    Json::obj()
        .push("bench", bench)
        .push("results", results)
}

/// Write a JSON report to `path` (the perf-trajectory file a bench
/// run leaves behind, e.g. `BENCH_fig5_e2e.json`).
pub fn write_json(path: &str, report: &Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{report}\n"))
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                       mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Adaptive variant: keep iterating until `min_time_s` of measurement
/// or `max_iters`, whichever first (good for multi-second HLO steps).
pub fn run_for<F: FnMut()>(name: &str, warmup: usize, min_time_s: f64,
                           max_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < max_iters
        && (samples.len() < 3 || start.elapsed().as_secs_f64() < min_time_s)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let r = run("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.summary.n, 10);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn run_for_respects_max_iters() {
        let r = run_for("fast", 0, 10.0, 5, || {});
        assert_eq!(r.summary.n, 5);
    }

    #[test]
    fn json_report_roundtrips() {
        let r = run("unit", 0, 4, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let j = report("mini", vec![r.to_json()]);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("mini"));
        let parsed = Json::parse(&j.to_string()).unwrap();
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("unit"));
        assert_eq!(results[0].get("n").unwrap().as_usize(), Some(4));
        assert!(results[0].get("mean_ms").unwrap().as_f64().unwrap()
                >= 0.0);
    }

    #[test]
    fn write_json_produces_parseable_file() {
        let path = std::env::temp_dir().join("sla2_bench_write_test.json");
        let path = path.to_str().unwrap().to_string();
        let j = report("t", vec![Json::obj().push("x", 1usize)]);
        write_json(&path, &j).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(back.trim()).unwrap(), j);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["name", "ms"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "10.25".into()]);
        let s = t.to_string();
        assert!(s.contains("| long-name |"));
        assert!(s.lines().count() == 4);
    }
}
