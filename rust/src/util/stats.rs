//! Descriptive statistics for bench results and serving metrics.

/// Summary of a sample of f64 observations (latencies, losses, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Welford's online mean/variance — allocation-free hot-loop metrics.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Online {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY,
                 max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37 - 5.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-9);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
        assert_eq!(o.count(), 100);
    }

    #[test]
    fn online_single_value() {
        let mut o = Online::new();
        o.push(4.2);
        assert_eq!(o.mean(), 4.2);
        assert_eq!(o.std(), 0.0);
    }
}
