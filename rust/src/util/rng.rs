//! PCG32 pseudo-random generator + sampling helpers.
//!
//! Deterministic, seedable, dependency-free (the `rand` crate is not in
//! the offline registry).  Used for synthetic workloads, request
//! traces, property-test case generation and noise tensors.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!((0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
                   (0..8).map(|_| b.next_u32()).collect::<Vec<_>>());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exp_mean() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
