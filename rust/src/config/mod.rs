//! Typed configuration system over the artifact manifest + run configs.
//!
//! `ModelConfig` mirrors `python/compile/model.py::ModelConfig` and is
//! parsed from `manifest.json` (the python side is the source of
//! truth; Rust never hardcodes geometry).  `ServeConfig`/`TrainConfig`
//! are the L3 runtime knobs, loadable from a JSON file or CLI flags.

use anyhow::{Context, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Mirror of the L2 model geometry (from `manifest.json::configs`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub video: [usize; 4], // (T, H, W, C)
    pub patch: [usize; 3],
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub b_q: usize,
    pub b_k: usize,
    pub n_tokens: usize,
    pub t_m: usize,
    pub t_n: usize,
    pub num_classes: usize,
    pub param_count: usize,
}

impl ModelConfig {
    pub fn from_json(name: &str, j: &Json) -> Result<ModelConfig> {
        let vid = j.req("video")?.as_usize_vec()
            .context("video shape")?;
        let patch = j.req("patch")?.as_usize_vec().context("patch")?;
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().context(format!("config field {k}"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            video: [vid[0], vid[1], vid[2], vid[3]],
            patch: [patch[0], patch[1], patch[2]],
            dim: u("dim")?,
            depth: u("depth")?,
            heads: u("heads")?,
            head_dim: u("head_dim")?,
            b_q: u("b_q")?,
            b_k: u("b_k")?,
            n_tokens: u("n_tokens")?,
            t_m: u("t_m")?,
            t_n: u("t_n")?,
            num_classes: u("num_classes")?,
            param_count: u("param_count")?,
        })
    }

    pub fn video_numel(&self) -> usize {
        self.video.iter().product()
    }

    /// Number of key blocks the sparse branch keeps at `k_pct`
    /// (mirrors `router.top_k_count`).
    pub fn kept_blocks(&self, k_pct: f64) -> usize {
        ((k_pct * self.t_n as f64).round() as usize).max(1)
    }

    /// Achieved block sparsity at `k_pct` (Table 1's "Sparsity" column).
    pub fn block_sparsity(&self, k_pct: f64) -> f64 {
        1.0 - self.kept_blocks(k_pct) as f64 / self.t_n as f64
    }
}

/// Default shard count for the engine pool: available cores minus one
/// (one core is left for the frontend/dispatcher), floored at 1 and
/// capped at 8 — every shard loads its own runtime + parameter copy
/// and compiles its own executables, so an uncapped default would
/// silently eat minutes and gigabytes on many-core hosts.  Set
/// `num_shards` explicitly to go wider.
pub fn default_num_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .saturating_sub(1)
        .clamp(1, 8)
}

/// Serving-side knobs (engine pool + dynamic batcher + sampler).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    /// default attention variant: on the native backend one of
    /// [`crate::runtime::native::model::SUPPORTED_VARIANTS`] (`"sla2"`,
    /// `"sla2_noquant"`, `"sparge2"`, `"svg_ear"`, `"full"`; validated
    /// at server startup), on `"xla"` whatever the artifact manifest
    /// provides.  Requests may override it per submission
    /// ([`crate::coordinator::SubmitOpts::variant`]); the dense tier
    /// always serves full softmax regardless
    pub variant: String,
    pub tier: String,
    /// compute backend: `"xla"` (AOT artifacts through PJRT, the
    /// default) or `"native"` (pure-Rust CPU SLA2 — no artifacts
    /// needed; uses the manifest's weights when present, a seeded init
    /// otherwise)
    pub backend: String,
    /// native backend only — how the `sla2` variant's INT8
    /// quantization points execute: `"int8"` (default; real `i8 x i8
    /// -> i32` integer kernels), `"sim"` (the f32 fake-quant
    /// simulation, kept as the parity/measurement baseline) or
    /// `"off"` (no quantization).  Ignored by `"xla"`, whose
    /// artifacts bake the quantization into the lowered HLO.
    pub quant_mode: String,
    /// native backend only — which SIMD instruction set the kernel
    /// layer dispatches to: `"auto"` (default; runtime feature
    /// detection picks the best available), `"avx2"`, `"sse41"`,
    /// `"neon"` or `"scalar"` (the portable reference).  Requesting an
    /// ISA the host cannot run fails at startup.  The
    /// `SLA2_FORCE_SCALAR` env var overrides everything (CI's
    /// forced-scalar conformance leg).  Ignored by `"xla"`.
    pub kernel_isa: String,
    pub sample_steps: usize,
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch before dispatching
    pub batch_window_ms: u64,
    pub queue_capacity: usize,
    /// engine-pool width: each shard owns its own PJRT runtime and
    /// executable cache (the client is `Rc`-based and never crosses
    /// threads); 1 reproduces the old single-engine behavior
    pub num_shards: usize,
    /// scheduler policy: `"class"` (default) buckets requests by
    /// compatibility class and lets an aged cheap class bypass an
    /// expensive head-of-line class; `"fifo"` reproduces the seed's
    /// strict-FIFO-compatible batching bit-for-bit
    pub scheduler: String,
    /// class mode only: how long a cheaper class's head must have
    /// waited before it may jump a more expensive class at the head
    pub bypass_threshold_ms: u64,
    /// TCP frontend bind address (e.g. `"127.0.0.1:7341"`, port 0 for
    /// an ephemeral port); empty = in-process API only, no listener
    pub listen_addr: String,
    /// frames per streamed [`ClipChunk`](crate::coordinator::ClipChunk);
    /// 0 = the whole clip as a single chunk
    pub chunk_frames: usize,
    /// chunks buffered per stream before the producer blocks
    /// (bounded backpressure; floored at 1)
    pub stream_buffer_chunks: usize,
    /// default per-request deadline applied when a submission carries
    /// none; 0 = no deadline
    pub default_deadline_ms: u64,
    /// admission control: shed (or degrade) once queue depth reaches
    /// this fraction of `queue_capacity`; >= 1.0 disables depth-based
    /// shedding (the default — the queue's own capacity still bounds
    /// admission)
    pub shed_watermark: f64,
    /// admission control: shed (or degrade) once the queue's summed
    /// estimated work (requests x class cost) reaches this value;
    /// 0 disables work-based shedding
    pub work_watermark: f64,
    /// how many times a request whose shard panicked is re-queued
    /// before it fails with a terminal `shard_failed`; 0 = never retry
    pub retry_budget: u32,
    /// base for the exponential jittered retry backoff (attempt 1
    /// waits ~`retry_backoff_ms`, capped at 2 s)
    pub retry_backoff_ms: u64,
    /// quarantine a shard after this many panics inside
    /// `quarantine_window_ms`; 0 disables quarantine
    pub quarantine_failures: u32,
    /// sliding window over which shard panics are counted
    pub quarantine_window_ms: u64,
    /// how long a quarantined shard sits out before rebuilding its
    /// backend and re-admitting itself
    pub quarantine_cooldown_ms: u64,
    /// watchdog: fail a shard's in-flight batch once its progress
    /// heartbeat (stamped per denoise step and per backend execute)
    /// is older than this, abandon the wedged thread and spawn a
    /// fenced replacement; 0 disables the watchdog.  Must comfortably
    /// exceed the slowest single denoise step (including a first-time
    /// XLA compile) or healthy shards get shot.
    pub stall_threshold_ms: u64,
    /// graceful shutdown: how long `Server::drain` waits for in-flight
    /// work (queue + busy shards + open streams) before forcing exit
    pub drain_timeout_ms: u64,
    /// TCP frontend: frames buffered per connection writer before the
    /// producer side blocks (bounded slow-client backpressure;
    /// floored at 1)
    pub net_send_queue: usize,
    /// TCP frontend: a connection whose writer cannot enqueue a frame
    /// for this long is declared a slow client — its streams are
    /// cancelled (freeing shard slots) and the connection is dropped
    pub write_stall_ms: u64,
    /// TCP frontend: reactor I/O worker threads multiplexing all
    /// connections (floored at 1).  Thread count is O(this), never
    /// O(connections).
    pub net_workers: usize,
    /// TCP frontend: access token every connection must present in a
    /// `hello` frame before any other verb; empty = auth off
    pub auth_token: String,
    /// TCP frontend: per-connection submit budget in submits/second
    /// (token bucket, burst `max(1, rate)`); over-budget submits are
    /// rejected with typed `rate_limited` + `retry_after_ms`.
    /// 0 = unlimited
    pub rate_limit: f64,
    /// deterministic fault-injection plan (chaos testing), e.g.
    /// `"panic:shard=1:nth=3,slow:ms=200:rate=0.1,drop-conn:rate=0.05"`;
    /// empty = no faults (production default)
    pub fault_plan: String,
    /// seed for the fault plan's per-site RNG streams — the same plan
    /// + seed replays the same faults
    pub fault_seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "dit-tiny".into(),
            variant: "sla2".into(),
            tier: "s90".into(),
            backend: "xla".into(),
            quant_mode: "int8".into(),
            kernel_isa: "auto".into(),
            sample_steps: 8,
            max_batch: 2,
            batch_window_ms: 5,
            queue_capacity: 256,
            num_shards: default_num_shards(),
            scheduler: "class".into(),
            bypass_threshold_ms: 50,
            listen_addr: String::new(),
            chunk_frames: 1,
            stream_buffer_chunks: 8,
            default_deadline_ms: 0,
            shed_watermark: 1.0,
            work_watermark: 0.0,
            retry_budget: 2,
            retry_backoff_ms: 20,
            quarantine_failures: 3,
            quarantine_window_ms: 10_000,
            quarantine_cooldown_ms: 250,
            stall_threshold_ms: 0,
            drain_timeout_ms: 5_000,
            net_send_queue: 64,
            write_stall_ms: 2_000,
            net_workers: 4,
            auth_token: String::new(),
            rate_limit: 0.0,
            fault_plan: String::new(),
            fault_seed: 0,
        }
    }
}

impl ServeConfig {
    pub fn from_args(args: &Args) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            model: args.str("model", &d.model),
            variant: args.str("variant", &d.variant),
            tier: args.str("tier", &d.tier),
            backend: args.str("backend", &d.backend),
            quant_mode: args.str("quant-mode", &d.quant_mode),
            kernel_isa: args.str("kernel-isa", &d.kernel_isa),
            sample_steps: args.usize("steps", d.sample_steps),
            max_batch: args.usize("max-batch", d.max_batch),
            batch_window_ms: args.u64("batch-window-ms", d.batch_window_ms),
            queue_capacity: args.usize("queue-capacity", d.queue_capacity),
            num_shards: args.usize("num-shards", d.num_shards).max(1),
            scheduler: args.str("scheduler", &d.scheduler),
            bypass_threshold_ms: args.u64("bypass-threshold-ms",
                                          d.bypass_threshold_ms),
            listen_addr: args.str("listen-addr", &d.listen_addr),
            chunk_frames: args.usize("chunk-frames", d.chunk_frames),
            stream_buffer_chunks:
                args.usize("stream-buffer-chunks",
                           d.stream_buffer_chunks).max(1),
            default_deadline_ms: args.u64("default-deadline-ms",
                                          d.default_deadline_ms),
            shed_watermark: args.f64("shed-watermark", d.shed_watermark),
            work_watermark: args.f64("work-watermark", d.work_watermark),
            retry_budget: args.u64("retry-budget",
                                   d.retry_budget as u64) as u32,
            retry_backoff_ms: args.u64("retry-backoff-ms",
                                       d.retry_backoff_ms),
            quarantine_failures:
                args.u64("quarantine-failures",
                         d.quarantine_failures as u64) as u32,
            quarantine_window_ms: args.u64("quarantine-window-ms",
                                           d.quarantine_window_ms),
            quarantine_cooldown_ms: args.u64("quarantine-cooldown-ms",
                                             d.quarantine_cooldown_ms),
            stall_threshold_ms: args.u64("stall-threshold-ms",
                                         d.stall_threshold_ms),
            drain_timeout_ms: args.u64("drain-timeout-ms",
                                       d.drain_timeout_ms),
            net_send_queue: args.usize("net-send-queue",
                                       d.net_send_queue).max(1),
            write_stall_ms: args.u64("write-stall-ms", d.write_stall_ms),
            net_workers: args.usize("net-workers", d.net_workers).max(1),
            auth_token: args.str("auth-token", &d.auth_token),
            rate_limit: args.f64("rate-limit", d.rate_limit),
            fault_plan: args.str("fault-plan", &d.fault_plan),
            fault_seed: args.u64("fault-seed", d.fault_seed),
        }
    }

    pub fn from_json(j: &Json) -> ServeConfig {
        let d = ServeConfig::default();
        let s = |k: &str, dv: &str| {
            j.get(k).and_then(|v| v.as_str()).unwrap_or(dv).to_string()
        };
        let u = |k: &str, dv: usize| {
            j.get(k).and_then(|v| v.as_usize()).unwrap_or(dv)
        };
        let f = |k: &str, dv: f64| {
            j.get(k).and_then(|v| v.as_f64()).unwrap_or(dv)
        };
        ServeConfig {
            model: s("model", &d.model),
            variant: s("variant", &d.variant),
            tier: s("tier", &d.tier),
            backend: s("backend", &d.backend),
            quant_mode: s("quant_mode", &d.quant_mode),
            kernel_isa: s("kernel_isa", &d.kernel_isa),
            sample_steps: u("sample_steps", d.sample_steps),
            max_batch: u("max_batch", d.max_batch),
            batch_window_ms: u("batch_window_ms",
                               d.batch_window_ms as usize) as u64,
            queue_capacity: u("queue_capacity", d.queue_capacity),
            num_shards: u("num_shards", d.num_shards).max(1),
            scheduler: s("scheduler", &d.scheduler),
            bypass_threshold_ms: u("bypass_threshold_ms",
                                   d.bypass_threshold_ms as usize) as u64,
            listen_addr: s("listen_addr", &d.listen_addr),
            chunk_frames: u("chunk_frames", d.chunk_frames),
            stream_buffer_chunks:
                u("stream_buffer_chunks", d.stream_buffer_chunks).max(1),
            default_deadline_ms: u("default_deadline_ms",
                                   d.default_deadline_ms as usize) as u64,
            shed_watermark: f("shed_watermark", d.shed_watermark),
            work_watermark: f("work_watermark", d.work_watermark),
            retry_budget: u("retry_budget",
                            d.retry_budget as usize) as u32,
            retry_backoff_ms: u("retry_backoff_ms",
                                d.retry_backoff_ms as usize) as u64,
            quarantine_failures:
                u("quarantine_failures",
                  d.quarantine_failures as usize) as u32,
            quarantine_window_ms:
                u("quarantine_window_ms",
                  d.quarantine_window_ms as usize) as u64,
            quarantine_cooldown_ms:
                u("quarantine_cooldown_ms",
                  d.quarantine_cooldown_ms as usize) as u64,
            stall_threshold_ms: u("stall_threshold_ms",
                                  d.stall_threshold_ms as usize) as u64,
            drain_timeout_ms: u("drain_timeout_ms",
                                d.drain_timeout_ms as usize) as u64,
            net_send_queue: u("net_send_queue", d.net_send_queue).max(1),
            write_stall_ms: u("write_stall_ms",
                              d.write_stall_ms as usize) as u64,
            net_workers: u("net_workers", d.net_workers).max(1),
            auth_token: s("auth_token", &d.auth_token),
            rate_limit: f("rate_limit", d.rate_limit),
            fault_plan: s("fault_plan", &d.fault_plan),
            fault_seed: u("fault_seed", d.fault_seed as usize) as u64,
        }
    }
}

/// Training-driver knobs (Alg. 1).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub variant: String,
    pub tier: String,
    pub stage1_steps: usize,
    pub stage2_steps: usize,
    pub batch: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "dit-tiny".into(),
            variant: "sla2".into(),
            tier: "s90".into(),
            stage1_steps: 30,
            stage2_steps: 100,
            batch: 2,
            seed: 42,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn from_args(args: &Args) -> TrainConfig {
        let d = TrainConfig::default();
        TrainConfig {
            model: args.str("model", &d.model),
            variant: args.str("variant", &d.variant),
            tier: args.str("tier", &d.tier),
            stage1_steps: args.usize("stage1-steps", d.stage1_steps),
            stage2_steps: args.usize("stage2-steps", d.stage2_steps),
            batch: args.usize("batch", d.batch),
            seed: args.u64("seed", d.seed),
            log_every: args.usize("log-every", d.log_every),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{"video":[4,8,8,3],"patch":[2,2,2],"dim":64,"depth":2,
                "heads":2,"head_dim":32,"b_q":8,"b_k":4,"n_tokens":32,
                "t_m":4,"t_n":8,"num_classes":10,"param_count":176032}"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_model_config() {
        let c = ModelConfig::from_json("dit-tiny", &sample_json()).unwrap();
        assert_eq!(c.video, [4, 8, 8, 3]);
        assert_eq!(c.n_tokens, 32);
        assert_eq!(c.video_numel(), 768);
    }

    #[test]
    fn missing_field_errors() {
        let j = Json::parse(r#"{"video":[1,2,3,4]}"#).unwrap();
        assert!(ModelConfig::from_json("x", &j).is_err());
    }

    #[test]
    fn sparsity_math() {
        let c = ModelConfig::from_json("dit-tiny", &sample_json()).unwrap();
        assert_eq!(c.kept_blocks(0.10), 1); // round(0.8) -> 1
        assert_eq!(c.kept_blocks(0.5), 4);
        assert!((c.block_sparsity(0.10) - 0.875).abs() < 1e-12);
    }

    #[test]
    fn serve_config_from_args() {
        let a = Args::parse_from(
            ["--model", "dit-small", "--steps", "4"].map(String::from));
        let s = ServeConfig::from_args(&a);
        assert_eq!(s.model, "dit-small");
        assert_eq!(s.sample_steps, 4);
        assert_eq!(s.max_batch, ServeConfig::default().max_batch);
    }

    #[test]
    fn serve_config_from_json() {
        let j = Json::parse(r#"{"model":"m","max_batch":8}"#).unwrap();
        let s = ServeConfig::from_json(&j);
        assert_eq!(s.model, "m");
        assert_eq!(s.max_batch, 8);
    }

    #[test]
    fn backend_knob_parses_with_default() {
        assert_eq!(ServeConfig::default().backend, "xla");
        let a = Args::parse_from(["--backend", "native"].map(String::from));
        assert_eq!(ServeConfig::from_args(&a).backend, "native");
        let j = Json::parse(r#"{"backend":"native"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).backend, "native");
    }

    #[test]
    fn quant_mode_knob_parses_with_default() {
        assert_eq!(ServeConfig::default().quant_mode, "int8");
        let a = Args::parse_from(
            ["--quant-mode", "sim"].map(String::from));
        assert_eq!(ServeConfig::from_args(&a).quant_mode, "sim");
        let j = Json::parse(r#"{"quant_mode":"off"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).quant_mode, "off");
    }

    #[test]
    fn kernel_isa_knob_parses_with_default() {
        assert_eq!(ServeConfig::default().kernel_isa, "auto");
        let a = Args::parse_from(
            ["--kernel-isa", "scalar"].map(String::from));
        assert_eq!(ServeConfig::from_args(&a).kernel_isa, "scalar");
        let j = Json::parse(r#"{"kernel_isa":"avx2"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).kernel_isa, "avx2");
    }

    #[test]
    fn scheduler_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert_eq!(d.scheduler, "class");
        assert_eq!(d.bypass_threshold_ms, 50);
        let a = Args::parse_from(
            ["--scheduler", "fifo", "--bypass-threshold-ms", "120"]
                .map(String::from));
        let s = ServeConfig::from_args(&a);
        assert_eq!(s.scheduler, "fifo");
        assert_eq!(s.bypass_threshold_ms, 120);
        let j = Json::parse(
            r#"{"scheduler":"fifo","bypass_threshold_ms":10}"#).unwrap();
        let s = ServeConfig::from_json(&j);
        assert_eq!(s.scheduler, "fifo");
        assert_eq!(s.bypass_threshold_ms, 10);
    }

    #[test]
    fn streaming_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert_eq!(d.listen_addr, "");
        assert_eq!(d.chunk_frames, 1);
        assert_eq!(d.stream_buffer_chunks, 8);
        let a = Args::parse_from(
            ["--listen-addr", "127.0.0.1:0", "--chunk-frames", "2",
             "--stream-buffer-chunks", "0"].map(String::from));
        let s = ServeConfig::from_args(&a);
        assert_eq!(s.listen_addr, "127.0.0.1:0");
        assert_eq!(s.chunk_frames, 2);
        assert_eq!(s.stream_buffer_chunks, 1,
                   "buffer must floor at 1 chunk");
        let j = Json::parse(
            r#"{"listen_addr":"0.0.0.0:9000","chunk_frames":0,
                "stream_buffer_chunks":4}"#).unwrap();
        let s = ServeConfig::from_json(&j);
        assert_eq!(s.listen_addr, "0.0.0.0:9000");
        assert_eq!(s.chunk_frames, 0); // 0 = whole clip in one chunk
        assert_eq!(s.stream_buffer_chunks, 4);
    }

    #[test]
    fn fault_tolerance_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert_eq!(d.default_deadline_ms, 0);
        assert_eq!(d.shed_watermark, 1.0);
        assert_eq!(d.work_watermark, 0.0);
        assert_eq!(d.retry_budget, 2);
        assert_eq!(d.retry_backoff_ms, 20);
        assert_eq!(d.quarantine_failures, 3);
        assert_eq!(d.quarantine_window_ms, 10_000);
        assert_eq!(d.quarantine_cooldown_ms, 250);
        assert_eq!(d.fault_plan, "");
        assert_eq!(d.fault_seed, 0);
        let a = Args::parse_from(
            ["--shed-watermark", "0.8", "--work-watermark", "64",
             "--retry-budget", "1", "--retry-backoff-ms", "5",
             "--quarantine-failures", "2",
             "--quarantine-window-ms", "500",
             "--quarantine-cooldown-ms", "50",
             "--default-deadline-ms", "750",
             "--fault-plan", "panic:shard=0:nth=2",
             "--fault-seed", "7"].map(String::from));
        let s = ServeConfig::from_args(&a);
        assert_eq!(s.shed_watermark, 0.8);
        assert_eq!(s.work_watermark, 64.0);
        assert_eq!(s.retry_budget, 1);
        assert_eq!(s.retry_backoff_ms, 5);
        assert_eq!(s.quarantine_failures, 2);
        assert_eq!(s.quarantine_window_ms, 500);
        assert_eq!(s.quarantine_cooldown_ms, 50);
        assert_eq!(s.default_deadline_ms, 750);
        assert_eq!(s.fault_plan, "panic:shard=0:nth=2");
        assert_eq!(s.fault_seed, 7);
        let j = Json::parse(
            r#"{"shed_watermark":0.5,"work_watermark":8,
                "retry_budget":0,"fault_plan":"slow:ms=10",
                "fault_seed":3,"default_deadline_ms":100}"#).unwrap();
        let s = ServeConfig::from_json(&j);
        assert_eq!(s.shed_watermark, 0.5);
        assert_eq!(s.work_watermark, 8.0);
        assert_eq!(s.retry_budget, 0);
        assert_eq!(s.fault_plan, "slow:ms=10");
        assert_eq!(s.fault_seed, 3);
        assert_eq!(s.default_deadline_ms, 100);
    }

    #[test]
    fn liveness_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert_eq!(d.stall_threshold_ms, 0, "watchdog is opt-in");
        assert_eq!(d.drain_timeout_ms, 5_000);
        assert_eq!(d.net_send_queue, 64);
        assert_eq!(d.write_stall_ms, 2_000);
        let a = Args::parse_from(
            ["--stall-threshold-ms", "400", "--drain-timeout-ms", "900",
             "--net-send-queue", "0", "--write-stall-ms", "150"]
                .map(String::from));
        let s = ServeConfig::from_args(&a);
        assert_eq!(s.stall_threshold_ms, 400);
        assert_eq!(s.drain_timeout_ms, 900);
        assert_eq!(s.net_send_queue, 1, "send queue must floor at 1");
        assert_eq!(s.write_stall_ms, 150);
        let j = Json::parse(
            r#"{"stall_threshold_ms":250,"drain_timeout_ms":1000,
                "net_send_queue":16,"write_stall_ms":80}"#).unwrap();
        let s = ServeConfig::from_json(&j);
        assert_eq!(s.stall_threshold_ms, 250);
        assert_eq!(s.drain_timeout_ms, 1000);
        assert_eq!(s.net_send_queue, 16);
        assert_eq!(s.write_stall_ms, 80);
    }

    #[test]
    fn wire_knobs_parse_with_defaults() {
        let d = ServeConfig::default();
        assert_eq!(d.net_workers, 4);
        assert_eq!(d.auth_token, "", "auth is opt-in");
        assert_eq!(d.rate_limit, 0.0, "rate limiting is opt-in");
        let a = Args::parse_from(
            ["--net-workers", "0", "--auth-token", "hunter2",
             "--rate-limit", "2.5"].map(String::from));
        let s = ServeConfig::from_args(&a);
        assert_eq!(s.net_workers, 1, "workers must floor at 1");
        assert_eq!(s.auth_token, "hunter2");
        assert_eq!(s.rate_limit, 2.5);
        let j = Json::parse(
            r#"{"net_workers":8,"auth_token":"tok","rate_limit":10}"#)
            .unwrap();
        let s = ServeConfig::from_json(&j);
        assert_eq!(s.net_workers, 8);
        assert_eq!(s.auth_token, "tok");
        assert_eq!(s.rate_limit, 10.0);
    }

    #[test]
    fn num_shards_parses_and_never_drops_below_one() {
        assert!(default_num_shards() >= 1);
        let a = Args::parse_from(["--num-shards", "3"].map(String::from));
        assert_eq!(ServeConfig::from_args(&a).num_shards, 3);
        let a = Args::parse_from(["--num-shards", "0"].map(String::from));
        assert_eq!(ServeConfig::from_args(&a).num_shards, 1);
        let j = Json::parse(r#"{"num_shards":4}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).num_shards, 4);
        let j = Json::parse(r#"{"num_shards":0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).num_shards, 1);
    }
}
