//! Batch-size planning: map N compatible requests onto the batch sizes
//! the backend actually supports.
//!
//! XLA executables have static shapes, so a `denoise_*_b4` artifact
//! serves exactly 4 clips; the manifest's size set (e.g. {1, 4}) is an
//! exact-cover constraint.  [`plan_batches`] solves min-launch cover
//! with a small DP (greedy is suboptimal off the chain case: sizes
//! {1,3,4} at n=6 → greedy [4,1,1], optimal [3,3]).  The native
//! backend has no static shapes ([`BatchSupport::Any`]) and gets an
//! exact single-launch plan.
//!
//! Padding is never planned: it wastes a full sample's compute, and
//! with size 1 always exported an exact cover always exists.

use anyhow::{ensure, Result};

use crate::runtime::BatchSupport;

/// Minimum-launch exact cover: batch sizes summing to `n`, fewest
/// launches (unbounded-coin-change DP; ties prefer larger sizes, so
/// chain size-sets reproduce the greedy plan).  `sizes` must contain
/// 1, which guarantees a solution exists.  Returned descending.
pub fn plan_batches(n: usize, sizes: &[usize]) -> Vec<usize> {
    assert!(sizes.contains(&1), "size-1 artifact must exist");
    // size 1 covers every n, so the DP cannot fail — but fall back to
    // all-1 launches rather than panic in the serving path
    plan_batches_any(n, sizes).unwrap_or_else(|| vec![1; n])
}

/// The DP core of [`plan_batches`] without the size-1 requirement:
/// `None` when no exact cover of `n` exists over `sizes`.
fn plan_batches_any(n: usize, sizes: &[usize]) -> Option<Vec<usize>> {
    if n == 0 {
        return Some(Vec::new());
    }
    let mut sorted: Vec<usize> = sizes.iter().copied()
        .filter(|&s| s > 0)
        .collect();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    sorted.dedup();
    // dp[i] = fewest launches covering i; take[i] = size chosen at i
    let mut dp = vec![usize::MAX; n + 1];
    let mut take = vec![0usize; n + 1];
    dp[0] = 0;
    for i in 1..=n {
        for &s in &sorted {
            if s <= i && dp[i - s] != usize::MAX && dp[i - s] + 1 < dp[i]
            {
                dp[i] = dp[i - s] + 1;
                take[i] = s;
            }
        }
    }
    if dp[n] == usize::MAX {
        return None;
    }
    let mut plan = Vec::with_capacity(dp[n]);
    let mut rem = n;
    while rem > 0 {
        plan.push(take[rem]);
        rem -= take[rem];
    }
    plan.sort_unstable_by(|a, b| b.cmp(a));
    debug_assert_eq!(plan.iter().sum::<usize>(), n);
    Some(plan)
}

/// The pre-DP greedy cover (largest size first) — kept as the
/// property-test baseline: the DP must never plan MORE launches.
pub fn plan_batches_greedy(n: usize, sizes: &[usize]) -> Vec<usize> {
    assert!(sizes.contains(&1), "size-1 artifact must exist");
    let mut sorted: Vec<usize> = sizes.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let mut remaining = n;
    let mut plan = Vec::new();
    for &s in &sorted {
        while remaining >= s {
            plan.push(s);
            remaining -= s;
        }
    }
    debug_assert_eq!(plan.iter().sum::<usize>(), n);
    plan
}

/// Plan `n` requests against a backend's [`BatchSupport`]:
/// * `Any` — one exact launch of the whole batch;
/// * `Exact(sizes)` — min-launch DP over the supported sizes.  An
///   exact cover is used whenever one exists (aot.py always exports
///   size 1, so normally it does); only a genuinely uncoverable `n`
///   falls back to all-1 sub-batches, surfacing the missing
///   b1-artifact error at execute time instead of panicking here.
pub fn plan_support(n: usize, support: &BatchSupport)
                    -> Result<Vec<usize>> {
    match support {
        BatchSupport::Any => {
            Ok(if n == 0 { Vec::new() } else { vec![n] })
        }
        BatchSupport::Exact(sizes) => {
            ensure!(!sizes.is_empty(),
                    "no denoise artifacts for this combination — re-run \
                     `make artifacts`");
            Ok(plan_batches_any(n, sizes)
                .unwrap_or_else(|| vec![1; n]))
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn min_launch_plan_basic() {
        assert_eq!(plan_batches(6, &[1, 4]), vec![4, 1, 1]);
        assert_eq!(plan_batches(8, &[1, 4]), vec![4, 4]);
        assert_eq!(plan_batches(3, &[1, 2, 4]), vec![2, 1]);
        assert_eq!(plan_batches(0, &[1]), Vec::<usize>::new());
        // the case greedy gets wrong: {1,3,4} at 6 is [3,3], not
        // [4,1,1]
        assert_eq!(plan_batches(6, &[1, 3, 4]), vec![3, 3]);
        assert_eq!(plan_batches_greedy(6, &[1, 3, 4]), vec![4, 1, 1]);
        // ties prefer larger sizes (chain sets reproduce greedy)
        assert_eq!(plan_batches(12, &[1, 2, 4, 8]), vec![8, 4]);
    }

    #[test]
    fn plan_support_modes() {
        assert_eq!(plan_support(5, &BatchSupport::Any).unwrap(), vec![5]);
        assert_eq!(plan_support(0, &BatchSupport::Any).unwrap(),
                   Vec::<usize>::new());
        assert_eq!(
            plan_support(6, &BatchSupport::Exact(vec![1, 3, 4])).unwrap(),
            vec![3, 3]);
        // no size-1 artifact but the batch IS coverable: serve it
        assert_eq!(
            plan_support(4, &BatchSupport::Exact(vec![2])).unwrap(),
            vec![2, 2]);
        // genuinely uncoverable: fall back to all-1 sub-batches (the
        // missing b1 artifact then errors at execute, not here)
        assert_eq!(
            plan_support(3, &BatchSupport::Exact(vec![2])).unwrap(),
            vec![1, 1, 1]);
        assert!(plan_support(3, &BatchSupport::Exact(vec![])).is_err());
    }

    #[test]
    fn prop_plan_covers_exactly_and_beats_greedy() {
        check("plan-covers", 256,
              |r: &mut Pcg32| {
                  let n = r.below(40) as usize;
                  let mut sizes = vec![1usize];
                  for s in [2, 3, 4, 5, 8] {
                      if r.f32() < 0.5 {
                          sizes.push(s);
                      }
                  }
                  (n, sizes)
              },
              |(n, sizes)| {
                  let plan = plan_batches(*n, sizes);
                  if plan.iter().sum::<usize>() != *n {
                      return Err(format!("sum {} != n {n}",
                                         plan.iter().sum::<usize>()));
                  }
                  if let Some(bad) =
                      plan.iter().find(|s| !sizes.contains(s))
                  {
                      return Err(format!("unsupported size {bad}"));
                  }
                  if plan.windows(2).any(|w| w[0] < w[1]) {
                      return Err("plan not descending".into());
                  }
                  // optimality versus the greedy baseline: the DP may
                  // never need MORE launches
                  let greedy = plan_batches_greedy(*n, sizes);
                  if plan.len() > greedy.len() {
                      return Err(format!(
                          "DP used {} launches, greedy {} ({n} over \
                           {sizes:?})", plan.len(), greedy.len()));
                  }
                  Ok(())
              });
    }

    #[test]
    fn prop_plan_is_optimal_by_brute_force() {
        // exhaustive minimum over all covers for small n pins true
        // optimality, not just greedy-dominance
        fn best(n: usize, sizes: &[usize]) -> usize {
            let mut dp = vec![usize::MAX; n + 1];
            dp[0] = 0;
            for i in 1..=n {
                for &s in sizes {
                    if s <= i && dp[i - s] != usize::MAX {
                        dp[i] = dp[i].min(dp[i - s] + 1);
                    }
                }
            }
            dp[n]
        }
        check("plan-optimal", 128,
              |r: &mut Pcg32| {
                  let n = r.below(24) as usize;
                  let mut sizes = vec![1usize];
                  for s in [2, 3, 5, 7] {
                      if r.f32() < 0.5 {
                          sizes.push(s);
                      }
                  }
                  (n, sizes)
              },
              |(n, sizes)| {
                  let plan = plan_batches(*n, sizes);
                  let opt = best(*n, sizes);
                  if *n > 0 && plan.len() != opt {
                      return Err(format!("{} launches, optimum {opt}",
                                         plan.len()));
                  }
                  Ok(())
              });
    }
}
