//! Batch-size planning: map N compatible requests onto the batch sizes
//! the AOT artifacts actually support.
//!
//! XLA executables have static shapes, so a `denoise_*_b4` artifact
//! serves exactly 4 clips.  Given N requests and the supported size
//! set (from the manifest, e.g. {1, 4}), plan a greedy cover that
//! minimizes launches without padding (padding wastes a full sample's
//! compute; with size 1 always exported, an exact cover always exists).

/// Greedy plan: largest supported size first.  Returns batch sizes
/// summing exactly to `n`.  `sizes` must contain 1.
pub fn plan_batches(n: usize, sizes: &[usize]) -> Vec<usize> {
    assert!(sizes.contains(&1), "size-1 artifact must exist");
    let mut sorted: Vec<usize> = sizes.to_vec();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let mut remaining = n;
    let mut plan = Vec::new();
    for &s in &sorted {
        while remaining >= s {
            plan.push(s);
            remaining -= s;
        }
    }
    debug_assert_eq!(plan.iter().sum::<usize>(), n);
    plan
}

/// The artifact name for a (model, variant, tier, batch) combination —
/// single source of naming truth, mirrored by aot.py.
pub fn denoise_artifact_name(model: &str, variant: &str, tier: &str,
                             batch: usize) -> String {
    format!("denoise_{model}_{variant}_{tier}_b{batch}")
}

/// Supported batch sizes for (model, variant, tier) per the manifest.
pub fn supported_batch_sizes(
    manifest: &crate::runtime::Manifest, model: &str, variant: &str,
    tier: &str) -> Vec<usize> {
    let prefix = format!("denoise_{model}_{variant}_{tier}_b");
    let mut sizes: Vec<usize> = manifest
        .artifacts
        .keys()
        .filter_map(|name| name.strip_prefix(&prefix))
        .filter_map(|suffix| suffix.parse().ok())
        .collect();
    sizes.sort_unstable();
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn greedy_plan_basic() {
        assert_eq!(plan_batches(6, &[1, 4]), vec![4, 1, 1]);
        assert_eq!(plan_batches(8, &[1, 4]), vec![4, 4]);
        assert_eq!(plan_batches(3, &[1, 2, 4]), vec![2, 1]);
        assert_eq!(plan_batches(0, &[1]), Vec::<usize>::new());
    }

    #[test]
    fn artifact_naming() {
        assert_eq!(denoise_artifact_name("dit-tiny", "sla2", "s90", 2),
                   "denoise_dit-tiny_sla2_s90_b2");
    }

    #[test]
    fn prop_plan_covers_exactly() {
        check("plan-covers", 256,
              |r: &mut Pcg32| {
                  let n = r.below(40) as usize;
                  let mut sizes = vec![1usize];
                  if r.f32() < 0.7 { sizes.push(2); }
                  if r.f32() < 0.7 { sizes.push(4); }
                  if r.f32() < 0.3 { sizes.push(8); }
                  (n, sizes)
              },
              |(n, sizes)| {
                  let plan = plan_batches(*n, sizes);
                  if plan.iter().sum::<usize>() != *n {
                      return Err(format!("sum {} != n {n}",
                                         plan.iter().sum::<usize>()));
                  }
                  if let Some(bad) =
                      plan.iter().find(|s| !sizes.contains(s))
                  {
                      return Err(format!("unsupported size {bad}"));
                  }
                  // greedy optimality for {1, k} ladders: number of
                  // launches <= n (trivial) and descending order
                  if plan.windows(2).any(|w| w[0] < w[1]) {
                      return Err("plan not descending".into());
                  }
                  Ok(())
              });
    }
}
