//! Versioned wire codecs for the TCP frontend: the debug-readable v0
//! JSON framing and the binary v1 frame format, behind one
//! incremental [`FrameDecoder`] that auto-detects which one a peer
//! speaks.
//!
//! Both formats carry the SAME application-level frames (the verb
//! tables in [`super::net`]); only the bytes differ.  The codec is
//! therefore Json-in / Json-out: [`encode`] takes a frame's metadata
//! tree plus an optional out-of-band [`Tensor`], and the decoder hands
//! back a [`WireFrame`] holding both halves.  Server and client share
//! this module, so an encode-side layout change is caught by the same
//! golden vectors and property tests on both ends.
//!
//! # v0 (JSON, debug-readable)
//!
//! A 4-byte big-endian unsigned length `n` (capped at
//! [`MAX_FRAME_LEN`]) followed by `n` bytes of UTF-8 JSON.  Tensors
//! travel inline as `{"shape": [..], "data": [f32 as double, ..]}` —
//! lossless but ~5x the bytes of raw f32.  `nc`-friendly: you can
//! debug a server with a shell one-liner.
//!
//! # v1 (binary)
//!
//! A fixed 20-byte header, all multi-byte fields **little-endian**:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "SLA2" (0x53 0x4c 0x41 0x32)
//! 4       1     version (= 1)
//! 5       1     verb (see the verb table below)
//! 6       2     flags u16: bit0 = tensor bytes zrle-compressed,
//!                          bit1 = a tensor section follows the meta
//! 8       8     request id u64 (mirrors meta "id"; 0 when unscoped)
//! 16      4     payload length u32 (capped at MAX_FRAME_LEN)
//! ```
//!
//! The payload is a length-prefixed JSON **meta** section (the frame
//! minus its tensor field) and, when `FLAG_TENSOR` is set, a raw
//! tensor section:
//!
//! ```text
//! meta_len  u32   meta      meta_len bytes of UTF-8 JSON
//! dtype     u8    (0 = f32, 1 = i32)
//! ndim      u8    dims      ndim x u32
//! raw_len   u32   (uncompressed data bytes = numel x 4)
//! enc_len   u32   data      enc_len bytes, little-endian scalars,
//!                           zrle-compressed iff FLAG_COMPRESSED
//! ```
//!
//! Only `chunk` frames (tensor field `frames`) and `clip` frames
//! (tensor field `clip`) carry tensor sections.  The header id and
//! verb are redundant with the meta — they exist so a router can
//! dispatch without parsing JSON — and the decoder REJECTS frames
//! where they disagree, which also catches single-byte corruption.
//!
//! Verb table (`op` = client->server, `type` = server->client):
//!
//! | code | frame     | code | frame      | code | frame      |
//! |------|-----------|------|------------|------|------------|
//! | 0x01 | hello     | 0x81 | hello_ok   | 0x87 | metrics    |
//! | 0x02 | submit    | 0x82 | accepted   | 0x88 | cancel_ok  |
//! | 0x03 | cancel    | 0x83 | rejected   | 0x89 | health     |
//! | 0x04 | metrics   | 0x84 | chunk      | 0x8a | drain_ok   |
//! | 0x05 | health    | 0x85 | done       | 0x8b | goaway     |
//! | 0x06 | drain     | 0x86 | clip       | 0x8c | error      |
//!
//! Code [`VERB_X_JSON`] (0x7f) is the escape hatch: a frame whose
//! `op`/`type` is not in the table travels with its whole JSON body in
//! the meta section, so v1 is total over the same frame set as v0
//! (forward compatibility for verbs this build does not know).
//!
//! # Negotiation
//!
//! Per connection, by first byte: a v1 frame starts with `'S'`
//! (0x53), while a legal v0 length prefix starts with 0x00 or 0x01
//! (the cap is 16 MiB = 0x0100_0000).  The first frame latches the
//! connection's format; the server replies in kind.  Any other first
//! byte is a typed protocol error.  Clients default to v1 and may
//! request compression in their `hello`.
//!
//! # Compression
//!
//! `zrle` — a first-party zero-run-length scheme (the offline registry
//! has no flate2): literal bytes pass through; a 0x00 is followed by a
//! run length byte (1..=255).  The encoder only keeps the compressed
//! form when it is strictly smaller (sparse/padded tensors win;
//! dense noise does not), recorded per frame in `FLAG_COMPRESSED`.

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Hard cap on a single frame (v0 body or v1 payload), both
/// directions.  Far above any legitimate chunk on the testbed models;
/// anything larger is treated as a protocol violation and closes the
/// connection.
pub const MAX_FRAME_LEN: usize = 16 * 1024 * 1024;

/// v1 frame magic: the first four bytes of every binary frame.
pub const MAGIC: [u8; 4] = *b"SLA2";

/// The one binary wire version this build speaks.
pub const WIRE_VERSION: u8 = 1;

/// Fixed v1 header length in bytes.
pub const HEADER_LEN: usize = 20;

/// Header flag: the tensor section's data bytes are zrle-compressed.
pub const FLAG_COMPRESSED: u16 = 1 << 0;

/// Header flag: a tensor section follows the meta section.
pub const FLAG_TENSOR: u16 = 1 << 1;

/// Escape verb: the meta carries a frame whose `op`/`type` is not in
/// this build's verb table.
pub const VERB_X_JSON: u8 = 0x7f;

const DTYPE_F32: u8 = 0;
const DTYPE_I32: u8 = 1;

const REQUEST_VERBS: &[(u8, &str)] = &[
    (0x01, "hello"),
    (0x02, "submit"),
    (0x03, "cancel"),
    (0x04, "metrics"),
    (0x05, "health"),
    (0x06, "drain"),
];

const REPLY_VERBS: &[(u8, &str)] = &[
    (0x81, "hello_ok"),
    (0x82, "accepted"),
    (0x83, "rejected"),
    (0x84, "chunk"),
    (0x85, "done"),
    (0x86, "clip"),
    (0x87, "metrics"),
    (0x88, "cancel_ok"),
    (0x89, "health"),
    (0x8a, "drain_ok"),
    (0x8b, "goaway"),
    (0x8c, "error"),
];

/// Which codec a connection speaks.  Latched per connection by the
/// first byte the peer sends (servers) or chosen up front (clients).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    /// length-prefixed JSON (debug-readable)
    V0,
    /// binary frames with raw little-endian tensor payloads
    V1,
}

impl WireFormat {
    pub fn parse(s: &str) -> Result<WireFormat> {
        match s {
            "v0" | "json" => Ok(WireFormat::V0),
            "v1" | "binary" => Ok(WireFormat::V1),
            _ => bail!("unknown wire format {s:?} (valid: v0, v1)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            WireFormat::V0 => "v0",
            WireFormat::V1 => "v1",
        }
    }
}

/// One decoded frame: the JSON metadata plus, on the v1 path, the
/// out-of-band tensor.  v0 frames always arrive with `tensor: None`
/// (their tensors are inline in `meta`); consumers that need the
/// tensor regardless of path go through [`super::net::chunk_from_frame`]
/// / [`super::net::clip_from_frame`] or [`WireFrame::into_inline`].
#[derive(Debug, Clone)]
pub struct WireFrame {
    pub meta: Json,
    pub tensor: Option<Tensor>,
}

impl WireFrame {
    /// Wrap a plain JSON frame (no out-of-band tensor).
    pub fn from_json(meta: Json) -> WireFrame {
        WireFrame { meta, tensor: None }
    }

    /// The frame's verb string: `op` for requests, `type` for replies.
    pub fn verb(&self) -> Option<&str> {
        self.meta.get("op").and_then(|v| v.as_str())
            .or_else(|| self.meta.get("type").and_then(|v| v.as_str()))
    }

    /// The request id this frame is scoped to, if any.
    pub fn id(&self) -> Option<u64> {
        self.meta.get("id").and_then(|v| v.as_f64()).map(|v| v as u64)
    }

    /// Fold the out-of-band tensor back into the JSON tree (under the
    /// verb's tensor key), yielding the exact shape a v0 frame has.
    /// Costly for large tensors — prefer the typed accessors.
    pub fn into_inline(self) -> Result<Json> {
        match self.tensor {
            None => Ok(self.meta),
            Some(t) => {
                let verb = verb_of(&self.meta);
                let key = tensor_key(verb).with_context(|| {
                    format!("verb 0x{verb:02x} cannot carry a tensor")
                })?;
                Ok(self.meta.push(key, tensor_to_json(&t)?))
            }
        }
    }
}

/// The v1 verb code for a frame body: its `op`/`type` looked up in
/// the verb table, or [`VERB_X_JSON`] when absent or unknown.
pub fn verb_of(meta: &Json) -> u8 {
    if let Some(op) = meta.get("op").and_then(|v| v.as_str()) {
        lookup(REQUEST_VERBS, op)
    } else if let Some(ty) = meta.get("type").and_then(|v| v.as_str()) {
        lookup(REPLY_VERBS, ty)
    } else {
        VERB_X_JSON
    }
}

fn lookup(table: &[(u8, &str)], name: &str) -> u8 {
    table.iter().find(|(_, n)| *n == name).map(|(c, _)| *c)
        .unwrap_or(VERB_X_JSON)
}

/// The JSON key a verb's tensor section maps to (`chunk` and `clip`
/// frames only).
pub fn tensor_key(verb: u8) -> Option<&'static str> {
    match verb {
        0x84 => Some("frames"),
        0x86 => Some("clip"),
        _ => None,
    }
}

// ---------------- JSON <-> tensor ---------------------------------------

/// Inline JSON tensor form (the v0 representation): lossless for f32 —
/// every f32 is exactly representable as a double and the writer emits
/// shortest-roundtrip decimals.
pub fn tensor_to_json(t: &Tensor) -> Result<Json> {
    let data: Vec<Json> =
        t.f32s()?.iter().map(|v| Json::Num(*v as f64)).collect();
    Ok(Json::obj()
        .push("shape", t.shape.as_slice())
        .push("data", data))
}

pub fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape = j.req("shape")?.as_usize_vec()
        .context("tensor shape")?;
    let data: Vec<f32> = j.req("data")?.as_arr()
        .context("tensor data")?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .context("non-numeric tensor data")?;
    Tensor::from_f32(&shape, data)
}

// ---------------- zrle compression --------------------------------------

/// Zero-run-length encode: literal bytes pass through; each 0x00 is
/// followed by a run length (1..=255).  Worst case (no zeros) is the
/// input unchanged; all-zero input compresses 128:1.
pub fn zrle_compress(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() / 2 + 16);
    let mut i = 0;
    while i < raw.len() {
        let b = raw[i];
        if b == 0 {
            let mut run = 1usize;
            while run < 255 && i + run < raw.len() && raw[i + run] == 0 {
                run += 1;
            }
            out.push(0);
            out.push(run as u8);
            i += run;
        } else {
            out.push(b);
            i += 1;
        }
    }
    out
}

/// Decode a zrle stream that must expand to exactly `expect` bytes
/// (the header's `raw_len`); anything else is a protocol error.
pub fn zrle_decompress(enc: &[u8], expect: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expect);
    let mut i = 0;
    while i < enc.len() {
        let b = enc[i];
        if b == 0 {
            anyhow::ensure!(i + 1 < enc.len(), "zrle: truncated zero run");
            let run = enc[i + 1] as usize;
            anyhow::ensure!(run > 0, "zrle: zero-length run");
            anyhow::ensure!(out.len() + run <= expect,
                            "zrle: output exceeds declared length");
            out.resize(out.len() + run, 0);
            i += 2;
        } else {
            anyhow::ensure!(out.len() < expect,
                            "zrle: output exceeds declared length");
            out.push(b);
            i += 1;
        }
    }
    anyhow::ensure!(out.len() == expect,
                    "zrle: output is {} bytes, header declared {expect}",
                    out.len());
    Ok(out)
}

// ---------------- encode ------------------------------------------------

/// Encode one frame.  `tensor` rides out-of-band on v1 (raw
/// little-endian, optionally compressed) and is folded inline into
/// the JSON on v0; only `chunk`/`clip` verbs may carry one.
pub fn encode(meta: &Json, tensor: Option<&Tensor>, wire: WireFormat,
              compress: bool) -> Result<Vec<u8>> {
    match wire {
        WireFormat::V0 => encode_v0(meta, tensor),
        WireFormat::V1 => encode_v1(meta, tensor, compress),
    }
}

fn encode_v0(meta: &Json, tensor: Option<&Tensor>) -> Result<Vec<u8>> {
    let body = match tensor {
        None => meta.to_string(),
        Some(t) => {
            let verb = verb_of(meta);
            let key = tensor_key(verb).with_context(|| {
                format!("verb 0x{verb:02x} cannot carry a tensor")
            })?;
            meta.clone().push(key, tensor_to_json(t)?).to_string()
        }
    };
    anyhow::ensure!(body.len() <= MAX_FRAME_LEN,
                    "frame of {} bytes exceeds the {} byte cap",
                    body.len(), MAX_FRAME_LEN);
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body.as_bytes());
    Ok(out)
}

fn encode_v1(meta: &Json, tensor: Option<&Tensor>, compress: bool)
             -> Result<Vec<u8>> {
    let verb = verb_of(meta);
    let text = meta.to_string();
    let mut flags: u16 = 0;
    let mut tensor_sec = Vec::new();
    if let Some(t) = tensor {
        anyhow::ensure!(tensor_key(verb).is_some(),
                        "verb 0x{verb:02x} cannot carry a tensor");
        flags |= FLAG_TENSOR;
        encode_tensor_section(t, compress, &mut tensor_sec, &mut flags)?;
    }
    let payload_len = 4 + text.len() + tensor_sec.len();
    anyhow::ensure!(payload_len <= MAX_FRAME_LEN,
                    "frame of {payload_len} bytes exceeds the \
                     {MAX_FRAME_LEN} byte cap");
    let id = meta.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let mut out = Vec::with_capacity(HEADER_LEN + payload_len);
    out.extend_from_slice(&MAGIC);
    out.push(WIRE_VERSION);
    out.push(verb);
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&(text.len() as u32).to_le_bytes());
    out.extend_from_slice(text.as_bytes());
    out.extend_from_slice(&tensor_sec);
    Ok(out)
}

fn encode_tensor_section(t: &Tensor, compress: bool, out: &mut Vec<u8>,
                         flags: &mut u16) -> Result<()> {
    let (dtype, raw): (u8, Vec<u8>) = if t.is_f32() {
        let mut b = Vec::with_capacity(t.numel() * 4);
        for v in t.f32s()? {
            b.extend_from_slice(&v.to_le_bytes());
        }
        (DTYPE_F32, b)
    } else {
        let mut b = Vec::with_capacity(t.numel() * 4);
        for v in t.i32s()? {
            b.extend_from_slice(&v.to_le_bytes());
        }
        (DTYPE_I32, b)
    };
    anyhow::ensure!(t.shape.len() <= u8::MAX as usize,
                    "tensor rank {} exceeds the wire cap", t.shape.len());
    anyhow::ensure!(raw.len() <= MAX_FRAME_LEN,
                    "tensor of {} bytes exceeds the {} byte cap",
                    raw.len(), MAX_FRAME_LEN);
    out.push(dtype);
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        anyhow::ensure!(d <= u32::MAX as usize,
                        "tensor dim {d} overflows u32");
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
    let enc = if compress {
        let z = zrle_compress(&raw);
        if z.len() < raw.len() {
            *flags |= FLAG_COMPRESSED;
            z
        } else {
            raw
        }
    } else {
        raw
    };
    out.extend_from_slice(&(enc.len() as u32).to_le_bytes());
    out.extend_from_slice(&enc);
    Ok(())
}

fn decode_tensor_section(b: &[u8], compressed: bool, max_len: usize)
                         -> Result<(Tensor, usize)> {
    anyhow::ensure!(b.len() >= 2, "truncated tensor section");
    let dtype = b[0];
    let ndim = b[1] as usize;
    let mut off = 2;
    anyhow::ensure!(b.len() >= off + ndim * 4 + 8,
                    "truncated tensor dims");
    let mut shape = Vec::with_capacity(ndim);
    let mut numel: usize = 1;
    for _ in 0..ndim {
        let d = u32l(&b[off..off + 4]) as usize;
        off += 4;
        numel = numel.checked_mul(d)
            .context("tensor element count overflows")?;
        shape.push(d);
    }
    let raw_len = u32l(&b[off..off + 4]) as usize;
    off += 4;
    let enc_len = u32l(&b[off..off + 4]) as usize;
    off += 4;
    anyhow::ensure!(raw_len <= max_len,
                    "oversized tensor: {raw_len} bytes (cap {max_len})");
    anyhow::ensure!(Some(raw_len) == numel.checked_mul(4),
                    "tensor data length {raw_len} does not match \
                     {numel} elements x 4 bytes");
    anyhow::ensure!(b.len() >= off + enc_len, "truncated tensor data");
    let enc = &b[off..off + enc_len];
    off += enc_len;
    let raw: Vec<u8> = if compressed {
        zrle_decompress(enc, raw_len)?
    } else {
        anyhow::ensure!(enc_len == raw_len,
                        "tensor data is {enc_len} bytes, header \
                         declared {raw_len}");
        enc.to_vec()
    };
    let t = match dtype {
        DTYPE_F32 => {
            let data: Vec<f32> = raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::from_f32(&shape, data)?
        }
        DTYPE_I32 => {
            let data: Vec<i32> = raw.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Tensor::from_i32(&shape, data)?
        }
        d => bail!("bad tensor dtype {d} (valid: 0 = f32, 1 = i32)"),
    };
    Ok((t, off))
}

fn u32l(b: &[u8]) -> u32 {
    let mut a = [0u8; 4];
    a.copy_from_slice(&b[..4]);
    u32::from_le_bytes(a)
}

fn u64l(b: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[..8]);
    u64::from_le_bytes(a)
}

// ---------------- decode ------------------------------------------------

/// Incremental frame decoder: feed it raw socket bytes, pull complete
/// frames.  The first byte latches the connection's [`WireFormat`]
/// (unless fixed up front with [`FrameDecoder::with_format`]).
///
/// `next` returns `Ok(None)` when more bytes are needed and `Err` on a
/// protocol violation — after which the byte stream cannot be
/// resynchronized: the decoder latches poisoned and the connection
/// must be dropped (the server sends a typed `bad_request` first).
pub struct FrameDecoder {
    buf: Vec<u8>,
    start: usize,
    wire: Option<WireFormat>,
    max_len: usize,
    poisoned: bool,
}

impl Default for FrameDecoder {
    fn default() -> Self {
        FrameDecoder::new()
    }
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder {
            buf: Vec::new(),
            start: 0,
            wire: None,
            max_len: MAX_FRAME_LEN,
            poisoned: false,
        }
    }

    /// A decoder pinned to one format (no first-byte detection).
    pub fn with_format(wire: WireFormat) -> FrameDecoder {
        FrameDecoder { wire: Some(wire), ..FrameDecoder::new() }
    }

    /// Lower the frame cap (tests of the oversized path).
    pub fn with_max_len(max_len: usize) -> FrameDecoder {
        FrameDecoder { max_len, ..FrameDecoder::new() }
    }

    /// The format latched so far, if any.
    pub fn wire(&self) -> Option<WireFormat> {
        self.wire
    }

    /// Bytes fed but not yet consumed by a decoded frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn feed(&mut self, bytes: &[u8]) {
        // compact: drop consumed bytes once they dominate the buffer
        if self.start > 0
            && (self.start >= self.buf.len() || self.start > 64 * 1024)
        {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pull the next complete frame: `Ok(None)` = need more bytes.
    pub fn next(&mut self) -> Result<Option<WireFrame>> {
        anyhow::ensure!(!self.poisoned,
                        "wire decoder poisoned by an earlier framing \
                         error");
        let r = self.try_next();
        if r.is_err() {
            self.poisoned = true;
        }
        r
    }

    fn try_next(&mut self) -> Result<Option<WireFrame>> {
        let first = match self.buf.get(self.start) {
            Some(b) => *b,
            None => return Ok(None),
        };
        let wire = match self.wire {
            Some(w) => w,
            None => {
                // a v1 frame starts with 'S'; a legal v0 BE length
                // prefix (cap 16 MiB = 0x0100_0000) starts 0x00/0x01
                let w = match first {
                    0x53 => WireFormat::V1,
                    0x00 | 0x01 => WireFormat::V0,
                    b => bail!("unknown wire format (first byte \
                                0x{b:02x}; expected a v0 length prefix \
                                or v1 magic \"SLA2\")"),
                };
                self.wire = Some(w);
                w
            }
        };
        match wire {
            WireFormat::V0 => self.next_v0(),
            WireFormat::V1 => self.next_v1(),
        }
    }

    fn next_v0(&mut self) -> Result<Option<WireFrame>> {
        let avail = self.buf.len() - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let h = &self.buf[self.start..self.start + 4];
        let len = u32::from_be_bytes([h[0], h[1], h[2], h[3]]) as usize;
        anyhow::ensure!(len <= self.max_len,
                        "oversized frame: {len} bytes (cap {})",
                        self.max_len);
        if avail < 4 + len {
            return Ok(None);
        }
        let body = &self.buf[self.start + 4..self.start + 4 + len];
        let text = std::str::from_utf8(body)
            .context("frame is not UTF-8")?;
        let meta = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("malformed frame: {e}"))?;
        self.start += 4 + len;
        Ok(Some(WireFrame { meta, tensor: None }))
    }

    fn next_v1(&mut self) -> Result<Option<WireFrame>> {
        let avail = self.buf.len() - self.start;
        if avail < HEADER_LEN {
            return Ok(None);
        }
        let h = &self.buf[self.start..self.start + HEADER_LEN];
        anyhow::ensure!(h[..4] == MAGIC,
                        "bad magic {:02x?} (expected \"SLA2\")", &h[..4]);
        anyhow::ensure!(h[4] == WIRE_VERSION,
                        "unsupported wire version {} (this build \
                         speaks {WIRE_VERSION})", h[4]);
        let verb = h[5];
        let flags = u16::from_le_bytes([h[6], h[7]]);
        let id = u64l(&h[8..16]);
        let payload_len = u32l(&h[16..20]) as usize;
        anyhow::ensure!(payload_len <= self.max_len,
                        "oversized frame: {payload_len} bytes (cap {})",
                        self.max_len);
        anyhow::ensure!(flags & !(FLAG_COMPRESSED | FLAG_TENSOR) == 0,
                        "unknown flag bits 0x{flags:04x}");
        if avail < HEADER_LEN + payload_len {
            return Ok(None);
        }
        let p = &self.buf
            [self.start + HEADER_LEN..self.start + HEADER_LEN + payload_len];
        anyhow::ensure!(p.len() >= 4, "truncated meta section");
        let meta_len = u32l(&p[..4]) as usize;
        anyhow::ensure!(meta_len <= p.len() - 4,
                        "meta length {meta_len} overruns the payload");
        let text = std::str::from_utf8(&p[4..4 + meta_len])
            .context("frame meta is not UTF-8")?;
        let meta = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("malformed frame meta: {e}"))?;
        anyhow::ensure!(verb_of(&meta) == verb,
                        "verb byte 0x{verb:02x} does not match the \
                         frame body");
        let mut off = 4 + meta_len;
        let tensor = if flags & FLAG_TENSOR != 0 {
            anyhow::ensure!(tensor_key(verb).is_some(),
                            "verb 0x{verb:02x} cannot carry a tensor \
                             section");
            let (t, used) = decode_tensor_section(
                &p[off..], flags & FLAG_COMPRESSED != 0, self.max_len)?;
            off += used;
            Some(t)
        } else {
            anyhow::ensure!(flags & FLAG_COMPRESSED == 0,
                            "COMPRESSED flag without a tensor section");
            None
        };
        anyhow::ensure!(off == payload_len,
                        "payload length mismatch: consumed {off} of \
                         {payload_len} bytes");
        let meta_id = meta.get("id").and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        anyhow::ensure!(id == meta_id,
                        "header id {id} does not match the frame \
                         body's {meta_id}");
        self.start += HEADER_LEN + payload_len;
        Ok(Some(WireFrame { meta, tensor }))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;

    fn decode_one(bytes: &[u8]) -> WireFrame {
        let mut d = FrameDecoder::new();
        d.feed(bytes);
        let f = d.next().unwrap().unwrap();
        assert_eq!(d.buffered(), 0, "trailing bytes after one frame");
        f
    }

    #[test]
    fn verb_table_is_bijective_and_direction_tagged() {
        let mut seen = std::collections::HashSet::new();
        for (code, name) in REQUEST_VERBS {
            assert!(seen.insert(*code), "duplicate code {code:#x}");
            assert_eq!(*code & 0x80, 0, "{name}: request high bit");
            let meta = Json::obj().push("op", *name);
            assert_eq!(verb_of(&meta), *code);
        }
        for (code, name) in REPLY_VERBS {
            assert!(seen.insert(*code), "duplicate code {code:#x}");
            assert_eq!(*code & 0x80, 0x80, "{name}: reply high bit");
            let meta = Json::obj().push("type", *name);
            assert_eq!(verb_of(&meta), *code);
        }
        assert!(!seen.contains(&VERB_X_JSON));
        assert_eq!(verb_of(&Json::obj().push("op", "frobnicate")),
                   VERB_X_JSON);
        assert_eq!(verb_of(&Json::obj()), VERB_X_JSON);
    }

    #[test]
    fn v1_layout_is_pinned() {
        // {"op":"cancel","id":7} — hand-check every header field
        let meta = Json::obj().push("op", "cancel").push("id", 7usize);
        let text = meta.to_string();
        assert_eq!(text, r#"{"op":"cancel","id":7}"#);
        let b = encode(&meta, None, WireFormat::V1, false).unwrap();
        assert_eq!(&b[..4], b"SLA2");
        assert_eq!(b[4], 1, "version");
        assert_eq!(b[5], 0x03, "cancel verb");
        assert_eq!(&b[6..8], &[0, 0], "flags");
        assert_eq!(&b[8..16], &7u64.to_le_bytes(), "id LE");
        let payload_len = (4 + text.len()) as u32;
        assert_eq!(&b[16..20], &payload_len.to_le_bytes());
        assert_eq!(&b[20..24], &(text.len() as u32).to_le_bytes());
        assert_eq!(&b[24..], text.as_bytes());
        let back = decode_one(&b);
        assert_eq!(back.meta, meta);
        assert!(back.tensor.is_none());
    }

    #[test]
    fn every_verb_roundtrips_both_formats() {
        let mut metas: Vec<Json> = Vec::new();
        for (_, name) in REQUEST_VERBS {
            metas.push(Json::obj().push("op", *name).push("id", 3usize));
        }
        for (_, name) in REPLY_VERBS {
            metas.push(Json::obj().push("type", *name)
                       .push("id", 9usize).push("x", 1.5));
        }
        // unknown verbs travel via the x-json escape
        metas.push(Json::obj().push("op", "frobnicate").push("k", true));
        metas.push(Json::obj().push("weird", "no verb at all"));
        for meta in &metas {
            for wire in [WireFormat::V0, WireFormat::V1] {
                let b = encode(meta, None, wire, false).unwrap();
                let f = decode_one(&b);
                assert_eq!(&f.meta, meta, "{wire:?}");
                assert!(f.tensor.is_none());
            }
        }
    }

    #[test]
    fn tensors_roundtrip_bit_identically() {
        check("wire-tensor-roundtrip", 48, |r| {
            let ndim = 1 + r.below(3) as usize;
            let shape: Vec<usize> =
                (0..ndim).map(|_| r.below(5) as usize).collect();
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| {
                match r.below(8) {
                    0 => 0.0,
                    1 => f32::NAN,
                    2 => f32::INFINITY,
                    3 => f32::MIN_POSITIVE / 2.0, // subnormal
                    _ => r.normal() as f32,
                }
            }).collect();
            let compress = r.below(2) == 0;
            (Tensor::from_f32(&shape, data).unwrap(), compress)
        }, |(t, compress)| {
            let meta = Json::obj().push("type", "chunk")
                .push("id", 5usize).push("last", true);
            let b = encode(&meta, Some(t), WireFormat::V1, *compress)
                .map_err(|e| e.to_string())?;
            let f = decode_one(&b);
            let back = f.tensor.as_ref().ok_or("no tensor")?;
            if back.shape != t.shape {
                return Err(format!("shape {:?} != {:?}",
                                   back.shape, t.shape));
            }
            // compare BITS: NaN payloads must survive, which Tensor's
            // PartialEq (f32 ==) cannot express
            let a: Vec<u32> = t.f32s().unwrap().iter()
                .map(|v| v.to_bits()).collect();
            let c: Vec<u32> = back.f32s().unwrap().iter()
                .map(|v| v.to_bits()).collect();
            if a == c { Ok(()) } else { Err("bits differ".into()) }
        });
    }

    #[test]
    fn i32_tensors_roundtrip() {
        let t = Tensor::from_i32(&[2, 3], vec![-5, 0, 0, 0, 7, 123])
            .unwrap();
        let meta = Json::obj().push("type", "chunk").push("id", 1usize);
        for compress in [false, true] {
            let b = encode(&meta, Some(&t), WireFormat::V1, compress)
                .unwrap();
            let f = decode_one(&b);
            assert_eq!(f.tensor.unwrap().i32s().unwrap(),
                       t.i32s().unwrap());
        }
    }

    #[test]
    fn empty_and_huge_ish_tensors_roundtrip() {
        // empty: zero elements, still carries shape
        let t = Tensor::from_f32(&[0, 4], vec![]).unwrap();
        let meta = Json::obj().push("type", "clip").push("id", 2usize);
        let f = decode_one(
            &encode(&meta, Some(&t), WireFormat::V1, true).unwrap());
        assert_eq!(f.tensor.unwrap().shape, vec![0, 4]);
        // large-ish (256 KiB raw) — exercises the length fields
        let mut rng = Pcg32::seeded(11);
        let big = Tensor::randn(&[64, 32, 32], &mut rng);
        let f = decode_one(
            &encode(&meta, Some(&big), WireFormat::V1, false).unwrap());
        assert_eq!(f.tensor.unwrap(), big);
    }

    #[test]
    fn compression_flag_is_honest() {
        let meta = Json::obj().push("type", "chunk").push("id", 1usize);
        // zero-heavy tensor: compression must engage and shrink
        let zeros = Tensor::from_f32(&[1024], vec![0.0; 1024]).unwrap();
        let plain = encode(&meta, Some(&zeros), WireFormat::V1, false)
            .unwrap();
        let packed = encode(&meta, Some(&zeros), WireFormat::V1, true)
            .unwrap();
        assert!(packed.len() < plain.len() / 10,
                "zrle on zeros: {} vs {}", packed.len(), plain.len());
        assert_eq!(u16::from_le_bytes([packed[6], packed[7]]),
                   FLAG_COMPRESSED | FLAG_TENSOR);
        assert_eq!(decode_one(&packed).tensor.unwrap(), zeros);
        // dense noise: zrle cannot win, the flag must stay clear
        let mut rng = Pcg32::seeded(3);
        let noise = Tensor::randn(&[1024], &mut rng);
        let b = encode(&meta, Some(&noise), WireFormat::V1, true).unwrap();
        assert_eq!(u16::from_le_bytes([b[6], b[7]]) & FLAG_COMPRESSED, 0);
        assert_eq!(decode_one(&b).tensor.unwrap(), noise);
    }

    #[test]
    fn zrle_roundtrips_and_rejects_bad_streams() {
        check("zrle-roundtrip", 64, |r| {
            let n = r.below(512) as usize;
            (0..n).map(|_| {
                if r.below(3) == 0 { 0u8 } else { (r.below(255) + 1) as u8 }
            }).collect::<Vec<u8>>()
        }, |raw| {
            let enc = zrle_compress(raw);
            let back = zrle_decompress(&enc, raw.len())
                .map_err(|e| e.to_string())?;
            if back == *raw { Ok(()) } else { Err("mismatch".into()) }
        });
        assert!(zrle_decompress(&[0], 4).is_err(), "truncated run");
        assert!(zrle_decompress(&[0, 0], 4).is_err(), "zero-length run");
        assert!(zrle_decompress(&[0, 200], 4).is_err(), "overlong run");
        assert!(zrle_decompress(&[1, 2], 4).is_err(), "short output");
    }

    #[test]
    fn v0_frames_interop_with_the_legacy_reader() {
        // FrameDecoder's v0 path and net::read_frame parse the same
        // bytes to the same tree
        let meta = Json::obj().push("op", "metrics").push("x", 1.5);
        let b = encode(&meta, None, WireFormat::V0, false).unwrap();
        let legacy = super::super::net::read_frame(
            &mut std::io::Cursor::new(&b), MAX_FRAME_LEN)
            .unwrap().unwrap();
        assert_eq!(legacy, meta);
        assert_eq!(decode_one(&b).meta, meta);
    }

    #[test]
    fn incremental_single_byte_feeding_yields_identical_frames() {
        let mut rng = Pcg32::seeded(7);
        let t = Tensor::randn(&[2, 3, 4], &mut rng);
        let meta = Json::obj().push("type", "chunk")
            .push("id", 42usize).push("seq", 0usize);
        let mut all = Vec::new();
        all.extend(encode(&meta, Some(&t), WireFormat::V1, true).unwrap());
        all.extend(encode(&Json::obj().push("op", "health"), None,
                          WireFormat::V1, false).unwrap());
        let mut d = FrameDecoder::new();
        let mut frames = Vec::new();
        for b in &all {
            d.feed(std::slice::from_ref(b));
            while let Some(f) = d.next().unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].meta, meta);
        assert_eq!(frames[0].tensor.as_ref().unwrap(), &t);
        assert_eq!(frames[1].meta, Json::obj().push("op", "health"));
    }

    #[test]
    fn truncated_prefixes_never_error_or_yield() {
        let meta = Json::obj().push("op", "submit").push("seed", 3.0);
        for wire in [WireFormat::V0, WireFormat::V1] {
            let full = encode(&meta, None, wire, false).unwrap();
            for cut in 0..full.len() {
                let mut d = FrameDecoder::new();
                d.feed(&full[..cut]);
                assert!(d.next().unwrap().is_none(),
                        "prefix {cut}/{} yielded a frame", full.len());
            }
        }
    }

    #[test]
    fn malformed_frames_yield_typed_errors_and_poison() {
        // unknown first byte
        let mut d = FrameDecoder::new();
        d.feed(b"GET / HTTP/1.1\r\n");
        let e = d.next().unwrap_err().to_string();
        assert!(e.contains("unknown wire format"), "{e}");
        assert!(d.next().is_err(), "poisoned decoder must stay dead");

        // bad magic after latching v1
        let mut d = FrameDecoder::with_format(WireFormat::V1);
        d.feed(b"SLAQxxxxxxxxxxxxxxxxxxxx");
        let e = d.next().unwrap_err().to_string();
        assert!(e.contains("bad magic"), "{e}");

        // wrong version
        let good = encode(&Json::obj().push("op", "health"), None,
                          WireFormat::V1, false).unwrap();
        let mut bad = good.clone();
        bad[4] = 9;
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        let e = d.next().unwrap_err().to_string();
        assert!(e.contains("unsupported wire version 9"), "{e}");

        // oversized payload length
        let mut bad = good.clone();
        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        let e = d.next().unwrap_err().to_string();
        assert!(e.contains("oversized frame"), "{e}");

        // verb byte contradicting the body
        let mut bad = good.clone();
        bad[5] = 0x02; // claims submit, body says health
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        let e = d.next().unwrap_err().to_string();
        assert!(e.contains("does not match"), "{e}");

        // header id contradicting the body
        let good = encode(&Json::obj().push("op", "cancel")
                          .push("id", 7usize), None,
                          WireFormat::V1, false).unwrap();
        let mut bad = good.clone();
        bad[8] = 99;
        let mut d = FrameDecoder::new();
        d.feed(&bad);
        let e = d.next().unwrap_err().to_string();
        assert!(e.contains("header id"), "{e}");
    }

    #[test]
    fn v1_is_at_least_4x_smaller_than_v0_on_f32_clips() {
        // the acceptance headline says >= 5x on realistic clips; pin a
        // conservative 4x here so the unit test is not flaky across
        // formatting changes, and let the fig5 `wire_serde` section
        // report the real ratio
        let mut rng = Pcg32::seeded(17);
        let clip = Tensor::randn(&[4, 16, 16, 3], &mut rng);
        let meta = Json::obj().push("type", "clip").push("id", 1usize);
        let v0 = encode(&meta, Some(&clip), WireFormat::V0, false)
            .unwrap();
        let v1 = encode(&meta, Some(&clip), WireFormat::V1, false)
            .unwrap();
        let ratio = v0.len() as f64 / v1.len() as f64;
        assert!(ratio >= 4.0,
                "v0 {} bytes / v1 {} bytes = {ratio:.2}x",
                v0.len(), v1.len());
    }

    #[test]
    fn into_inline_matches_the_v0_tree() {
        let t = Tensor::from_f32(&[1, 2], vec![0.25, -1.5]).unwrap();
        let meta = Json::obj().push("type", "clip").push("id", 4usize);
        let f = decode_one(
            &encode(&meta, Some(&t), WireFormat::V1, false).unwrap());
        let inline = f.into_inline().unwrap();
        assert_eq!(tensor_from_json(inline.req("clip").unwrap()).unwrap(),
                   t);
    }

    #[test]
    fn wire_format_parses() {
        assert_eq!(WireFormat::parse("v0").unwrap(), WireFormat::V0);
        assert_eq!(WireFormat::parse("json").unwrap(), WireFormat::V0);
        assert_eq!(WireFormat::parse("v1").unwrap(), WireFormat::V1);
        assert_eq!(WireFormat::parse("binary").unwrap(), WireFormat::V1);
        assert!(WireFormat::parse("v2").is_err());
    }
}
