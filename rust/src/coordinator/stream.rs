//! Streaming clip delivery: the chunked half of the reply path.
//!
//! A finished clip used to travel as ONE monolithic [`GenResponse`].
//! This module splits delivery into [`ClipChunk`]s — contiguous frame
//! ranges of the final clip, tagged with sequence numbers and
//! per-chunk metrics — flowing through a bounded channel from the
//! serving shard to a [`ClipStream`] handle the client polls.
//!
//! Semantics:
//!
//! * **Chunks are frame ranges of the FINAL clip.**  Full-clip
//!   diffusion denoises every frame of a sub-batch together, so frames
//!   become final at that sub-batch's last sampling step; what
//!   streaming buys is that each request's frames leave the shard the
//!   moment its sub-batch finishes — before the rest of the dispatched
//!   batch is served, before server-side bookkeeping, and (over the
//!   TCP frontend) while later frames are still in flight.
//!   `ServeConfig::chunk_frames` sets the range granularity
//!   (`0` = the whole clip as one chunk).
//! * **Reassembly is exact.**  [`assemble_response`] concatenates the
//!   ranges back into a clip that is byte-identical to the one-shot
//!   result for the same seed — the one-shot reply path itself is a
//!   thin wrapper over this module (chunk, then reassemble), so every
//!   one-shot request exercises the stream invariants.
//! * **Bounded backpressure.**  The channel holds at most
//!   `ServeConfig::stream_buffer_chunks` chunks; a producer ahead of
//!   its consumer blocks rather than buffering a whole clip per slow
//!   client.
//! * **Cancel-on-drop.**  Dropping a [`ClipStream`] (or an explicit
//!   [`StreamCancel::cancel`]) sets a shared flag AND closes the
//!   receiver: an in-flight send fails immediately, the shard stops
//!   emitting for that request, and a batch whose every request is
//!   cancelled is skipped without compute — an abandoned client frees
//!   its shard slot instead of pinning it.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::error::ServeError;
use super::request::{GenResponse, RequestMetrics};
use crate::tensor::Tensor;

/// One contiguous frame range of a generated clip.
#[derive(Debug, Clone)]
pub struct ClipChunk {
    /// request id this chunk belongs to
    pub id: u64,
    /// 0-based chunk index; chunks arrive in `seq` order
    pub seq: usize,
    /// first frame (inclusive) of the range
    pub frame_start: usize,
    /// one past the last frame of the range
    pub frame_end: usize,
    /// total frames in the full clip (same on every chunk)
    pub total_frames: usize,
    /// set on the final chunk of the clip
    pub last: bool,
    /// `[frame_end - frame_start, H, W, C]` frame data
    pub frames: Tensor,
    /// request-level service metrics (repeated on every chunk so a
    /// consumer that only keeps the first chunk still sees them)
    pub metrics: RequestMetrics,
}

/// What a delivery attempt did (the producer-side outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendOutcome {
    /// all chunks were handed to the stream (count included)
    Delivered(usize),
    /// the client cancelled / dropped the stream; delivery stopped
    Cancelled,
}

/// Producer half: owned by the reply path, travels through the queue
/// inside the request envelope.
#[derive(Debug)]
pub struct ChunkSender {
    id: u64,
    chunk_frames: usize,
    tx: SyncSender<Result<ClipChunk, ServeError>>,
    cancelled: Arc<AtomicBool>,
}

/// Consumer half: yields chunks in order; dropping it cancels the
/// stream.
#[derive(Debug)]
pub struct ClipStream {
    id: u64,
    rx: Receiver<Result<ClipChunk, ServeError>>,
    cancelled: Arc<AtomicBool>,
}

/// Cloneable cancel handle (e.g. for a connection registry that must
/// cancel a stream whose `ClipStream` lives on a pump thread).
#[derive(Debug, Clone)]
pub struct StreamCancel(Arc<AtomicBool>);

impl StreamCancel {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Build a bounded chunk channel for request `id`.
///
/// `chunk_frames` is the frames-per-chunk granularity (`0` = whole
/// clip in one chunk); `buffer_chunks` bounds how many chunks may sit
/// in flight before the producer blocks (floored at 1).
pub fn channel(id: u64, chunk_frames: usize, buffer_chunks: usize)
               -> (ChunkSender, ClipStream) {
    let (tx, rx) = sync_channel(buffer_chunks.max(1));
    let cancelled = Arc::new(AtomicBool::new(false));
    (ChunkSender { id, chunk_frames, tx,
                   cancelled: Arc::clone(&cancelled) },
     ClipStream { id, rx, cancelled })
}

impl ChunkSender {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// True once the consumer dropped its stream or called cancel.
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Chunk `clip` into frame ranges and send them in order.
    ///
    /// Blocks when the buffer is full (bounded backpressure).  Stops
    /// early — and reports [`SendOutcome::Cancelled`] — the moment the
    /// cancel flag is set or the receiver is gone, so a shard never
    /// stalls on an abandoned client.
    pub fn send_clip(&self, clip: Tensor, metrics: &RequestMetrics)
                     -> SendOutcome {
        if self.is_cancelled() {
            return SendOutcome::Cancelled;
        }
        let chunks = match chunk_clip(self.id, clip, metrics,
                                      self.chunk_frames) {
            Ok(c) => c,
            Err(e) => {
                self.send_error(ServeError::shard_fatal(format!("{e:#}")));
                return SendOutcome::Cancelled;
            }
        };
        let mut sent = 0usize;
        for chunk in chunks {
            if self.is_cancelled() {
                return SendOutcome::Cancelled;
            }
            match self.tx.send(Ok(chunk)) {
                Ok(()) => sent += 1,
                Err(_) => {
                    // receiver dropped: remember it so the batch-level
                    // cancel fast paths see this stream as dead too
                    self.cancelled.store(true, Ordering::Relaxed);
                    return SendOutcome::Cancelled;
                }
            }
        }
        SendOutcome::Delivered(sent)
    }

    /// Push a typed terminal error onto the stream.  Uses `try_send`
    /// so the failure path can never block on a stalled consumer: if
    /// the buffer is full the stream simply ends without a `last`
    /// chunk, which the consumer reports as "stream ended early".
    pub fn send_error(&self, err: ServeError) {
        let _ = self.tx.try_send(Err(err));
    }
}

impl ClipStream {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next chunk, blocking.  `None` once the producer is done (after
    /// the `last` chunk, a cancellation, or a producer-side drop).
    pub fn recv(&self) -> Option<Result<ClipChunk, ServeError>> {
        self.rx.recv().ok()
    }

    /// Non-blocking variant: `Ok(None)` = nothing buffered yet.
    pub fn try_recv(&self)
                    -> Result<Option<Result<ClipChunk, ServeError>>> {
        match self.rx.try_recv() {
            Ok(item) => Ok(Some(item)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                bail!("stream closed")
            }
        }
    }

    /// Ask the producer to stop without dropping the handle.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// A cloneable cancel handle for registries.
    pub fn cancel_handle(&self) -> StreamCancel {
        StreamCancel(Arc::clone(&self.cancelled))
    }

    /// Drain the stream and reassemble the full clip — the one-shot
    /// view of a streaming submit.  Errors (with the typed
    /// [`ServeError`] as the cause) if the producer reported a failure
    /// or the stream ended before its `last` chunk.
    pub fn collect(self) -> Result<GenResponse> {
        let mut chunks = Vec::new();
        while let Some(item) = self.recv() {
            let chunk = item?;
            let last = chunk.last;
            chunks.push(chunk);
            if last {
                break;
            }
        }
        assemble_response(self.id, chunks)
    }
}

impl Drop for ClipStream {
    fn drop(&mut self) {
        // cancel-on-drop: the producer observes the flag (or the
        // disconnected receiver) and stops emitting for this request
        self.cancelled.store(true, Ordering::Relaxed);
    }
}

/// Split `clip` (`[T, ...]`, f32) into `ceil(T / chunk_frames)` frame
/// ranges.  `chunk_frames == 0` (or `>= T`) keeps the whole clip as a
/// single chunk WITHOUT copying its data.
pub fn chunk_clip(id: u64, clip: Tensor, metrics: &RequestMetrics,
                  chunk_frames: usize) -> Result<Vec<ClipChunk>> {
    let total = *clip.shape.first()
        .context("cannot chunk a scalar clip")?;
    anyhow::ensure!(total > 0, "cannot chunk an empty clip");
    let per = if chunk_frames == 0 { total }
              else { chunk_frames.min(total) };
    if per == total {
        return Ok(vec![ClipChunk {
            id, seq: 0, frame_start: 0, frame_end: total,
            total_frames: total, last: true, frames: clip,
            metrics: metrics.clone(),
        }]);
    }
    let inner: Vec<usize> = clip.shape[1..].to_vec();
    let stride: usize = inner.iter().product();
    let data = clip.f32s()?;
    let mut chunks = Vec::with_capacity((total + per - 1) / per);
    let mut start = 0usize;
    let mut seq = 0usize;
    while start < total {
        let end = (start + per).min(total);
        let mut shape = vec![end - start];
        shape.extend_from_slice(&inner);
        let frames = Tensor::from_f32(
            &shape, data[start * stride..end * stride].to_vec())?;
        chunks.push(ClipChunk {
            id, seq, frame_start: start, frame_end: end,
            total_frames: total, last: end == total, frames,
            metrics: metrics.clone(),
        });
        start = end;
        seq += 1;
    }
    Ok(chunks)
}

/// Validate chunk ordering/completeness and concatenate the ranges
/// back into the full clip.  The inverse of [`chunk_clip`]: for any
/// clip and granularity, `assemble_response(chunk_clip(..))` yields a
/// byte-identical tensor.
pub fn assemble_response(id: u64, chunks: Vec<ClipChunk>)
                         -> Result<GenResponse> {
    let total = {
        let last = chunks.last()
            .context("stream ended before any chunk")?;
        anyhow::ensure!(last.last, "stream ended early: chunk {}/{} \
                                    frames [{}, {}) is not terminal",
                        last.seq, last.total_frames, last.frame_start,
                        last.frame_end);
        last.total_frames
    };
    if chunks.len() == 1 {
        // single whole-clip chunk (the one-shot wrapper's shape):
        // validate and move the tensor out without copying it
        let Some(c) = chunks.into_iter().next() else {
            anyhow::bail!("stream ended before any chunk");
        };
        anyhow::ensure!(c.id == id, "chunk for request {} on stream {id}",
                        c.id);
        anyhow::ensure!(c.seq == 0 && c.frame_start == 0
                        && c.frame_end == c.total_frames
                        && c.frames.shape.first() == Some(&c.total_frames),
                        "lone chunk does not cover the clip: seq {} \
                         frames [{}, {}) of {}", c.seq, c.frame_start,
                        c.frame_end, c.total_frames);
        return Ok(GenResponse { id, clip: c.frames, metrics: c.metrics });
    }
    let inner: Vec<usize> = chunks[0].frames.shape[1..].to_vec();
    let stride: usize = inner.iter().product();
    let mut data: Vec<f32> = Vec::with_capacity(total * stride);
    let mut cursor = 0usize;
    for (i, c) in chunks.iter().enumerate() {
        anyhow::ensure!(c.id == id, "chunk for request {} on stream {id}",
                        c.id);
        anyhow::ensure!(c.seq == i, "chunk out of order: seq {} at \
                                     position {i}", c.seq);
        anyhow::ensure!(c.frame_start == cursor,
                        "frame gap: chunk {i} starts at {} but {} frames \
                         assembled", c.frame_start, cursor);
        anyhow::ensure!(c.frame_end > c.frame_start
                        && c.frame_end <= total,
                        "bad frame range [{}, {}) of {total}",
                        c.frame_start, c.frame_end);
        anyhow::ensure!(c.total_frames == total,
                        "total_frames changed mid-stream");
        anyhow::ensure!(c.frames.shape[1..] == inner[..],
                        "frame shape changed mid-stream");
        data.extend_from_slice(c.frames.f32s()?);
        cursor = c.frame_end;
    }
    anyhow::ensure!(cursor == total,
                    "incomplete clip: {cursor} of {total} frames");
    let mut shape = vec![total];
    shape.extend_from_slice(&inner);
    let metrics = chunks.last()
        .context("stream ended before any chunk")?
        .metrics.clone();
    Ok(GenResponse { id, clip: Tensor::from_f32(&shape, data)?, metrics })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn clip(seed: u64, t: usize) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::randn(&[t, 2, 2, 3], &mut rng)
    }

    #[test]
    fn chunk_then_assemble_is_identity() {
        for chunk_frames in [0, 1, 2, 3, 4, 7] {
            let original = clip(5, 4);
            let chunks = chunk_clip(9, original.clone(),
                                    &RequestMetrics::default(),
                                    chunk_frames).unwrap();
            let expect = if chunk_frames == 0 { 1 }
                         else { (4 + chunk_frames.min(4) - 1)
                                / chunk_frames.min(4) };
            assert_eq!(chunks.len(), expect, "cf={chunk_frames}");
            assert!(chunks.last().unwrap().last);
            assert!(chunks[..chunks.len() - 1].iter()
                        .all(|c| !c.last));
            for (i, c) in chunks.iter().enumerate() {
                assert_eq!(c.seq, i);
                assert_eq!(c.total_frames, 4);
                assert_eq!(c.frames.shape[0], c.frame_end - c.frame_start);
            }
            let resp = assemble_response(9, chunks).unwrap();
            assert_eq!(resp.id, 9);
            assert_eq!(resp.clip, original, "cf={chunk_frames}");
        }
    }

    #[test]
    fn assemble_rejects_gaps_reorders_and_truncation() {
        let rm = RequestMetrics::default();
        let whole = chunk_clip(1, clip(2, 4), &rm, 1).unwrap();
        // truncated: missing the last chunk
        let mut truncated = whole.clone();
        truncated.pop();
        assert!(assemble_response(1, truncated).is_err());
        // reordered
        let mut reordered = whole.clone();
        reordered.swap(1, 2);
        assert!(assemble_response(1, reordered).is_err());
        // empty
        assert!(assemble_response(1, Vec::new()).is_err());
        // wrong id
        assert!(assemble_response(2, whole).is_err());
    }

    #[test]
    fn stream_channel_roundtrip_and_collect() {
        let (tx, rx) = channel(3, 1, 8);
        let original = clip(7, 4);
        let rm = RequestMetrics { queue_ms: 1.0, compute_ms: 2.0,
                                  steps: 4, batch_size: 1 };
        assert_eq!(tx.send_clip(original.clone(), &rm),
                   SendOutcome::Delivered(4));
        drop(tx);
        let resp = rx.collect().unwrap();
        assert_eq!(resp.clip, original);
        assert_eq!(resp.metrics.steps, 4);
    }

    #[test]
    fn dropped_stream_cancels_sender_without_blocking() {
        // buffer of 1 against 4 chunks: if cancel-on-drop failed, the
        // second send would block forever
        let (tx, rx) = channel(4, 1, 1);
        drop(rx);
        assert_eq!(tx.send_clip(clip(1, 4), &RequestMetrics::default()),
                   SendOutcome::Cancelled);
        assert!(tx.is_cancelled());
    }

    #[test]
    fn explicit_cancel_stops_delivery() {
        let (tx, rx) = channel(5, 1, 8);
        rx.cancel_handle().cancel();
        assert_eq!(tx.send_clip(clip(1, 4), &RequestMetrics::default()),
                   SendOutcome::Cancelled);
        // producer side done (sender dropped): the consumer sees the
        // stream end without a terminal chunk
        drop(tx);
        assert!(rx.collect().is_err());
    }

    #[test]
    fn mid_stream_error_surfaces_in_collect() {
        let (tx, rx) = channel(6, 1, 8);
        tx.send_error(ServeError::shard_transient("shard died"));
        drop(tx);
        let err = rx.collect().unwrap_err();
        assert!(err.to_string().contains("shard died"), "{err}");
        // the typed error survives the anyhow wrap
        let typed = err.downcast_ref::<ServeError>().unwrap();
        assert_eq!(typed.code(), "shard_failed");
        assert!(typed.retryable());
    }

    #[test]
    fn recv_yields_the_typed_error() {
        let (tx, rx) = channel(8, 1, 8);
        tx.send_error(ServeError::DeadlineExceeded);
        match rx.recv() {
            Some(Err(ServeError::DeadlineExceeded)) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
}
