//! Bounded request queue with backpressure + compatibility-aware
//! batch extraction (the batcher's front half).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::Envelope;

#[derive(Debug, thiserror::Error)]
pub enum QueueError {
    #[error("queue full ({0} pending) — backpressure")]
    Full(usize),
    #[error("queue closed")]
    Closed,
}

struct Inner {
    items: VecDeque<Envelope>,
    closed: bool,
}

/// MPSC: many frontend producers, one consumer (the pool dispatcher).
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(),
                                      closed: false }),
            cv: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking submit; `Err(Full)` is the backpressure signal the
    /// frontend surfaces to clients.
    pub fn push(&self, env: Envelope) -> Result<(), QueueError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(QueueError::Full(g.items.len()));
        }
        g.items.push_back(env);
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Engine side: block (up to `wait`) for a first request, then
    /// collect every already-queued request COMPATIBLE with it (same
    /// tier + steps), up to `max_batch`, preserving FIFO order for the
    /// rest.  After the first arrival, also waits up to `window` for
    /// stragglers to fill the batch (the dynamic-batching knob).
    ///
    /// Returns `None` on close-and-drained.
    pub fn pop_batch(&self, max_batch: usize, wait: Duration,
                     window: Duration) -> Option<Vec<Envelope>> {
        let deadline = Instant::now() + wait;
        let mut g = self.inner.lock().unwrap();
        while g.items.is_empty() {
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new()); // timeout, no work
            }
            let (ng, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
        // batch window: give stragglers a chance to coalesce
        if g.items.len() < max_batch && !window.is_zero() {
            let wdeadline = Instant::now() + window;
            while g.items.len() < max_batch && !g.closed {
                let now = Instant::now();
                if now >= wdeadline {
                    break;
                }
                let (ng, _) =
                    self.cv.wait_timeout(g, wdeadline - now).unwrap();
                g = ng;
            }
        }
        let first = g.items.pop_front().expect("non-empty");
        let mut batch = vec![first];
        let mut rest = VecDeque::new();
        while let Some(env) = g.items.pop_front() {
            if batch.len() < max_batch
                && env.request.compatible(&batch[0].request)
            {
                batch.push(env);
            } else {
                rest.push_back(env);
            }
        }
        g.items = rest;
        drop(g);
        // stamp the dequeue so queue wait is measured directly
        // (submit -> here) instead of being reconstructed later
        let now = Instant::now();
        for env in &mut batch {
            env.request.dequeued_at = Some(now);
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenRequest;
    use std::sync::mpsc::channel;

    fn env(id: u64, tier: &str, steps: usize) -> Envelope {
        let (tx, _rx) = channel();
        // leak the receiver so the sender stays usable in tests
        std::mem::forget(_rx);
        Envelope { request: GenRequest::new(id, 0, id, steps, tier),
                   reply: tx }
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = RequestQueue::new(2);
        q.push(env(1, "s95", 8)).unwrap();
        q.push(env(2, "s95", 8)).unwrap();
        match q.push(env(3, "s95", 8)) {
            Err(QueueError::Full(2)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pop_batch_groups_compatible() {
        let q = RequestQueue::new(16);
        q.push(env(1, "s95", 8)).unwrap();
        q.push(env(2, "s97", 8)).unwrap(); // incompatible, must stay
        q.push(env(3, "s95", 8)).unwrap();
        q.push(env(4, "s95", 4)).unwrap(); // different steps, stays
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(b.iter().map(|e| e.request.id).collect::<Vec<_>>(),
                   vec![1, 3]);
        assert_eq!(q.len(), 2);
        // FIFO preserved for the remainder
        let b2 = q.pop_batch(4, Duration::from_millis(10),
                             Duration::ZERO).unwrap();
        assert_eq!(b2[0].request.id, 2);
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = RequestQueue::new(16);
        for i in 0..6 {
            q.push(env(i, "s95", 8)).unwrap();
        }
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn timeout_returns_empty() {
        let q = RequestQueue::new(4);
        let b = q.pop_batch(4, Duration::from_millis(5), Duration::ZERO)
            .unwrap();
        assert!(b.is_empty());
    }

    #[test]
    fn close_drains_to_none() {
        let q = RequestQueue::new(4);
        q.close();
        assert!(q.pop_batch(4, Duration::from_millis(5),
                            Duration::ZERO).is_none());
        assert!(matches!(q.push(env(1, "s95", 8)),
                         Err(QueueError::Closed)));
    }

    #[test]
    fn pop_batch_stamps_nonnegative_dequeue_time() {
        let q = RequestQueue::new(4);
        q.push(env(1, "s95", 8)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let b = q.pop_batch(4, Duration::from_millis(10), Duration::ZERO)
            .unwrap();
        let r = &b[0].request;
        let d = r.dequeued_at.expect("pop_batch must stamp dequeued_at");
        assert!(d >= r.submitted_at);
        let wait = r.queue_wait_ms();
        assert!(wait >= 0.0, "queue wait went negative: {wait}");
        assert!(wait >= 4.0, "expected >=4ms of queue wait, got {wait}");
    }

    #[test]
    fn batch_window_coalesces_concurrent_pushes() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(16));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.push(env(2, "s95", 8)).unwrap();
        });
        q.push(env(1, "s95", 8)).unwrap();
        let b = q.pop_batch(4, Duration::from_millis(100),
                            Duration::from_millis(200)).unwrap();
        h.join().unwrap();
        // either both coalesced (common) or at least the first arrived
        assert!(!b.is_empty());
        if b.len() == 2 {
            assert_eq!(b[1].request.id, 2);
        }
    }
}
