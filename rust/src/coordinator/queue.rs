//! Bounded request queue with backpressure + class-keyed scheduling
//! (the batcher's front half).
//!
//! Requests are bucketed at push time by **compatibility class**
//! `(tier, steps)` — exactly the predicate [`GenRequest::compatible`]
//! implements — so the dispatcher can pick WHICH class to serve
//! instead of being forced to serve whatever sits at the global head.
//! Per-class FIFO order is always preserved; global arrival order is
//! tracked with sequence numbers so strict-FIFO mode reconstructs the
//! old single-`VecDeque` behavior bit-for-bit.
//!
//! Two scheduling policies ([`SchedPolicy`]):
//!
//! * **`Fifo`** — the class whose head arrived earliest is served.
//!   Because a class bucket holds exactly the requests the old scan
//!   would have collected (in the same order), this reproduces the
//!   seed's strict-FIFO-compatible batching exactly.
//! * **`ClassAware`** — same oldest-head-first baseline, plus a
//!   cost-aware head-of-line bypass: when the oldest head belongs to
//!   an expensive class (e.g. dense) and a *cheaper* class's head has
//!   already waited past `bypass_threshold`, the cheap class jumps
//!   the line.  Consecutive bypasses are capped at
//!   [`MAX_BYPASS_STREAK`], so the expensive class is served after a
//!   bounded number of jumps — no starvation.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::error::ServeError;
use super::pool::lock_recover;
use super::request::{Envelope, GenRequest};

/// Upper bound on consecutive cost-aware bypasses.  After this many
/// jumps in a row the oldest head is served unconditionally, which
/// bounds any class's extra wait to `MAX_BYPASS_STREAK` batch services
/// — the anti-starvation guarantee the property tests pin down.
pub const MAX_BYPASS_STREAK: u32 = 4;

/// A batch-compatibility class: requests in the same class run the
/// same artifact family and walk the same timestep grid, so they can
/// share a batch (mirrors [`GenRequest::compatible`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassKey {
    pub tier: String,
    pub steps: usize,
    /// attention-variant override (`None` = server default) — part of
    /// the key because shards compile per (variant, tier), so mixed
    /// variants must not share a batch
    pub variant: Option<String>,
}

impl ClassKey {
    pub fn of(req: &GenRequest) -> ClassKey {
        ClassKey { tier: req.tier.clone(), steps: req.steps,
                   variant: req.variant.clone() }
    }

    /// Relative service-cost proxy used by the bypass policy — NOT a
    /// latency estimate.  Monotone in what matters: more steps cost
    /// more, dense attention costs more than any sparse tier, higher
    /// sparsity costs less.  Sparse tiers are parsed from their
    /// "sNN" name; unknown tiers land in the middle.  The variant is
    /// deliberately NOT weighted: all implemented variants run the
    /// same tile budget per tier, so tier x steps stays the proxy.
    pub fn cost(&self) -> f64 {
        let tier_weight = match self.tier.as_str() {
            "dense" => 1.0,
            t => t.strip_prefix('s')
                .and_then(|pct| pct.parse::<f64>().ok())
                .map(|pct| 0.2 + 0.8 * (1.0 - pct / 100.0))
                .unwrap_or(0.5),
        };
        self.steps as f64 * tier_weight
    }
}

/// Which class the next `pop_batch` serves.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedPolicy {
    /// Oldest head wins, always — bit-for-bit the seed's behavior.
    Fifo,
    /// Oldest head wins unless a cheaper class's head has waited at
    /// least `bypass_threshold` (then it jumps, streak-capped).
    ClassAware { bypass_threshold: Duration },
}

impl SchedPolicy {
    /// Build from the `ServeConfig` string knobs: `"fifo"` is strict
    /// FIFO, `"class"` is class-aware with the given bypass
    /// threshold.  Anything else falls back to class-aware WITH a
    /// warning — silently honoring a typo like `"fifio"` would
    /// switch serving semantics out from under a determinism repro.
    pub fn from_config(scheduler: &str, bypass_threshold_ms: u64)
                       -> SchedPolicy {
        if scheduler == "fifo" {
            return SchedPolicy::Fifo;
        }
        if scheduler != "class" {
            crate::warn_!("unknown scheduler {scheduler:?}; using \
                           \"class\" (valid: \"class\", \"fifo\")");
        }
        SchedPolicy::ClassAware {
            bypass_threshold: Duration::from_millis(bypass_threshold_ms),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::ClassAware { .. } => "class",
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum QueueError {
    #[error("queue full ({0} pending) — backpressure")]
    Full(usize),
    #[error("queue closed")]
    Closed,
}

/// One class bucket: per-class FIFO, entries stamped with their global
/// arrival sequence number.
#[derive(Debug)]
struct Bucket {
    key: ClassKey,
    items: VecDeque<(u64, Envelope)>,
}

#[derive(Debug)]
struct Inner {
    /// Non-empty class buckets.  The class count is tiny (tiers x
    /// step-counts actually in flight), so linear scans beat map
    /// overhead and keep iteration order deterministic.
    buckets: Vec<Bucket>,
    len: usize,
    next_seq: u64,
    closed: bool,
    /// consecutive cost-aware bypasses (ClassAware anti-starvation)
    bypass_streak: u32,
}

impl Inner {
    /// Index of the bucket whose head arrived earliest.
    fn oldest(&self) -> Option<usize> {
        self.buckets.iter().enumerate()
            .filter_map(|(i, b)| b.items.front().map(|(seq, _)| (i, *seq)))
            .min_by_key(|(_, seq)| *seq)
            .map(|(i, _)| i)
    }
}

/// Queue-side view of the overload watermarks, computed under one
/// lock so depth and estimated work are a consistent snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionState {
    /// pending requests across all classes
    pub depth: usize,
    /// Σ over classes of `len × ClassKey::cost()` — the work proxy
    pub estimated_work: f64,
    /// true when either watermark is tripped
    pub overloaded: bool,
    /// deterministic drain estimate clients should back off for;
    /// meaningful only when `overloaded`
    pub retry_after_ms: u64,
}

/// MPSC: many frontend producers, one consumer (the pool dispatcher).
#[derive(Debug)]
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
    policy: SchedPolicy,
    /// requests dropped at dequeue because their deadline had passed
    /// (each was failed with [`ServeError::DeadlineExceeded`])
    expired_drops: AtomicU64,
}

impl RequestQueue {
    /// Strict-FIFO queue (the seed's behavior); serving stacks that
    /// want head-of-line bypass use [`RequestQueue::with_policy`].
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue::with_policy(capacity, SchedPolicy::Fifo)
    }

    pub fn with_policy(capacity: usize, policy: SchedPolicy)
                       -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { buckets: Vec::new(),
                                      len: 0,
                                      next_seq: 0,
                                      closed: false,
                                      bypass_streak: 0 }),
            cv: Condvar::new(),
            capacity,
            policy,
            expired_drops: AtomicU64::new(0),
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Non-blocking submit; `Err(Full)` is the backpressure signal the
    /// frontend surfaces to clients.  Capacity counts pending requests
    /// across ALL classes.
    pub fn push(&self, env: Envelope) -> Result<(), QueueError> {
        self.push_or_return(env).map_err(|(_, e)| e)
    }

    /// Like [`RequestQueue::push`], but hands the envelope back on
    /// rejection so the caller can resolve its reply sink with a typed
    /// error instead of silently dropping the channel (the retry
    /// path's requirement: every request resolves exactly once).
    pub fn push_or_return(&self, env: Envelope)
                          -> Result<(), (Envelope, QueueError)> {
        let mut g = lock_recover(&self.inner);
        if g.closed {
            return Err((env, QueueError::Closed));
        }
        if g.len >= self.capacity {
            return Err((env, QueueError::Full(g.len)));
        }
        let key = ClassKey::of(&env.request);
        let seq = g.next_seq;
        g.next_seq += 1;
        match g.buckets.iter().position(|b| b.key == key) {
            Some(i) => g.buckets[i].items.push_back((seq, env)),
            None => g.buckets.push(Bucket {
                key,
                items: VecDeque::from([(seq, env)]),
            }),
        }
        g.len += 1;
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pending depth per class, sorted by key — the per-class gauge
    /// `ServerMetrics::snapshot` reports.
    pub fn class_depths(&self) -> Vec<(ClassKey, usize)> {
        let g = lock_recover(&self.inner);
        let mut v: Vec<(ClassKey, usize)> = g.buckets.iter()
            .filter(|b| !b.items.is_empty())
            .map(|b| (b.key.clone(), b.items.len()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Requests dropped at dequeue because their deadline had passed.
    pub fn expired_drops(&self) -> u64 {
        self.expired_drops.load(Ordering::Relaxed)
    }

    /// Evaluate the overload watermarks (admission control's input).
    ///
    /// * `shed_watermark` — fraction of capacity past which the queue
    ///   reports overload; `>= 1.0` disables the depth check (the hard
    ///   `Full` rejection still applies at capacity).
    /// * `work_watermark` — ceiling on the estimated-work proxy
    ///   (Σ `len × ClassKey::cost()` across classes); `0` disables.
    ///
    /// `retry_after_ms` is a deterministic drain estimate: a base of
    /// 25 ms plus 25 ms per request beyond the depth watermark, capped
    /// at 2 s — so clients spread out instead of retrying in lockstep
    /// with the same period regardless of backlog.
    pub fn admission(&self, shed_watermark: f64, work_watermark: f64)
                     -> AdmissionState {
        let g = lock_recover(&self.inner);
        let depth = g.len;
        let estimated_work: f64 = g.buckets.iter()
            .map(|b| b.items.len() as f64 * b.key.cost())
            .sum();
        drop(g);
        let depth_limit = (shed_watermark * self.capacity as f64)
            .ceil() as usize;
        let depth_over = shed_watermark < 1.0 && depth >= depth_limit.max(1);
        let work_over = work_watermark > 0.0
            && estimated_work >= work_watermark;
        let overloaded = depth_over || work_over;
        let excess = depth.saturating_sub(depth_limit.min(depth)) as u64;
        let retry_after_ms = if overloaded {
            (25 + 25 * excess).min(2_000)
        } else {
            0
        };
        AdmissionState { depth, estimated_work, overloaded, retry_after_ms }
    }

    pub fn close(&self) {
        lock_recover(&self.inner).closed = true;
        self.cv.notify_all();
    }

    /// Engine side: block (up to `wait`) for a first request, then
    /// serve the scheduled class — up to `max_batch` of its oldest
    /// requests, per-class FIFO order preserved.  After the first
    /// arrival, also waits up to `window` for stragglers to fill the
    /// batch (the dynamic-batching knob).
    ///
    /// Which class gets served is the policy's call: `Fifo` always
    /// takes the class of the globally oldest request (reproducing the
    /// seed's scan exactly); `ClassAware` lets a cheaper class whose
    /// head has aged past the bypass threshold jump an expensive one,
    /// at most [`MAX_BYPASS_STREAK`] times in a row.
    ///
    /// Returns `None` on close-and-drained.
    pub fn pop_batch(&self, max_batch: usize, wait: Duration,
                     window: Duration) -> Option<Vec<Envelope>> {
        let deadline = Instant::now() + wait;
        let mut g = lock_recover(&self.inner);
        while g.len == 0 {
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(Vec::new()); // timeout, no work
            }
            let (ng, _) = self.cv.wait_timeout(g, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            g = ng;
        }
        // batch window: give stragglers a chance to coalesce
        if g.len < max_batch && !window.is_zero() {
            let wdeadline = Instant::now() + window;
            while g.len < max_batch && !g.closed {
                let now = Instant::now();
                if now >= wdeadline {
                    break;
                }
                let (ng, _) = self.cv.wait_timeout(g, wdeadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                g = ng;
            }
        }
        // `schedule` only returns None on an empty queue, which the
        // loop above rules out — but an empty batch is the safe answer
        let Some(bi) = self.schedule(&mut g) else {
            return Some(Vec::new());
        };
        let take = g.buckets[bi].items.len().min(max_batch.max(1));
        let mut batch = Vec::with_capacity(take);
        while batch.len() < take {
            match g.buckets[bi].items.pop_front() {
                Some((_, env)) => batch.push(env),
                None => break,
            }
        }
        if g.buckets[bi].items.is_empty() {
            g.buckets.swap_remove(bi);
        }
        g.len -= batch.len();
        drop(g);
        // stamp the dequeue so queue wait is measured directly
        // (submit -> here) instead of being reconstructed later,
        // and drop requests whose deadline already passed: failing
        // them here costs one reply send instead of a denoise run
        let now = Instant::now();
        let mut expired = 0u64;
        batch.retain_mut(|env| {
            env.request.dequeued_at = Some(now);
            if env.request.expired(now) {
                env.reply.fail(ServeError::DeadlineExceeded);
                expired += 1;
                false
            } else {
                true
            }
        });
        if expired > 0 {
            self.expired_drops.fetch_add(expired, Ordering::Relaxed);
        }
        Some(batch)
    }

    /// Pick the bucket to serve.  Requires a non-empty queue.
    fn schedule(&self, g: &mut Inner) -> Option<usize> {
        let oldest = g.oldest()?;
        let bypass_threshold = match &self.policy {
            SchedPolicy::Fifo => {
                return Some(oldest);
            }
            SchedPolicy::ClassAware { bypass_threshold } => {
                *bypass_threshold
            }
        };
        if g.bypass_streak >= MAX_BYPASS_STREAK {
            g.bypass_streak = 0;
            return Some(oldest);
        }
        let now = Instant::now();
        let oldest_cost = g.buckets[oldest].key.cost();
        // cheapest bypass-eligible class; oldest head breaks cost ties
        let jump = g.buckets.iter().enumerate()
            .filter(|(i, b)| {
                *i != oldest && !b.items.is_empty()
                    && b.key.cost() < oldest_cost
            })
            .filter_map(|(i, b)| {
                let (seq, env) = b.items.front()?;
                let waited = now.saturating_duration_since(
                    env.request.submitted_at);
                (waited >= bypass_threshold)
                    .then_some((i, b.key.cost(), *seq))
            })
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.2.cmp(&b.2)))
            .map(|(i, _, _)| i);
        match jump {
            Some(i) => {
                g.bypass_streak += 1;
                Some(i)
            }
            None => {
                g.bypass_streak = 0;
                Some(oldest)
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::request::{GenRequest, GenResponse};
    use std::sync::mpsc::{channel, Receiver};

    type Reply = Receiver<Result<GenResponse, ServeError>>;

    /// Build an envelope AND hand back its reply receiver so tests
    /// keep it alive for the envelope's lifetime (no `mem::forget`
    /// leak; a dropped receiver would make reply sends fail).
    fn env(id: u64, tier: &str, steps: usize) -> (Envelope, Reply) {
        let (tx, rx) = channel();
        (Envelope::oneshot(GenRequest::new(id, 0, id, steps, tier), tx),
         rx)
    }

    /// Push a fresh envelope, stashing the receiver in `keep`.
    fn push(q: &RequestQueue, keep: &mut Vec<Reply>,
            id: u64, tier: &str, steps: usize) -> Result<(), QueueError> {
        let (e, rx) = env(id, tier, steps);
        keep.push(rx);
        q.push(e)
    }

    fn ids(batch: &[Envelope]) -> Vec<u64> {
        batch.iter().map(|e| e.request.id).collect()
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = RequestQueue::new(2);
        let mut keep = Vec::new();
        push(&q, &mut keep, 1, "s95", 8).unwrap();
        push(&q, &mut keep, 2, "s95", 8).unwrap();
        match push(&q, &mut keep, 3, "s95", 8) {
            Err(QueueError::Full(2)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn backpressure_counts_across_classes() {
        // capacity is a TOTAL across class buckets, not per class
        let q = RequestQueue::new(3);
        let mut keep = Vec::new();
        push(&q, &mut keep, 1, "s95", 8).unwrap();
        push(&q, &mut keep, 2, "dense", 8).unwrap();
        push(&q, &mut keep, 3, "s90", 4).unwrap();
        match push(&q, &mut keep, 4, "s97", 8) {
            Err(QueueError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
    }

    #[test]
    fn pop_batch_groups_compatible() {
        let q = RequestQueue::new(16);
        let mut keep = Vec::new();
        push(&q, &mut keep, 1, "s95", 8).unwrap();
        push(&q, &mut keep, 2, "s97", 8).unwrap(); // incompatible, stays
        push(&q, &mut keep, 3, "s95", 8).unwrap();
        push(&q, &mut keep, 4, "s95", 4).unwrap(); // different steps
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(ids(&b), vec![1, 3]);
        assert_eq!(q.len(), 2);
        // FIFO preserved for the remainder
        let b2 = q.pop_batch(4, Duration::from_millis(10),
                             Duration::ZERO).unwrap();
        assert_eq!(b2[0].request.id, 2);
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = RequestQueue::new(16);
        let mut keep = Vec::new();
        for i in 0..6 {
            push(&q, &mut keep, i, "s95", 8).unwrap();
        }
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn timeout_returns_empty() {
        let q = RequestQueue::new(4);
        let b = q.pop_batch(4, Duration::from_millis(5), Duration::ZERO)
            .unwrap();
        assert!(b.is_empty());
    }

    #[test]
    fn close_drains_to_none() {
        let q = RequestQueue::new(4);
        q.close();
        assert!(q.pop_batch(4, Duration::from_millis(5),
                            Duration::ZERO).is_none());
        let (e, _rx) = env(1, "s95", 8);
        assert!(matches!(q.push(e), Err(QueueError::Closed)));
    }

    #[test]
    fn pop_batch_stamps_nonnegative_dequeue_time() {
        let q = RequestQueue::new(4);
        let mut keep = Vec::new();
        push(&q, &mut keep, 1, "s95", 8).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let b = q.pop_batch(4, Duration::from_millis(10), Duration::ZERO)
            .unwrap();
        let r = &b[0].request;
        let d = r.dequeued_at.expect("pop_batch must stamp dequeued_at");
        assert!(d >= r.submitted_at);
        let wait = r.queue_wait_ms();
        assert!(wait >= 0.0, "queue wait went negative: {wait}");
        assert!(wait >= 4.0, "expected >=4ms of queue wait, got {wait}");
    }

    #[test]
    fn batch_window_coalesces_concurrent_pushes() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(16));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            let (e, rx) = env(2, "s95", 8);
            q2.push(e).unwrap();
            rx
        });
        let (e, _rx1) = env(1, "s95", 8);
        q.push(e).unwrap();
        let b = q.pop_batch(4, Duration::from_millis(100),
                            Duration::from_millis(200)).unwrap();
        let _rx2 = h.join().unwrap();
        // either both coalesced (common) or at least the first arrived
        assert!(!b.is_empty());
        if b.len() == 2 {
            assert_eq!(b[1].request.id, 2);
        }
    }

    #[test]
    fn class_depths_reports_per_class() {
        let q = RequestQueue::new(16);
        let mut keep = Vec::new();
        push(&q, &mut keep, 1, "s90", 8).unwrap();
        push(&q, &mut keep, 2, "s90", 8).unwrap();
        push(&q, &mut keep, 3, "dense", 8).unwrap();
        let depths = q.class_depths();
        assert_eq!(depths.len(), 2);
        let dense = depths.iter()
            .find(|(k, _)| k.tier == "dense").unwrap();
        assert_eq!(dense.1, 1);
        let s90 = depths.iter().find(|(k, _)| k.tier == "s90").unwrap();
        assert_eq!(s90.1, 2);
    }

    #[test]
    fn class_cost_orders_dense_above_sparse() {
        let key = |tier: &str, steps| ClassKey {
            tier: tier.into(), steps, variant: None,
        };
        let dense = key("dense", 8);
        let s90 = key("s90", 8);
        let s97 = key("s97", 8);
        let s90_short = key("s90", 4);
        assert!(dense.cost() > s90.cost());
        assert!(s90.cost() > s97.cost());
        assert!(s90.cost() > s90_short.cost());
        // same tier budget => same cost regardless of variant, but a
        // DIFFERENT class (shards compile per variant)
        let s90_sparge = ClassKey { tier: "s90".into(), steps: 8,
                                    variant: Some("sparge2".into()) };
        assert_eq!(s90.cost(), s90_sparge.cost());
        assert_ne!(s90, s90_sparge);
    }

    #[test]
    fn variant_overrides_split_scheduling_classes() {
        // two requests differing only in variant land in different
        // buckets and never share a pop_batch
        let q = RequestQueue::new(8);
        let (tx1, _rx1) = channel();
        q.push(Envelope::oneshot(
            GenRequest::new(1, 0, 1, 8, "s90"), tx1)).unwrap();
        let (tx2, _rx2) = channel();
        q.push(Envelope::oneshot(
            GenRequest::new(2, 0, 2, 8, "s90")
                .with_variant(Some("sparge2".into())), tx2)).unwrap();
        let depths = q.class_depths();
        assert_eq!(depths.len(), 2, "variants must split classes");
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(b.len(), 1,
                   "mixed-variant requests must not share a batch");
    }

    #[test]
    fn young_sparse_head_does_not_bypass() {
        // threshold far beyond the test's runtime: however loaded the
        // machine, the sparse head cannot have aged past it, so the
        // oldest (dense) head must win
        let q = RequestQueue::with_policy(
            64,
            SchedPolicy::ClassAware {
                bypass_threshold: Duration::from_secs(3600),
            });
        let mut keep = Vec::new();
        push(&q, &mut keep, 0, "dense", 8).unwrap();
        push(&q, &mut keep, 10, "s97", 8).unwrap();
        let b = q.pop_batch(1, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(ids(&b), vec![0], "young sparse head must not jump");
    }

    #[test]
    fn aged_sparse_class_bypasses_dense_backlog() {
        // the acceptance scenario: a dense backlog at the head, one
        // sparse request behind it.  Strict FIFO serves all dense
        // first; class-aware serves the sparse one once it has aged
        // past the bypass threshold.  The sleep strictly exceeds the
        // threshold, so this cannot flake on a slow runner (extra
        // elapsed time only ages the head further).
        let threshold = Duration::from_millis(5);
        let q = RequestQueue::with_policy(
            64, SchedPolicy::ClassAware { bypass_threshold: threshold });
        let mut keep = Vec::new();
        for i in 0..4 {
            push(&q, &mut keep, i, "dense", 8).unwrap();
        }
        push(&q, &mut keep, 10, "s97", 8).unwrap();
        std::thread::sleep(threshold + Duration::from_millis(5));
        let b = q.pop_batch(1, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(ids(&b), vec![10], "aged sparse head must bypass");
        // and the dense backlog then drains in order
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(ids(&b), vec![0, 1, 2, 3]);
    }

    #[test]
    fn bypass_streak_is_capped() {
        // threshold 0: sparse is ALWAYS bypass-eligible.  The streak
        // cap must still force the dense head through after at most
        // MAX_BYPASS_STREAK jumps.
        let q = RequestQueue::with_policy(
            64,
            SchedPolicy::ClassAware { bypass_threshold: Duration::ZERO });
        let mut keep = Vec::new();
        push(&q, &mut keep, 100, "dense", 8).unwrap();
        let mut next_sparse = 0u64;
        let mut pops_until_dense = 0usize;
        loop {
            // adversarial arrival pattern: keep the sparse bucket
            // non-empty forever
            push(&q, &mut keep, next_sparse, "s97", 8).unwrap();
            next_sparse += 1;
            let b = q.pop_batch(1, Duration::from_millis(10),
                                Duration::ZERO).unwrap();
            pops_until_dense += 1;
            if b[0].request.tier == "dense" {
                break;
            }
            assert!(pops_until_dense <= MAX_BYPASS_STREAK as usize + 1,
                    "dense starved past the streak cap");
        }
        assert!(pops_until_dense <= MAX_BYPASS_STREAK as usize + 1);
    }

    #[test]
    fn fifo_policy_never_bypasses() {
        let q = RequestQueue::with_policy(64, SchedPolicy::Fifo);
        assert_eq!(q.policy_name(), "fifo");
        let mut keep = Vec::new();
        for i in 0..3 {
            push(&q, &mut keep, i, "dense", 8).unwrap();
        }
        push(&q, &mut keep, 10, "s97", 8).unwrap();
        std::thread::sleep(Duration::from_millis(15));
        // however long the sparse head has waited, FIFO serves dense
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        assert_eq!(ids(&b), vec![0, 1, 2]);
    }

    #[test]
    fn expired_requests_are_dropped_at_dequeue_with_a_typed_error() {
        let q = RequestQueue::new(8);
        let (tx, rx_dead) = channel();
        let dead = GenRequest::new(1, 0, 1, 8, "s95").with_deadline_ms(1);
        q.push(Envelope::oneshot(dead, tx)).unwrap();
        let mut keep = Vec::new();
        push(&q, &mut keep, 2, "s95", 8).unwrap(); // no deadline
        std::thread::sleep(Duration::from_millis(5));
        let b = q.pop_batch(4, Duration::from_millis(10),
                            Duration::ZERO).unwrap();
        // the expired request never reaches a shard; the live one does
        assert_eq!(ids(&b), vec![2]);
        assert_eq!(q.expired_drops(), 1);
        match rx_dead.recv().unwrap() {
            Err(ServeError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn admission_depth_watermark() {
        let q = RequestQueue::new(10);
        let mut keep = Vec::new();
        for i in 0..5 {
            push(&q, &mut keep, i, "s90", 8).unwrap();
        }
        // watermark at half capacity: 5 pending trips it
        let a = q.admission(0.5, 0.0);
        assert!(a.overloaded);
        assert_eq!(a.depth, 5);
        assert!(a.retry_after_ms >= 25);
        // watermark disabled: never overloaded from depth
        let a = q.admission(1.0, 0.0);
        assert!(!a.overloaded);
        assert_eq!(a.retry_after_ms, 0);
    }

    #[test]
    fn admission_work_watermark_weights_expensive_classes() {
        let q = RequestQueue::new(64);
        let mut keep = Vec::new();
        push(&q, &mut keep, 1, "dense", 8).unwrap();
        push(&q, &mut keep, 2, "s97", 8).unwrap();
        let a = q.admission(1.0, 0.0);
        let want = ClassKey { tier: "dense".into(), steps: 8,
                              variant: None }.cost()
            + ClassKey { tier: "s97".into(), steps: 8,
                         variant: None }.cost();
        assert!((a.estimated_work - want).abs() < 1e-9);
        assert!(!a.overloaded);
        // a work ceiling below the current load trips overload even
        // though the depth watermark is disabled
        let a = q.admission(1.0, want * 0.5);
        assert!(a.overloaded);
    }

    #[test]
    fn retry_after_grows_with_backlog() {
        let q = RequestQueue::new(100);
        let mut keep = Vec::new();
        for i in 0..10 {
            push(&q, &mut keep, i, "s90", 8).unwrap();
        }
        let shallow = q.admission(0.05, 0.0).retry_after_ms;
        for i in 10..40 {
            push(&q, &mut keep, i, "s90", 8).unwrap();
        }
        let deep = q.admission(0.05, 0.0).retry_after_ms;
        assert!(deep > shallow, "deep={deep} shallow={shallow}");
    }

    #[test]
    fn sched_policy_from_config() {
        assert_eq!(SchedPolicy::from_config("fifo", 50), SchedPolicy::Fifo);
        assert_eq!(
            SchedPolicy::from_config("class", 50),
            SchedPolicy::ClassAware {
                bypass_threshold: Duration::from_millis(50)
            });
    }
}
