//! TCP network frontend: the readiness-driven reactor serving the
//! [`super::wire`] protocol over the [`Gateway`], plus the matching
//! [`NetClient`].
//!
//! # Wire formats
//!
//! Two codecs share one port, negotiated per connection by the FIRST
//! byte the client sends (see [`super::wire`] for the byte-level
//! spec):
//!
//! * **v0** — length-prefixed JSON, debug-readable: a 4-byte
//!   big-endian length followed by a UTF-8 JSON body.  Tensors ride
//!   inline as `{"shape": [..], "data": [f32 as double, ..]}`.
//! * **v1** — binary frames: a fixed 20-byte header (magic `SLA2`,
//!   version, verb, flags, request id, payload length) followed by a
//!   JSON meta section and, on `chunk`/`clip` frames, a raw
//!   little-endian tensor section with optional zero-run-length
//!   compression.  ~5x smaller than v0 on f32 clip payloads.
//!
//! The server answers in whichever format the connection latched;
//! frames never mix formats mid-connection.
//!
//! # Verbs
//!
//! Client -> server (the `"op"` field):
//!
//! | op        | fields                                             |
//! |-----------|----------------------------------------------------|
//! | `hello`   | optional handshake: `token` (required when the     |
//! |           | server was started with `--auth-token`), `wire`,   |
//! |           | `compress` (opt into v1 tensor compression);       |
//! |           | answered with `hello_ok`                           |
//! | `submit`  | `class`, `seed`, `steps` (1..=[`MAX_NET_STEPS`]),  |
//! |           | `tier`, `stream` (bool), `deadline_ms` (0 = server |
//! |           | default), `allow_degrade` (bool), `variant`        |
//! | `cancel`  | `id` — cancel an in-flight streaming request       |
//! | `metrics` | none — request a metrics snapshot                  |
//! | `health`  | none — liveness/readiness probe (cheap; safe for   |
//! |           | load balancers to poll)                            |
//! | `drain`   | none — begin graceful drain: admission flips to    |
//! |           | typed `shutting_down`, in-flight work completes    |
//!
//! Server -> client frames (the `"type"` field): `hello_ok`,
//! `accepted` / `rejected`, `chunk`, `done` (`{id, complete}`),
//! `clip`, `metrics`, `cancel_ok`, `health`, `drain_ok`, `goaway`,
//! and `error` — exactly the PR-3/6 set plus the handshake ack.
//! Framing-level errors (malformed bytes, oversized frame, bad magic)
//! send a `bad_request` error frame and then close the connection,
//! since the byte stream can no longer be resynchronized.
//!
//! Typed failures (`rejected` and `error` frames) carry `error`,
//! `code` ([`ServeError`] codes, now including `unauthorized` and
//! `rate_limited`), `retryable`, and `retry_after_ms` (present on
//! `overloaded` and `rate_limited`).
//!
//! # Auth and rate limiting
//!
//! With `--auth-token` set, every connection must open with a `hello`
//! frame carrying the exact token; anything else gets a typed
//! `unauthorized` error and the connection closes.  The comparison is
//! constant-time.  With `--rate-limit R` set, each connection gets a
//! token bucket (R submits/second, burst `max(1, R)`); submits over
//! the budget are rejected with typed `rate_limited` +
//! `retry_after_ms` — the connection stays up, only submits shed.
//! TLS remains stubbed behind the `tls` cargo feature (no vendorable
//! implementation fits the offline registry).
//!
//! # Threads: a reactor, not thread-per-connection
//!
//! One acceptor thread plus `ServeConfig::net_workers` I/O workers —
//! O(workers), never O(connections).  The acceptor hands each socket
//! to a worker (round-robin by accept ordinal); the worker multiplexes
//! all of its connections over one readiness loop (epoll on Linux,
//! level-triggered; a bounded sweep elsewhere), with nonblocking
//! sockets throughout.  A per-worker loopback doorbell wakes the loop
//! instantly for handoffs, drain broadcasts and shutdown, so an idle
//! worker sleeps in `epoll_wait` — 10k idle streaming connections
//! cost file descriptors and a few hundred bytes each, not threads.
//! In-flight work is polled, not pumped: streams via
//! [`ClipStream::try_recv`], one-shot results via channel `try_recv`,
//! only while the connection's outbound queue has room.
//!
//! A dropped connection cancels every stream it still owns, so
//! abandoned clients release their shard slots (see
//! [`crate::coordinator::stream`]).
//!
//! # Slow-client protection
//!
//! The outbound path is BOUNDED: each connection buffers at most
//! `ServeConfig::net_send_queue` frames, and chunk-pulling stops while
//! the queue is full.  A queue that stays full past
//! `ServeConfig::write_stall_ms` declares the client slow: every
//! stream it owns is cancelled through the normal cancel path (freeing
//! shard slots) and the socket is severed.  One stuck client can never
//! wedge a worker — it costs exactly one bounded queue of frames,
//! then it is gone.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Shutdown, SocketAddr, TcpListener,
               TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::error::ServeError;
use super::request::{GenResponse, RequestMetrics};
use super::server::{Gateway, SubmitOpts};
use super::stream::{self, ClipChunk, ClipStream, StreamCancel};
use super::wire;
use crate::config::ServeConfig;
use crate::tensor::Tensor;
use crate::util::faults::{FaultAction, FaultInjector, FaultPlan};
use crate::util::json::Json;

pub use super::wire::{tensor_from_json, tensor_to_json, FrameDecoder,
                      WireFormat, WireFrame, MAX_FRAME_LEN};

/// Hard cap on a network submit's `steps`.  Frames are size-capped by
/// [`MAX_FRAME_LEN`], but nothing else bounds per-request COMPUTE, and
/// a denoise loop cannot be interrupted once it starts — an
/// unvalidated `steps` would let one request pin a shard arbitrarily
/// long.  Requests outside `1..=MAX_NET_STEPS` are rejected.
pub const MAX_NET_STEPS: usize = 1024;

// ---------------- blocking v0 framing (legacy helpers) ------------------

/// Write one length-prefixed v0 JSON frame (blocking).  Kept for raw
/// protocol tests and v0-only tooling; the server and [`NetClient`]
/// go through [`wire::encode`] / [`FrameDecoder`].
pub fn write_frame(w: &mut impl Write, j: &Json) -> Result<()> {
    let body = j.to_string();
    anyhow::ensure!(body.len() <= MAX_FRAME_LEN,
                    "frame of {} bytes exceeds the {} byte cap",
                    body.len(), MAX_FRAME_LEN);
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    Ok(())
}

/// Read one v0 frame (blocking).  `Ok(None)` = the peer closed cleanly
/// between frames; `Err` = oversized length prefix, truncated frame,
/// or malformed JSON (the caller should drop the connection — the
/// byte stream cannot be resynchronized).
pub fn read_frame(r: &mut impl Read, max_len: usize)
                  -> Result<Option<Json>> {
    let mut header = [0u8; 4];
    // distinguish clean EOF (no header at all) from truncation
    match r.read(&mut header)? {
        0 => return Ok(None),
        mut got => {
            while got < 4 {
                let n = r.read(&mut header[got..])?;
                anyhow::ensure!(n > 0, "truncated frame header");
                got += n;
            }
        }
    }
    let len = u32::from_be_bytes(header) as usize;
    anyhow::ensure!(len <= max_len,
                    "oversized frame: {len} bytes (cap {max_len})");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("truncated frame body")?;
    let text = std::str::from_utf8(&body).context("frame is not UTF-8")?;
    let j = Json::parse(text)
        .map_err(|e| anyhow::anyhow!("malformed frame: {e}"))?;
    Ok(Some(j))
}

// ---------------- JSON <-> domain conversions ---------------------------

fn metrics_to_json(m: &RequestMetrics) -> Json {
    Json::obj()
        .push("queue_ms", m.queue_ms)
        .push("compute_ms", m.compute_ms)
        .push("steps", m.steps)
        .push("batch_size", m.batch_size)
}

fn metrics_from_json(j: &Json) -> RequestMetrics {
    let f = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let u = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
    RequestMetrics { queue_ms: f("queue_ms"), compute_ms: f("compute_ms"),
                     steps: u("steps"), batch_size: u("batch_size") }
}

/// A chunk's meta fields WITHOUT the tensor — the wire codec carries
/// the tensor out-of-band (v1) or folds it back in under `"frames"`
/// (v0).
fn chunk_meta(c: &ClipChunk) -> Json {
    Json::obj()
        .push("type", "chunk")
        .push("id", c.id as usize)
        .push("seq", c.seq)
        .push("frame_start", c.frame_start)
        .push("frame_end", c.frame_end)
        .push("total_frames", c.total_frames)
        .push("last", c.last)
        .push("metrics", metrics_to_json(&c.metrics))
}

/// The full inline (v0-shaped) chunk JSON, tensor included.
pub fn chunk_to_json(c: &ClipChunk) -> Result<Json> {
    Ok(Json::obj()
        .push("type", "chunk")
        .push("id", c.id as usize)
        .push("seq", c.seq)
        .push("frame_start", c.frame_start)
        .push("frame_end", c.frame_end)
        .push("total_frames", c.total_frames)
        .push("last", c.last)
        .push("frames", tensor_to_json(&c.frames)?)
        .push("metrics", metrics_to_json(&c.metrics)))
}

fn chunk_fields(j: &Json, frames: Tensor) -> Result<ClipChunk> {
    let u = |k: &str| -> Result<usize> {
        j.req(k)?.as_usize().context(format!("chunk field {k}"))
    };
    Ok(ClipChunk {
        id: u("id")? as u64,
        seq: u("seq")?,
        frame_start: u("frame_start")?,
        frame_end: u("frame_end")?,
        total_frames: u("total_frames")?,
        last: j.req("last")?.as_bool().context("chunk field last")?,
        frames,
        metrics: j.get("metrics").map(metrics_from_json)
            .unwrap_or_default(),
    })
}

pub fn chunk_from_json(j: &Json) -> Result<ClipChunk> {
    chunk_fields(j, tensor_from_json(j.req("frames")?)?)
}

/// Decode a chunk from either path: the out-of-band v1 tensor when
/// present, the inline `"frames"` tree otherwise.
pub fn chunk_from_frame(f: &WireFrame) -> Result<ClipChunk> {
    match &f.tensor {
        Some(t) => chunk_fields(&f.meta, t.clone()),
        None => chunk_from_json(&f.meta),
    }
}

fn clip_meta(resp: &GenResponse) -> Json {
    Json::obj()
        .push("type", "clip")
        .push("id", resp.id as usize)
        .push("metrics", metrics_to_json(&resp.metrics))
}

/// Decode a `clip` frame from either path (see [`chunk_from_frame`]).
pub fn clip_from_frame(f: &WireFrame) -> Result<GenResponse> {
    let clip = match &f.tensor {
        Some(t) => t.clone(),
        None => tensor_from_json(f.meta.req("clip")?)?,
    };
    Ok(GenResponse {
        id: f.meta.get("id").and_then(|v| v.as_usize())
            .unwrap_or(0) as u64,
        clip,
        metrics: f.meta.get("metrics").map(metrics_from_json)
            .unwrap_or_default(),
    })
}

/// The typed failure fields shared by `error` and `rejected` frames.
fn push_error_fields(mut j: Json, err: &ServeError) -> Json {
    j = j.push("error", format!("{err}"))
         .push("code", err.code())
         .push("retryable", err.retryable());
    if let Some(ms) = err.retry_after_ms() {
        j = j.push("retry_after_ms", ms as usize);
    }
    j
}

fn error_frame(id: Option<u64>, err: &ServeError) -> Json {
    let mut j = Json::obj().push("type", "error");
    if let Some(id) = id {
        j = j.push("id", id as usize);
    }
    push_error_fields(j, err)
}

fn rejected_frame(err: &ServeError) -> Json {
    push_error_fields(Json::obj().push("type", "rejected"), err)
}

/// A request-scoped internal failure (serialization and the like):
/// terminal, non-retryable.
fn internal_error_frame(id: u64, msg: &str) -> Json {
    error_frame(Some(id), &ServeError::shard_fatal(msg.to_string()))
}

/// The unsolicited drain notice pushed to connections when the server
/// begins draining.
fn goaway_frame() -> Json {
    Json::obj()
        .push("type", "goaway")
        .push("reason",
              "server draining: in-flight streams will complete; do \
               not submit again on this connection")
}

fn accepted_frame(id: u64) -> Json {
    Json::obj().push("type", "accepted").push("id", id as usize)
}

fn done_frame(id: u64, complete: bool) -> Json {
    Json::obj()
        .push("type", "done")
        .push("id", id as usize)
        .push("complete", complete)
}

/// Decode the typed failure carried by a `rejected` / `error` frame
/// back into a [`ServeError`] (frames from servers predating the
/// `code` field decode as non-retryable `shard_failed`).
pub fn error_from_frame(f: &Json) -> ServeError {
    ServeError::from_wire(
        f.get("code").and_then(|v| v.as_str()).unwrap_or(""),
        f.get("error").and_then(|v| v.as_str()).unwrap_or("unknown"),
        f.get("retryable").and_then(|v| v.as_bool()).unwrap_or(false),
        f.get("retry_after_ms").and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64)
}

// ---------------- submit parsing ----------------------------------------

/// A submit request's decoded fields — identical whichever wire
/// format carried the frame (the property tests pin this).
#[derive(Debug)]
struct SubmitParams {
    class: i32,
    seed: u64,
    steps: usize,
    tier: String,
    streaming: bool,
    opts: SubmitOpts,
}

fn parse_submit(req: &Json, serve: &ServeConfig) -> SubmitParams {
    SubmitParams {
        class: req.get("class").and_then(|v| v.as_i64()).unwrap_or(0)
            as i32,
        seed: req.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0)
            as u64,
        steps: req.get("steps").and_then(|v| v.as_usize())
            .unwrap_or(serve.sample_steps),
        tier: req.get("tier").and_then(|v| v.as_str())
            .unwrap_or(&serve.tier).to_string(),
        streaming: req.get("stream").and_then(|v| v.as_bool())
            .unwrap_or(true),
        opts: SubmitOpts {
            deadline_ms: req.get("deadline_ms").and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64,
            allow_degrade: req.get("allow_degrade")
                .and_then(|v| v.as_bool()).unwrap_or(false),
            // absent = serve the server's configured default variant;
            // an unknown name comes back as a typed bad_request reject
            // frame (gateway admission validates against the backend's
            // set)
            variant: req.get("variant").and_then(|v| v.as_str())
                .map(String::from),
        },
    }
}

// ---------------- auth + rate limiting ----------------------------------

/// Constant-time token comparison: the loop always covers the full
/// length, so timing does not leak the first mismatching byte.
fn token_eq(a: &str, b: &str) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.bytes().zip(b.bytes())
        .fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

/// Per-connection token bucket: `rate` submits/second with a burst of
/// `max(1, rate)`.  `rate <= 0` disables limiting.
struct TokenBucket {
    level: f64,
    at: Instant,
}

impl TokenBucket {
    fn new(rate: f64, now: Instant) -> TokenBucket {
        TokenBucket { level: rate.max(1.0), at: now }
    }

    /// `None` = admitted (one token spent); `Some(ms)` = over budget,
    /// with the backoff hint until the next token accrues.
    fn hit(&mut self, rate: f64, now: Instant) -> Option<u64> {
        if rate <= 0.0 {
            return None;
        }
        let burst = rate.max(1.0);
        let dt = now.saturating_duration_since(self.at).as_secs_f64();
        self.at = now;
        self.level = (self.level + dt * rate).min(burst);
        if self.level >= 1.0 {
            self.level -= 1.0;
            None
        } else {
            Some((((1.0 - self.level) / rate) * 1000.0).ceil() as u64)
        }
    }
}

// ---------------- readiness poller --------------------------------------

#[cfg(target_os = "linux")]
mod poll {
    //! Level-triggered epoll over the worker's connections plus its
    //! doorbell, through direct `extern "C"` FFI (the offline registry
    //! carries no mio/libc; precedent: `main.rs` binds `signal(2)` the
    //! same way).  Read-interest only — writes are retried from the
    //! tick loop, which the doorbell and the busy timeout keep hot.

    use std::io::Read;
    use std::net::TcpStream;
    use std::os::unix::io::{AsRawFd, RawFd};
    use std::time::Duration;

    // x86_64 is the one Linux ABI where epoll_event is packed
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct Event {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CLOEXEC: i32 = 0x8_0000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut Event)
                     -> i32;
        fn epoll_wait(epfd: i32, events: *mut Event, maxevents: i32,
                      timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// The token the worker doorbell is registered under (never a
    /// valid accept ordinal — ordinals count up from 0).
    const DOORBELL: u64 = u64::MAX;

    pub struct Poller {
        epfd: RawFd,
        bell: TcpStream,
    }

    impl Poller {
        pub fn new(bell: TcpStream) -> std::io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            let p = Poller { epfd, bell };
            p.register(p.bell.as_raw_fd(), DOORBELL)?;
            Ok(p)
        }

        fn register(&self, fd: RawFd, token: u64) -> std::io::Result<()> {
            let mut ev = Event { events: EPOLLIN | EPOLLRDHUP,
                                 data: token };
            let rc = unsafe {
                epoll_ctl(self.epfd, EPOLL_CTL_ADD, fd, &mut ev)
            };
            if rc < 0 {
                Err(std::io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        pub fn add(&self, sock: &TcpStream, token: u64)
                   -> std::io::Result<()> {
            self.register(sock.as_raw_fd(), token)
        }

        pub fn del(&self, sock: &TcpStream) {
            let mut ev = Event { events: 0, data: 0 };
            unsafe {
                epoll_ctl(self.epfd, EPOLL_CTL_DEL, sock.as_raw_fd(),
                          &mut ev);
            }
        }

        /// Wait up to `timeout`, pushing ready tokens into `ready`
        /// (the doorbell is drained internally and never surfaces).
        /// Returns whether the caller must treat EVERY connection as
        /// readable — always false here; the portable fallback's
        /// contract.
        pub fn wait(&mut self, timeout: Duration, ready: &mut Vec<u64>)
                    -> bool {
            let mut evs = [Event { events: 0, data: 0 }; 64];
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let n = unsafe {
                epoll_wait(self.epfd, evs.as_mut_ptr(),
                           evs.len() as i32, ms)
            };
            if n <= 0 {
                return false; // timeout (EINTR folds into one)
            }
            for ev in evs.iter().take(n as usize) {
                let token = ev.data; // copy out of the packed struct
                if token == DOORBELL {
                    let mut buf = [0u8; 64];
                    while matches!((&self.bell).read(&mut buf),
                                   Ok(n) if n > 0) {}
                } else {
                    ready.push(token);
                }
            }
            false
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod poll {
    //! Portable fallback: no readiness facility — sleep a bounded
    //! slice, then report "treat every connection as readable"
    //! (spurious readiness is free on nonblocking sockets).  Correct
    //! but O(connections) per tick; the epoll build is the scale
    //! path.

    use std::io::Read;
    use std::net::TcpStream;
    use std::time::Duration;

    pub struct Poller {
        bell: TcpStream,
    }

    impl Poller {
        pub fn new(bell: TcpStream) -> std::io::Result<Poller> {
            Ok(Poller { bell })
        }

        pub fn add(&self, _sock: &TcpStream, _token: u64)
                   -> std::io::Result<()> {
            Ok(())
        }

        pub fn del(&self, _sock: &TcpStream) {}

        pub fn wait(&mut self, timeout: Duration, _ready: &mut Vec<u64>)
                    -> bool {
            std::thread::sleep(timeout.min(Duration::from_millis(1)));
            let mut buf = [0u8; 64];
            while matches!((&self.bell).read(&mut buf), Ok(n) if n > 0) {}
            true
        }
    }
}

/// A nonblocking loopback socket pair: the write half lives with the
/// acceptor, the read half is registered in the worker's poller, and
/// one byte rings the worker awake.
fn doorbell_pair() -> Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind((Ipv4Addr::LOCALHOST, 0))
        .context("bind doorbell listener")?;
    let addr = l.local_addr()?;
    let tx = TcpStream::connect(addr).context("connect doorbell")?;
    let (rx, _) = l.accept().context("accept doorbell")?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    let _ = tx.set_nodelay(true);
    Ok((tx, rx))
}

/// Ring a worker doorbell: nonblocking one-byte write.  A full buffer
/// means unread wakeups are already pending, which is just as good.
fn ring(bell: &TcpStream) {
    let _ = (&mut &*bell).write(&[1u8]);
}

// ---------------- server side: connections ------------------------------

/// The per-worker slice of [`ServeConfig`] the connection handlers
/// need.
struct WorkerCfg {
    /// how long the outbound queue may stay full before the client is
    /// declared slow
    stall: Duration,
    /// outbound queue bound (frames)
    cap: usize,
    auth_token: String,
    rate_limit: f64,
}

impl WorkerCfg {
    fn from_serve(serve: &ServeConfig) -> WorkerCfg {
        WorkerCfg {
            stall: Duration::from_millis(serve.write_stall_ms.max(1)),
            cap: serve.net_send_queue.max(1),
            auth_token: serve.auth_token.clone(),
            rate_limit: serve.rate_limit,
        }
    }
}

struct StreamEntry {
    stream: ClipStream,
    cancel: StreamCancel,
    /// whether the last chunk seen carried `last: true` — decides the
    /// `done` terminal's `complete` flag
    complete: bool,
}

/// One multiplexed connection: decoder state, in-flight work, and the
/// bounded outbound queue, all owned by exactly one worker thread.
struct Conn {
    sock: TcpStream,
    decoder: FrameDecoder,
    /// per-frame outbound fault site (`drop-conn` / `slow-client`
    /// chaos clauses)
    injector: FaultInjector,
    cap: usize,
    outq: VecDeque<Vec<u8>>,
    /// bytes of `outq[0]` already written
    out_pos: usize,
    /// fault-injection latch: the front frame has been checked
    out_checked: bool,
    /// `slow-client` chaos: writes pause until this instant
    write_paused_until: Option<Instant>,
    /// since when the outbound queue has been full
    stall_since: Option<Instant>,
    /// set after a framing/auth error: flush what's queued, then close
    closing: Option<Instant>,
    dead: bool,
    authed: bool,
    goaway_sent: bool,
    /// v1 tensor compression, opted into via `hello`
    compress: bool,
    bucket: TokenBucket,
    active: HashMap<u64, StreamEntry>,
    oneshots: HashMap<u64, Receiver<Result<GenResponse, ServeError>>>,
}

impl Conn {
    fn new(sock: TcpStream, injector: FaultInjector, cfg: &WorkerCfg,
           now: Instant) -> Conn {
        Conn {
            sock,
            decoder: FrameDecoder::new(),
            injector,
            cap: cfg.cap,
            outq: VecDeque::new(),
            out_pos: 0,
            out_checked: false,
            write_paused_until: None,
            stall_since: None,
            closing: None,
            dead: false,
            authed: cfg.auth_token.is_empty(),
            goaway_sent: false,
            compress: false,
            bucket: TokenBucket::new(cfg.rate_limit, now),
            active: HashMap::new(),
            oneshots: HashMap::new(),
        }
    }

    /// The latched wire format (v0 until the first byte arrives —
    /// error replies to undecodable openings go out debug-readable).
    fn wire(&self) -> WireFormat {
        self.decoder.wire().unwrap_or(WireFormat::V0)
    }

    fn has_room(&self) -> bool {
        self.outq.len() < self.cap
    }

    /// Anything that wants the 1ms busy timeout instead of the idle
    /// 250ms sleep.
    fn is_busy(&self) -> bool {
        self.dead
            || !self.active.is_empty()
            || !self.oneshots.is_empty()
            || !self.outq.is_empty()
            || self.write_paused_until.is_some()
            || self.closing.is_some()
            || self.stall_since.is_some()
    }

    /// Encode and enqueue one outbound frame in the connection's wire
    /// format.  Control frames always enqueue (they are small and
    /// per-request); bulk backpressure is enforced where chunks are
    /// PULLED ([`Conn::service_streams`] checks [`Conn::has_room`]).
    fn push(&mut self, meta: Json, tensor: Option<&Tensor>) {
        if self.dead {
            return;
        }
        match wire::encode(&meta, tensor, self.wire(), self.compress) {
            Ok(bytes) => self.outq.push_back(bytes),
            Err(e) => {
                // an unencodable reply (tensor over the frame cap,
                // ...) turns into a typed error where one fits
                crate::warn_!("net: encode failed: {e:#}");
                if let Some(id) = meta.get("id")
                    .and_then(|v| v.as_usize())
                {
                    if let Ok(b) = wire::encode(
                        &internal_error_frame(id as u64,
                                              &format!("{e:#}")),
                        None, self.wire(), false)
                    {
                        self.outq.push_back(b);
                    }
                }
            }
        }
    }

    /// Drain readable bytes and dispatch complete frames.  Bounded
    /// per call (4 reads of 16KB) for fairness across the worker's
    /// connections.
    fn service_read(&mut self, gw: &Arc<Gateway>, cfg: &WorkerCfg,
                    now: Instant) {
        if self.dead || self.closing.is_some() {
            return;
        }
        let mut buf = [0u8; 16 * 1024];
        for _ in 0..4 {
            let n = match self.sock.read(&mut buf) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    continue;
                }
                Err(_) => {
                    self.dead = true;
                    return;
                }
            };
            self.decoder.feed(&buf[..n]);
            loop {
                match self.decoder.next() {
                    Ok(Some(frame)) => {
                        self.dispatch(gw, cfg, frame, now);
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // framing is broken: answer WHY with a typed
                        // bad_request, flush it, then close — the
                        // byte stream cannot be resynchronized
                        self.push(error_frame(
                            None,
                            &ServeError::BadRequest(format!("{e:#}"))),
                            None);
                        self.closing = Some(now);
                        return;
                    }
                }
                if self.dead || self.closing.is_some() {
                    return;
                }
            }
            if n < buf.len() {
                return;
            }
        }
    }

    fn dispatch(&mut self, gw: &Arc<Gateway>, cfg: &WorkerCfg,
                frame: WireFrame, now: Instant) {
        let req = frame.meta;
        let op = req.get("op").and_then(|v| v.as_str());
        if op == Some("hello") {
            self.handle_hello(&req, cfg, now);
            return;
        }
        if !self.authed {
            self.push(error_frame(None, &ServeError::Unauthorized(
                "this server requires a hello frame carrying its \
                 access token".into())), None);
            self.closing = Some(now);
            return;
        }
        match op {
            Some("submit") => self.handle_submit(gw, &req, cfg, now),
            Some("metrics") => {
                let f = Json::obj()
                    .push("type", "metrics")
                    .push("snapshot", gw.metrics_snapshot());
                self.push(f, None);
            }
            Some("health") => {
                // the snapshot's health section IS the probe payload:
                // live / ready / draining, derived from the same state
                // the operator sees in `metrics`
                let snap = gw.metrics_snapshot();
                let health = snap.get("health").cloned()
                    .unwrap_or_else(Json::obj);
                self.push(Json::obj()
                    .push("type", "health")
                    .push("health", health), None);
            }
            Some("drain") => {
                gw.begin_drain();
                self.push(Json::obj()
                    .push("type", "drain_ok")
                    .push("draining", true), None);
            }
            Some("cancel") => {
                let id = req.get("id").and_then(|v| v.as_usize())
                    .unwrap_or(0) as u64;
                let found = match self.active.get(&id) {
                    Some(e) => {
                        e.cancel.cancel();
                        true
                    }
                    None => false,
                };
                self.push(Json::obj()
                    .push("type", "cancel_ok")
                    .push("id", id as usize)
                    .push("found", found), None);
            }
            Some(op) => {
                self.push(error_frame(
                    None, &ServeError::BadRequest(format!(
                        "unknown op {op:?} (valid: hello, submit, \
                         cancel, metrics, health, drain)"))), None);
            }
            None => {
                self.push(error_frame(
                    None, &ServeError::BadRequest(
                        "request has no \"op\"".into())), None);
            }
        }
    }

    fn handle_hello(&mut self, req: &Json, cfg: &WorkerCfg,
                    now: Instant) {
        if !cfg.auth_token.is_empty() {
            let ok = req.get("token").and_then(|v| v.as_str())
                .map(|t| token_eq(t, &cfg.auth_token))
                .unwrap_or(false);
            if !ok {
                self.push(error_frame(None, &ServeError::Unauthorized(
                    "bad or missing token".into())), None);
                self.closing = Some(now);
                return;
            }
        }
        self.authed = true;
        self.compress = req.get("compress").and_then(|v| v.as_bool())
            .unwrap_or(false);
        let wire = self.wire();
        self.push(Json::obj()
            .push("type", "hello_ok")
            .push("wire", wire.as_str())
            .push("compress", self.compress), None);
    }

    fn handle_submit(&mut self, gw: &Arc<Gateway>, req: &Json,
                     cfg: &WorkerCfg, now: Instant) {
        if let Some(retry_after_ms) = self.bucket.hit(cfg.rate_limit,
                                                      now) {
            self.push(rejected_frame(
                &ServeError::RateLimited { retry_after_ms }), None);
            return;
        }
        let p = parse_submit(req, gw.serve_config());
        if p.steps == 0 || p.steps > MAX_NET_STEPS {
            self.push(rejected_frame(&ServeError::BadRequest(format!(
                "steps {} out of range (1..={MAX_NET_STEPS})",
                p.steps))), None);
            return;
        }
        if p.streaming {
            match gw.submit_streaming_with(p.class, p.seed, p.steps,
                                           &p.tier, p.opts) {
                Ok(s) => {
                    let id = s.id();
                    let cancel = s.cancel_handle();
                    self.push(accepted_frame(id), None);
                    self.active.insert(id, StreamEntry {
                        stream: s, cancel, complete: false });
                }
                Err(e) => self.push(rejected_frame(&e), None),
            }
        } else {
            match gw.submit_tracked_with(p.class, p.seed, p.steps,
                                         &p.tier, p.opts) {
                // ack with the real gateway id: clip/error frames are
                // tagged with it, so pipelined one-shot submits on one
                // connection stay correlatable whatever order they
                // complete in
                Ok((id, rx)) => {
                    self.push(accepted_frame(id), None);
                    self.oneshots.insert(id, rx);
                }
                Err(e) => self.push(rejected_frame(&e), None),
            }
        }
    }

    /// Move ready chunks/results from in-flight work to the outbound
    /// queue — the polled replacement for PR-3's pump threads.
    /// Chunks are pulled only while the queue has room, so a stream
    /// never buffers past the slow-client bound.
    fn service_streams(&mut self) {
        if self.dead {
            return;
        }
        let mut active = std::mem::take(&mut self.active);
        let mut finished: Vec<u64> = Vec::new();
        for (&id, entry) in active.iter_mut() {
            loop {
                if !self.has_room() {
                    break;
                }
                match entry.stream.try_recv() {
                    Ok(Some(Ok(chunk))) => {
                        entry.complete = chunk.last;
                        self.push(chunk_meta(&chunk),
                                  Some(&chunk.frames));
                    }
                    Ok(Some(Err(e))) => {
                        // typed terminal failure (deadline, shard
                        // death, shed on retry-requeue, ...) —
                        // forwarded verbatim, then the terminal
                        self.push(error_frame(Some(id), &e), None);
                        self.push(done_frame(id, false), None);
                        finished.push(id);
                        break;
                    }
                    Ok(None) => break, // nothing buffered yet
                    Err(_) => {
                        // producer closed the channel: stream over
                        self.push(done_frame(id, entry.complete), None);
                        finished.push(id);
                        break;
                    }
                }
            }
        }
        for id in &finished {
            active.remove(id);
        }
        self.active = active;

        let mut oneshots = std::mem::take(&mut self.oneshots);
        let mut done: Vec<u64> = Vec::new();
        for (&id, rx) in oneshots.iter() {
            match rx.try_recv() {
                Ok(Ok(resp)) => {
                    self.push(clip_meta(&resp), Some(&resp.clip));
                    done.push(id);
                }
                Ok(Err(e)) => {
                    self.push(error_frame(Some(id), &e), None);
                    done.push(id);
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    self.push(internal_error_frame(
                        id, "server dropped the request"), None);
                    done.push(id);
                }
            }
        }
        for id in &done {
            oneshots.remove(id);
        }
        self.oneshots = oneshots;
    }

    /// Write queued frames until the socket would block.  The
    /// per-frame fault check runs once per frame, exactly where the
    /// old writer thread ran it, so `drop-conn` / `slow-client` chaos
    /// clauses keep their meaning.
    fn flush(&mut self, now: Instant) {
        if self.dead {
            return;
        }
        if let Some(until) = self.write_paused_until {
            if now < until {
                return;
            }
            self.write_paused_until = None;
        }
        while !self.outq.is_empty() {
            if self.out_pos == 0 && !self.out_checked {
                self.out_checked = true;
                match self.injector.check() {
                    FaultAction::DropConn => {
                        // kill BOTH halves so the disconnect sweep
                        // runs — exactly where a flaky network would
                        let _ = self.sock.shutdown(Shutdown::Both);
                        self.dead = true;
                        return;
                    }
                    // slow-client chaos: writes stall, frames pile up
                    // in the bounded queue — exactly how a peer that
                    // stopped reading presents
                    FaultAction::Slow(d)
                    | FaultAction::SlowClient(d) => {
                        self.write_paused_until = Some(now + d);
                        return;
                    }
                    FaultAction::Panic | FaultAction::Hang
                    | FaultAction::None => {}
                }
            }
            let frame_len = self.outq[0].len();
            match self.sock.write(&self.outq[0][self.out_pos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => {
                    self.out_pos += n;
                    if self.out_pos >= frame_len {
                        self.outq.pop_front();
                        self.out_pos = 0;
                        self.out_checked = false;
                    }
                }
                Err(e) if e.kind()
                    == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind()
                    == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// One reactor turn: drain notices, poll in-flight work, write,
    /// and enforce the slow-client and close-after-error deadlines.
    fn tick(&mut self, cfg: &WorkerCfg, draining: bool, now: Instant) {
        if self.dead {
            return;
        }
        if draining && !self.goaway_sent {
            self.goaway_sent = true;
            self.push(goaway_frame(), None);
        }
        self.service_streams();
        self.flush(now);
        if self.outq.len() >= self.cap {
            if self.stall_since.is_none() {
                self.stall_since = Some(now);
            }
        } else {
            self.stall_since = None;
        }
        if let Some(since) = self.stall_since {
            if now.saturating_duration_since(since) >= cfg.stall {
                crate::warn_!(
                    "slow client: outbound queue stalled over {:?}; \
                     cancelling {} stream(s) and dropping the \
                     connection",
                    cfg.stall, self.active.len());
                let _ = self.sock.shutdown(Shutdown::Both);
                self.dead = true;
                return;
            }
        }
        if let Some(since) = self.closing {
            if self.outq.is_empty()
                || now.saturating_duration_since(since) >= cfg.stall
            {
                let _ = self.sock.shutdown(Shutdown::Both);
                self.dead = true;
            }
        }
    }

    /// cancel-on-disconnect: whatever this client still had in flight
    /// is dead work now — cancelling frees the shard slots through
    /// the normal cancel path.
    fn teardown(&mut self) {
        for (_, entry) in self.active.drain() {
            entry.cancel.cancel();
            drop(entry.stream);
        }
        self.oneshots.clear();
        let _ = self.sock.shutdown(Shutdown::Both);
    }

    /// Best-effort blocking flush at worker exit, so buffered
    /// terminal frames (`done`, `goaway`, `drain_ok`) reach
    /// well-behaved peers before the socket drops.
    fn final_flush(&mut self) {
        if self.dead || self.outq.is_empty() {
            return;
        }
        let _ = self.sock.set_nonblocking(false);
        let _ = self.sock
            .set_write_timeout(Some(Duration::from_millis(250)));
        if self.out_pos > 0 {
            let rest: Vec<u8> = self.outq[0][self.out_pos..].to_vec();
            if self.sock.write_all(&rest).is_err() {
                return;
            }
            self.outq.pop_front();
            self.out_pos = 0;
        }
        while let Some(frame) = self.outq.pop_front() {
            if self.sock.write_all(&frame).is_err() {
                return;
            }
        }
        let _ = self.sock.flush();
    }
}

// ---------------- server side: workers + frontend -----------------------

type Handoff = (TcpStream, u64, FaultInjector);

fn worker_loop(gw: Arc<Gateway>, inbox: Receiver<Handoff>,
               bell: TcpStream, stop: Arc<AtomicBool>,
               draining: Arc<AtomicBool>) {
    let cfg = WorkerCfg::from_serve(gw.serve_config());
    let mut poller = match poll::Poller::new(bell) {
        Ok(p) => p,
        Err(e) => {
            crate::warn_!("net worker: poller init failed: {e}");
            return;
        }
    };
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut ready: Vec<u64> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // adopt handed-off connections
        while let Ok((sock, token, injector)) = inbox.try_recv() {
            let _ = sock.set_nodelay(true);
            if sock.set_nonblocking(true).is_err() {
                continue;
            }
            if let Err(e) = poller.add(&sock, token) {
                crate::warn_!("net worker: register failed: {e}");
                continue;
            }
            let now = Instant::now();
            let mut conn = Conn::new(sock, injector, &cfg, now);
            if draining.load(Ordering::Relaxed) {
                // the server is already draining: say so up front
                conn.goaway_sent = true;
                conn.push(goaway_frame(), None);
            }
            conns.insert(token, conn);
        }
        let busy = conns.values().any(|c| c.is_busy());
        let timeout = if busy {
            Duration::from_millis(1)
        } else {
            Duration::from_millis(250)
        };
        ready.clear();
        let all_readable = poller.wait(timeout, &mut ready);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let now = Instant::now();
        let is_draining = draining.load(Ordering::Relaxed);
        if all_readable {
            for conn in conns.values_mut() {
                conn.service_read(&gw, &cfg, now);
            }
        } else {
            for token in &ready {
                if let Some(conn) = conns.get_mut(token) {
                    conn.service_read(&gw, &cfg, now);
                }
            }
        }
        for conn in conns.values_mut() {
            conn.tick(&cfg, is_draining, now);
        }
        conns.retain(|_, conn| {
            if conn.dead {
                poller.del(&conn.sock);
                conn.teardown();
                false
            } else {
                true
            }
        });
    }
    for (_, mut conn) in conns.drain() {
        conn.final_flush();
        conn.teardown();
    }
}

/// The listening half: accepts connections and hands them to the
/// reactor workers.  Owned by [`super::server::Server`]; tests start
/// one over a mock-backed gateway directly.
pub struct NetFrontend {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    doorbells: Vec<TcpStream>,
    draining: Arc<AtomicBool>,
}

impl NetFrontend {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start the accept loop + worker pool.
    pub fn start(gateway: Arc<Gateway>, addr: &str)
                 -> Result<NetFrontend> {
        NetFrontend::start_with_faults(gateway, addr, FaultPlan::none())
    }

    /// [`NetFrontend::start`] with a fault plan: each accepted
    /// connection gets a deterministic net-site [`FaultInjector`]
    /// keyed by its accept ordinal, so `drop-conn` chaos runs replay
    /// per (plan, seed).
    pub fn start_with_faults(gateway: Arc<Gateway>, addr: &str,
                             plan: FaultPlan) -> Result<NetFrontend> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let n_workers = gateway.serve_config().net_workers.max(1);
        let mut workers = Vec::with_capacity(n_workers);
        let mut doorbells = Vec::with_capacity(n_workers);
        let mut inboxes = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = channel::<Handoff>();
            let (bell_tx, bell_rx) = doorbell_pair()?;
            let gw = Arc::clone(&gateway);
            let stop2 = Arc::clone(&stop);
            let draining2 = Arc::clone(&draining);
            let h = std::thread::Builder::new()
                .name(format!("sla2-net-io-{w}"))
                .spawn(move || {
                    worker_loop(gw, rx, bell_rx, stop2, draining2)
                })?;
            workers.push(h);
            doorbells.push(bell_tx);
            inboxes.push(tx);
        }
        let bells: Vec<TcpStream> = doorbells.iter()
            .map(|b| b.try_clone())
            .collect::<std::io::Result<_>>()?;
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("sla2-net-accept".into())
            .spawn(move || {
                let mut ordinal: u64 = 0;
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(sock) => {
                            let injector = if plan.has_net_faults() {
                                plan.net_injector(ordinal)
                            } else {
                                FaultInjector::inert()
                            };
                            let w = (ordinal % inboxes.len() as u64)
                                as usize;
                            if inboxes[w]
                                .send((sock, ordinal, injector))
                                .is_ok()
                            {
                                ring(&bells[w]);
                            }
                            ordinal += 1;
                        }
                        Err(e) => {
                            crate::warn_!("accept failed: {e}");
                        }
                    }
                }
            })?;
        Ok(NetFrontend { local_addr, stop,
                         accept_thread: Some(accept_thread),
                         workers, doorbells, draining })
    }

    /// The bound address (port 0 resolved to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Mark the frontend draining and wake every worker: each live
    /// connection gets a `goaway` frame on its next tick, and
    /// connections accepted from now on get it as their first frame.
    /// Admission itself is flipped by the caller
    /// ([`super::server::Server::drain`] / the `drain` verb).
    pub fn announce_drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
        crate::info!("net: goaway broadcast over {} worker(s)",
                     self.doorbells.len());
        for bell in &self.doorbells {
            ring(bell);
        }
    }

    /// Stop accepting and wind the workers down (each gives its
    /// connections a best-effort final flush so buffered terminals go
    /// out).
    pub fn shutdown(&mut self) {
        if let Some(h) = self.accept_thread.take() {
            self.stop.store(true, Ordering::Relaxed);
            // the accept loop only observes `stop` on its next
            // connection: poke it awake
            let mut wake = self.local_addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
            }
            let _ = TcpStream::connect(wake);
            let _ = h.join();
            for bell in &self.doorbells {
                ring(bell);
            }
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl Drop for NetFrontend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------- client side -------------------------------------------

/// Connection options for [`NetClient::connect_with`].
#[derive(Debug, Clone)]
pub struct ClientOpts {
    /// wire format to speak (the server answers in kind)
    pub wire: WireFormat,
    /// auth token, required against servers started with
    /// `--auth-token`
    pub token: Option<String>,
    /// opt into v1 tensor compression (ignored on v0)
    pub compress: bool,
}

impl Default for ClientOpts {
    fn default() -> ClientOpts {
        ClientOpts { wire: WireFormat::V1, token: None, compress: false }
    }
}

/// Minimal blocking client for the wire protocol, used by the
/// `sla2-stream-client` binary and the integration tests.  Speaks v1
/// by default (v0 via [`ClientOpts`]).  Designed for sequential use:
/// submit, then consume that request's frames; frames for other
/// requests encountered while scanning are buffered and replayed in
/// order.
pub struct NetClient {
    sock: TcpStream,
    decoder: FrameDecoder,
    wire: WireFormat,
    pending: VecDeque<WireFrame>,
}

impl NetClient {
    /// Connect with the defaults: v1, no token, no compression.
    pub fn connect(addr: &str) -> Result<NetClient> {
        NetClient::connect_with(addr, ClientOpts::default())
    }

    /// Connect with explicit options.  A `hello` handshake is sent
    /// (and its ack awaited) whenever a token or compression is in
    /// play; a bare connect skips it, matching v0 clients.
    pub fn connect_with(addr: &str, opts: ClientOpts)
                        -> Result<NetClient> {
        let sock = TcpStream::connect(addr)
            .with_context(|| format!("connect {addr}"))?;
        let _ = sock.set_nodelay(true);
        let mut c = NetClient {
            sock,
            decoder: FrameDecoder::with_format(opts.wire),
            wire: opts.wire,
            pending: VecDeque::new(),
        };
        if opts.token.is_some() || opts.compress {
            let mut hello = Json::obj().push("op", "hello");
            if let Some(t) = &opts.token {
                hello = hello.push("token", t.as_str());
            }
            hello = hello.push("wire", opts.wire.as_str())
                         .push("compress", opts.compress);
            c.send(&hello)?;
            let f = c.wait_for(|f| {
                matches!(f.get("type").and_then(|v| v.as_str()),
                         Some("hello_ok") | Some("error"))
            })?;
            if f.meta.get("type").and_then(|v| v.as_str())
                != Some("hello_ok")
            {
                let e = error_from_frame(&f.meta);
                return Err(anyhow::Error::new(e.clone())
                    .context(format!("hello rejected: {e}")));
            }
        }
        Ok(c)
    }

    /// Send one request frame in the connection's wire format.
    pub fn send(&mut self, frame: &Json) -> Result<()> {
        let bytes = wire::encode(frame, None, self.wire, false)?;
        self.sock.write_all(&bytes)?;
        Ok(())
    }

    fn read_more(&mut self) -> Result<()> {
        let mut buf = [0u8; 64 * 1024];
        let n = self.sock.read(&mut buf)?;
        anyhow::ensure!(n > 0, "connection closed");
        self.decoder.feed(&buf[..n]);
        Ok(())
    }

    /// Next decoded frame, tensor out-of-band on v1: replays buffered
    /// frames first, then reads the wire.
    pub fn next_wire(&mut self) -> Result<WireFrame> {
        if let Some(f) = self.pending.pop_front() {
            return Ok(f);
        }
        loop {
            if let Some(f) = self.decoder.next()? {
                return Ok(f);
            }
            self.read_more()?;
        }
    }

    /// Next frame as inline JSON (v0-shaped whatever the wire): the
    /// back-compatible view; costly for large tensors.
    pub fn next_frame(&mut self) -> Result<Json> {
        self.next_wire()?.into_inline()
    }

    /// Read until `pred` matches a frame's meta, buffering everything
    /// else in order.
    fn wait_for(&mut self, pred: impl Fn(&Json) -> bool)
                -> Result<WireFrame> {
        for i in 0..self.pending.len() {
            if pred(&self.pending[i].meta) {
                if let Some(f) = self.pending.remove(i) {
                    return Ok(f);
                }
            }
        }
        loop {
            if let Some(f) = self.decoder.next()? {
                if pred(&f.meta) {
                    return Ok(f);
                }
                self.pending.push_back(f);
                continue;
            }
            self.read_more()?;
        }
    }

    /// Submit; `Ok(id)` on accept (streaming and one-shot submits both
    /// ack with the gateway-allocated request id).  On rejection the
    /// `Err` wraps the typed [`ServeError`] — downcast to inspect the
    /// code / `retry_after_ms`.
    pub fn submit(&mut self, class: i32, seed: u64, steps: usize,
                  tier: &str, streaming: bool) -> Result<u64> {
        self.submit_with(class, seed, steps, tier, streaming,
                         SubmitOpts::default())
    }

    /// [`NetClient::submit`] with per-request options (deadline,
    /// degradation opt-in) carried on the wire.
    pub fn submit_with(&mut self, class: i32, seed: u64, steps: usize,
                       tier: &str, streaming: bool, opts: SubmitOpts)
                       -> Result<u64> {
        self.send(&Json::obj()
            .push("op", "submit")
            .push("class", class as i64)
            .push("seed", seed as f64)
            .push("steps", steps)
            .push("tier", tier)
            .push("stream", streaming)
            .push("deadline_ms", opts.deadline_ms as usize)
            .push("allow_degrade", opts.allow_degrade)
            .push_opt("variant", opts.variant))?;
        // an unscoped error (auth failure, framing complaint) must
        // surface too, or the client would hang on a closing socket
        let ack = self.wait_for(|f| {
            matches!(f.get("type").and_then(|v| v.as_str()),
                     Some("accepted") | Some("rejected"))
                || (f.get("type").and_then(|v| v.as_str())
                        == Some("error")
                    && f.get("id").is_none())
        })?;
        match ack.meta.get("type").and_then(|v| v.as_str()) {
            Some("accepted") => Ok(ack.meta.get("id")
                .and_then(|v| v.as_usize()).unwrap_or(0) as u64),
            _ => {
                let e = error_from_frame(&ack.meta);
                Err(anyhow::Error::new(e.clone())
                    .context(format!("submit rejected: {e}")))
            }
        }
    }

    /// Consume one stream to completion, invoking `on_chunk` per
    /// chunk, and reassemble the clip (validating order and
    /// completeness).
    pub fn collect_stream_with(
        &mut self, id: u64, mut on_chunk: impl FnMut(&ClipChunk))
        -> Result<GenResponse> {
        let of_id = move |f: &Json| {
            f.get("id").and_then(|v| v.as_usize()).map(|v| v as u64)
                == Some(id)
        };
        let mut chunks: Vec<ClipChunk> = Vec::new();
        loop {
            let f = self.wait_for(|f| {
                of_id(f)
                    && matches!(f.get("type").and_then(|v| v.as_str()),
                                Some("chunk") | Some("done")
                                | Some("error"))
            })?;
            match f.meta.get("type").and_then(|v| v.as_str()) {
                Some("chunk") => {
                    let c = chunk_from_frame(&f)?;
                    on_chunk(&c);
                    chunks.push(c);
                }
                Some("done") => {
                    return stream::assemble_response(id, chunks);
                }
                _ => {
                    let e = error_from_frame(&f.meta);
                    return Err(anyhow::Error::new(e.clone())
                        .context(format!("stream {id} failed: {e}")));
                }
            }
        }
    }

    pub fn collect_stream(&mut self, id: u64) -> Result<GenResponse> {
        self.collect_stream_with(id, |_| {})
    }

    /// Wait for one non-streaming submit's clip frame, matched by the
    /// id its ack returned (results answer in completion order, not
    /// submit order).
    pub fn collect_clip(&mut self, id: u64) -> Result<GenResponse> {
        let f = self.wait_for(|f| {
            f.get("id").and_then(|v| v.as_usize()).map(|v| v as u64)
                == Some(id)
                && matches!(f.get("type").and_then(|v| v.as_str()),
                            Some("clip") | Some("error"))
        })?;
        match f.meta.get("type").and_then(|v| v.as_str()) {
            Some("clip") => {
                let mut resp = clip_from_frame(&f)?;
                resp.id = id;
                Ok(resp)
            }
            _ => {
                let e = error_from_frame(&f.meta);
                Err(anyhow::Error::new(e.clone())
                    .context(format!("request {id} failed: {e}")))
            }
        }
    }

    /// Request and await a server metrics snapshot.
    pub fn metrics_snapshot(&mut self) -> Result<Json> {
        self.send(&Json::obj().push("op", "metrics"))?;
        let f = self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("metrics")
        })?;
        Ok(f.meta.req("snapshot")?.clone())
    }

    /// Probe liveness/readiness; returns the server's health object
    /// (`{live, ready, draining}`).
    pub fn health(&mut self) -> Result<Json> {
        self.send(&Json::obj().push("op", "health"))?;
        let f = self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("health")
        })?;
        Ok(f.meta.req("health")?.clone())
    }

    /// Ask the server to begin a graceful drain (admission flips to
    /// typed `shutting_down`; in-flight work completes).
    pub fn drain(&mut self) -> Result<()> {
        self.send(&Json::obj().push("op", "drain"))?;
        self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("drain_ok")
        })?;
        Ok(())
    }

    /// Cancel an in-flight streaming request; `Ok(found)`.
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        self.send(&Json::obj()
            .push("op", "cancel")
            .push("id", id as usize))?;
        let f = self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("cancel_ok")
                && f.get("id").and_then(|v| v.as_usize())
                    .map(|v| v as u64) == Some(id)
        })?;
        Ok(f.meta.get("found").and_then(|v| v.as_bool())
            .unwrap_or(false))
    }
}

// ---------------- TLS (stub) --------------------------------------------

/// Transport encryption, reserved behind the `tls` cargo feature.
///
/// The offline registry carries no TLS implementation, so this module
/// only pins the API shape the real handshake will slot into: both
/// halves return a typed "not implemented" error.  Building without
/// the feature removes the module entirely, so nothing can link
/// against a TLS that is not there.
#[cfg(feature = "tls")]
pub mod tls {
    use std::net::TcpStream;

    use anyhow::{bail, Result};

    /// Server-side accept wrapper: will perform the TLS handshake on
    /// `sock` once an implementation lands.
    pub fn accept(_sock: TcpStream) -> Result<TcpStream> {
        bail!("tls: enabled at build time but not implemented — the \
               offline registry has no TLS crate; terminate TLS in \
               front of the server for now")
    }

    /// Client-side connect wrapper, mirroring [`accept`].
    pub fn connect(_sock: TcpStream, _host: &str) -> Result<TcpStream> {
        bail!("tls: enabled at build time but not implemented — the \
               offline registry has no TLS crate; terminate TLS in \
               front of the server for now")
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let j = Json::obj().push("op", "metrics").push("x", 1.5);
        let mut buf = Vec::new();
        write_frame(&mut buf, &j).unwrap();
        let mut r = Cursor::new(buf);
        let back = read_frame(&mut r, MAX_FRAME_LEN).unwrap().unwrap();
        assert_eq!(back, j);
        // clean EOF after the frame
        assert!(read_frame(&mut r, MAX_FRAME_LEN).unwrap().is_none());
    }

    #[test]
    fn read_frame_rejects_oversized_and_malformed() {
        // oversized: length prefix beyond the cap
        let mut buf = Vec::new();
        buf.extend_from_slice(&(64u32).to_be_bytes());
        buf.extend_from_slice(&[b'{'; 64]);
        let err = read_frame(&mut Cursor::new(&buf), 16).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
        // malformed JSON body
        let mut buf = Vec::new();
        buf.extend_from_slice(&(3u32).to_be_bytes());
        buf.extend_from_slice(b"{x}");
        let err = read_frame(&mut Cursor::new(&buf), MAX_FRAME_LEN)
            .unwrap_err();
        assert!(err.to_string().contains("malformed"), "{err}");
        // truncated body
        let mut buf = Vec::new();
        buf.extend_from_slice(&(10u32).to_be_bytes());
        buf.extend_from_slice(b"{}");
        assert!(read_frame(&mut Cursor::new(&buf), MAX_FRAME_LEN)
                    .is_err());
    }

    #[test]
    fn typed_error_frames_roundtrip_through_the_wire() {
        let err = ServeError::Overloaded { retry_after_ms: 75 };
        let text = rejected_frame(&err).to_string();
        let f = Json::parse(&text).unwrap();
        assert_eq!(f.get("code").and_then(|v| v.as_str()),
                   Some("overloaded"));
        assert_eq!(f.get("retryable").and_then(|v| v.as_bool()),
                   Some(true));
        assert_eq!(error_from_frame(&f), err);

        let err = ServeError::BadRequest("no \"op\"".into());
        let f = Json::parse(&error_frame(None, &err).to_string()).unwrap();
        assert_eq!(f.get("code").and_then(|v| v.as_str()),
                   Some("bad_request"));
        let back = error_from_frame(&f);
        assert_eq!(back.code(), err.code());
        assert!(!back.retryable());
        assert!(back.to_string().contains("no \"op\""));

        // the transport-hardening additions survive the wire too
        let err = ServeError::RateLimited { retry_after_ms: 40 };
        let f = Json::parse(&rejected_frame(&err).to_string()).unwrap();
        assert_eq!(f.get("code").and_then(|v| v.as_str()),
                   Some("rate_limited"));
        assert_eq!(f.get("retry_after_ms").and_then(|v| v.as_usize()),
                   Some(40));
        assert_eq!(error_from_frame(&f), err);

        let err = ServeError::Unauthorized("bad or missing token".into());
        let f = Json::parse(&error_frame(None, &err).to_string())
            .unwrap();
        assert_eq!(f.get("code").and_then(|v| v.as_str()),
                   Some("unauthorized"));
        assert_eq!(f.get("retryable").and_then(|v| v.as_bool()),
                   Some(false));
        let back = error_from_frame(&f);
        assert_eq!(back.code(), err.code());
        assert!(!back.retryable());

        // legacy frame without a code decodes as terminal shard_failed
        let legacy = Json::obj().push("type", "error")
            .push("error", "boom");
        let back = error_from_frame(&legacy);
        assert_eq!(back.code(), "shard_failed");
        assert!(!back.retryable());
    }

    #[test]
    fn tensor_json_roundtrip_is_bit_exact() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(3);
        let t = Tensor::randn(&[2, 3, 4], &mut rng);
        // through the actual WIRE TEXT, not just the Json tree: the
        // f32 -> double -> shortest-decimal -> double -> f32 path
        // must be lossless
        let text = tensor_to_json(&t).unwrap().to_string();
        let back = tensor_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn chunk_json_roundtrip() {
        let c = ClipChunk {
            id: 7, seq: 2, frame_start: 2, frame_end: 3, total_frames: 4,
            last: false,
            frames: Tensor::from_f32(&[1, 2], vec![0.25, -1.5]).unwrap(),
            metrics: RequestMetrics { queue_ms: 1.0, compute_ms: 2.0,
                                      steps: 4, batch_size: 2 },
        };
        let text = chunk_to_json(&c).unwrap().to_string();
        let back = chunk_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.seq, 2);
        assert_eq!(back.frames, c.frames);
        assert_eq!(back.metrics.batch_size, 2);
        assert!(!back.last);
    }

    #[test]
    fn chunk_frames_decode_identically_from_both_wires() {
        let c = ClipChunk {
            id: 9, seq: 0, frame_start: 0, frame_end: 2, total_frames: 2,
            last: true,
            frames: Tensor::from_f32(&[2, 2],
                                     vec![0.5, -0.25, 3.0, f32::MIN_POSITIVE])
                .unwrap(),
            metrics: RequestMetrics::default(),
        };
        for fmt in [WireFormat::V0, WireFormat::V1] {
            let bytes = wire::encode(&chunk_meta(&c), Some(&c.frames),
                                     fmt, false).unwrap();
            let mut d = FrameDecoder::new();
            d.feed(&bytes);
            let f = d.next().unwrap().unwrap();
            let back = chunk_from_frame(&f).unwrap();
            assert_eq!(back.id, c.id, "{fmt:?}");
            assert_eq!(back.frames, c.frames, "{fmt:?}");
            assert!(back.last, "{fmt:?}");
        }
    }

    #[test]
    fn parse_submit_is_wire_agnostic() {
        let serve = ServeConfig::default();
        let req = Json::obj()
            .push("op", "submit")
            .push("class", 3i64)
            .push("seed", 41.0)
            .push("steps", 6usize)
            .push("tier", "s95")
            .push("stream", false)
            .push("deadline_ms", 120usize)
            .push("allow_degrade", true)
            .push("variant", "sparge2");
        let mut params = Vec::new();
        for fmt in [WireFormat::V0, WireFormat::V1] {
            let bytes = wire::encode(&req, None, fmt, false).unwrap();
            let mut d = FrameDecoder::new();
            d.feed(&bytes);
            let meta = d.next().unwrap().unwrap().meta;
            params.push(parse_submit(&meta, &serve));
        }
        for p in &params {
            assert_eq!(p.class, 3);
            assert_eq!(p.seed, 41);
            assert_eq!(p.steps, 6);
            assert_eq!(p.tier, "s95");
            assert!(!p.streaming);
            assert_eq!(p.opts.deadline_ms, 120);
            assert!(p.opts.allow_degrade);
            assert_eq!(p.opts.variant.as_deref(), Some("sparge2"));
        }
        // defaults fill in identically too
        let bare = Json::obj().push("op", "submit");
        let p = parse_submit(&bare, &serve);
        assert_eq!(p.steps, serve.sample_steps);
        assert_eq!(p.tier, serve.tier);
        assert!(p.streaming);
        assert_eq!(p.opts.variant, None);
    }

    #[test]
    fn token_eq_is_length_and_content_sensitive() {
        assert!(token_eq("secret", "secret"));
        assert!(!token_eq("secret", "secreT"));
        assert!(!token_eq("secret", "secre"));
        assert!(!token_eq("", "x"));
        assert!(token_eq("", ""));
    }

    #[test]
    fn token_bucket_admits_burst_then_throttles() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(2.0, t0);
        assert_eq!(b.hit(2.0, t0), None);
        assert_eq!(b.hit(2.0, t0), None);
        let hint = b.hit(2.0, t0).expect("burst exhausted");
        assert!(hint >= 1 && hint <= 500, "{hint}");
        // half a second refills one token at 2/s
        let t1 = t0 + Duration::from_millis(500);
        assert_eq!(b.hit(2.0, t1), None);
        // rate 0 = unlimited
        let mut open = TokenBucket::new(0.0, t0);
        for _ in 0..100 {
            assert_eq!(open.hit(0.0, t0), None);
        }
    }
}
