//! TCP network frontend: length-prefixed JSON framing over the
//! [`Gateway`], plus the matching [`NetClient`].
//!
//! # Wire protocol (v1)
//!
//! Every message is a **frame**: a 4-byte big-endian unsigned length
//! `n` (capped at [`MAX_FRAME_LEN`]) followed by exactly `n` bytes of
//! UTF-8 JSON (the [`crate::util::json`] subset).  Frames flow both
//! ways on one connection; the server multiplexes responses for every
//! in-flight request onto the socket, tagged by request `id`.
//! Numbers travel as JSON doubles, so integer fields (ids, seeds) are
//! exact up to 2^53.
//!
//! Client -> server verbs (the `"op"` field):
//!
//! | op        | fields                                             |
//! |-----------|----------------------------------------------------|
//! | `submit`  | `class`, `seed`, `steps` (1..=[`MAX_NET_STEPS`]),  |
//! |           | `tier`, `stream` (bool), `deadline_ms` (0 = server |
//! |           | default), `allow_degrade` (bool)                   |
//! | `cancel`  | `id` — cancel an in-flight streaming request       |
//! | `metrics` | none — request a metrics snapshot                  |
//! | `health`  | none — liveness/readiness probe (cheap; safe for   |
//! |           | load balancers to poll)                            |
//! | `drain`   | none — begin graceful drain: admission flips to    |
//! |           | typed `shutting_down`, in-flight work completes    |
//!
//! Server -> client frames (the `"type"` field):
//!
//! * `accepted` / `rejected` — submit ack: `{id}` or a typed failure
//!   (see the error fields below; rejection = shed, backpressure or
//!   shutdown).
//! * `chunk` — one streamed frame range: `id`, `seq`, `frame_start`,
//!   `frame_end`, `total_frames`, `last`, `frames` (tensor), and the
//!   request `metrics`; chunks for an id arrive in `seq` order.
//! * `done` — stream terminal: `{id, complete}`; `complete` is false
//!   when the stream ended without its last chunk (cancel/failure).
//! * `clip` — non-streaming result: `{id, clip, metrics}`.
//! * `metrics` — `{snapshot}`.
//! * `cancel_ok` — `{id, found}`.
//! * `health` — `{health: {live, ready, draining}}` (the snapshot's
//!   health section).
//! * `drain_ok` — `{draining: true}`, ack for the `drain` verb.
//! * `goaway` — unsolicited drain notice: the server has begun
//!   draining; finish consuming in-flight streams (they complete) and
//!   do not submit again on this connection.
//! * `error` — a typed failure and, for request-scoped failures,
//!   `{id}`.  Framing-level errors (malformed JSON, oversized frame)
//!   send a `bad_request` error frame and then close the connection,
//!   since the byte stream can no longer be trusted.
//!
//! Typed failures (`rejected` and `error` frames) carry:
//!
//! * `error` — human-readable message,
//! * `code` — machine-readable [`ServeError`] code: `overloaded` |
//!   `deadline_exceeded` | `shard_failed` | `shard_stalled` |
//!   `cancelled` | `bad_request` | `shutting_down`,
//! * `retryable` — whether retrying the same request may succeed,
//! * `retry_after_ms` — backoff hint, present on `overloaded` only.
//!
//! Tensors are `{"shape": [..], "data": [f32 as double, ..]}` —
//! lossless for f32 (every f32 is exactly representable as a double
//! and the writer emits shortest-roundtrip decimals).
//!
//! Not covered (recorded in ROADMAP.md): TLS, authentication,
//! compression, binary tensor payloads.
//!
//! # Threads
//!
//! One listener thread; per connection, a reader thread (this is the
//! connection's request loop), one writer thread serializing outbound
//! frames, and one short-lived pump thread per in-flight request
//! moving chunks from its [`stream::ClipStream`] to the writer.  A
//! dropped
//! connection cancels every stream it still owns, so abandoned
//! clients release their shard slots (see
//! [`crate::coordinator::stream`]).
//!
//! # Slow-client protection
//!
//! The outbound path is BOUNDED: the writer consumes a
//! `sync_channel(ServeConfig::net_send_queue)` of frames, and a sender
//! (the reader answering a verb, or a pump thread moving chunks) waits
//! at most `ServeConfig::write_stall_ms` for queue space.  A client
//! that stops reading fills its queue, the next send times out, and
//! the connection is declared slow: every stream it owns is cancelled
//! through the normal cancel path (freeing shard slots) and the socket
//! is severed.  One stuck client can therefore never wedge a pump
//! thread or hold shard-side work hostage — it costs exactly one
//! bounded queue of frames, then it is gone.

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{IpAddr, Ipv4Addr, Shutdown, SocketAddr, TcpListener,
               TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::error::ServeError;
use super::pool::lock_recover;
use super::request::{GenResponse, RequestMetrics};
use super::server::{Gateway, SubmitOpts};
use super::stream::{self, ClipChunk, StreamCancel};
use crate::tensor::Tensor;
use crate::util::faults::{FaultAction, FaultInjector, FaultPlan};
use crate::util::json::Json;

/// Hard cap on a single frame (header `n`), both directions.  Far
/// above any legitimate chunk on the testbed models; anything larger
/// is treated as a protocol violation and closes the connection.
pub const MAX_FRAME_LEN: usize = 16 * 1024 * 1024;

/// Hard cap on a network submit's `steps`.  Frames are size-capped by
/// [`MAX_FRAME_LEN`], but nothing else bounds per-request COMPUTE, and
/// a denoise loop cannot be interrupted once it starts — an
/// unvalidated `steps` would let one request pin a shard arbitrarily
/// long.  Requests outside `1..=MAX_NET_STEPS` are rejected.
pub const MAX_NET_STEPS: usize = 1024;

// ---------------- framing ----------------------------------------------

/// Write one length-prefixed JSON frame.
pub fn write_frame(w: &mut impl Write, j: &Json) -> Result<()> {
    let body = j.to_string();
    anyhow::ensure!(body.len() <= MAX_FRAME_LEN,
                    "frame of {} bytes exceeds the {} byte cap",
                    body.len(), MAX_FRAME_LEN);
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    Ok(())
}

/// Read one frame.  `Ok(None)` = the peer closed cleanly between
/// frames; `Err` = oversized length prefix, truncated frame, or
/// malformed JSON (the caller should drop the connection — the byte
/// stream cannot be resynchronized).
pub fn read_frame(r: &mut impl Read, max_len: usize)
                  -> Result<Option<Json>> {
    let mut header = [0u8; 4];
    // distinguish clean EOF (no header at all) from truncation
    match r.read(&mut header)? {
        0 => return Ok(None),
        mut got => {
            while got < 4 {
                let n = r.read(&mut header[got..])?;
                anyhow::ensure!(n > 0, "truncated frame header");
                got += n;
            }
        }
    }
    let len = u32::from_be_bytes(header) as usize;
    anyhow::ensure!(len <= max_len,
                    "oversized frame: {len} bytes (cap {max_len})");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("truncated frame body")?;
    let text = std::str::from_utf8(&body).context("frame is not UTF-8")?;
    let j = Json::parse(text)
        .map_err(|e| anyhow::anyhow!("malformed frame: {e}"))?;
    Ok(Some(j))
}

// ---------------- JSON <-> domain conversions ---------------------------

pub fn tensor_to_json(t: &Tensor) -> Result<Json> {
    let data: Vec<Json> =
        t.f32s()?.iter().map(|v| Json::Num(*v as f64)).collect();
    Ok(Json::obj()
        .push("shape", t.shape.as_slice())
        .push("data", data))
}

pub fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape = j.req("shape")?.as_usize_vec()
        .context("tensor shape")?;
    let data: Vec<f32> = j.req("data")?.as_arr()
        .context("tensor data")?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<_>>()
        .context("non-numeric tensor data")?;
    Tensor::from_f32(&shape, data)
}

fn metrics_to_json(m: &RequestMetrics) -> Json {
    Json::obj()
        .push("queue_ms", m.queue_ms)
        .push("compute_ms", m.compute_ms)
        .push("steps", m.steps)
        .push("batch_size", m.batch_size)
}

fn metrics_from_json(j: &Json) -> RequestMetrics {
    let f = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let u = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
    RequestMetrics { queue_ms: f("queue_ms"), compute_ms: f("compute_ms"),
                     steps: u("steps"), batch_size: u("batch_size") }
}

pub fn chunk_to_json(c: &ClipChunk) -> Result<Json> {
    Ok(Json::obj()
        .push("type", "chunk")
        .push("id", c.id as usize)
        .push("seq", c.seq)
        .push("frame_start", c.frame_start)
        .push("frame_end", c.frame_end)
        .push("total_frames", c.total_frames)
        .push("last", c.last)
        .push("frames", tensor_to_json(&c.frames)?)
        .push("metrics", metrics_to_json(&c.metrics)))
}

pub fn chunk_from_json(j: &Json) -> Result<ClipChunk> {
    let u = |k: &str| -> Result<usize> {
        j.req(k)?.as_usize().context(format!("chunk field {k}"))
    };
    Ok(ClipChunk {
        id: u("id")? as u64,
        seq: u("seq")?,
        frame_start: u("frame_start")?,
        frame_end: u("frame_end")?,
        total_frames: u("total_frames")?,
        last: j.req("last")?.as_bool().context("chunk field last")?,
        frames: tensor_from_json(j.req("frames")?)?,
        metrics: j.get("metrics").map(metrics_from_json)
            .unwrap_or_default(),
    })
}

/// The typed failure fields shared by `error` and `rejected` frames.
fn push_error_fields(mut j: Json, err: &ServeError) -> Json {
    j = j.push("error", format!("{err}"))
         .push("code", err.code())
         .push("retryable", err.retryable());
    if let Some(ms) = err.retry_after_ms() {
        j = j.push("retry_after_ms", ms as usize);
    }
    j
}

fn error_frame(id: Option<u64>, err: &ServeError) -> Json {
    let mut j = Json::obj().push("type", "error");
    if let Some(id) = id {
        j = j.push("id", id as usize);
    }
    push_error_fields(j, err)
}

fn rejected_frame(err: &ServeError) -> Json {
    push_error_fields(Json::obj().push("type", "rejected"), err)
}

/// A request-scoped internal failure (serialization and the like):
/// terminal, non-retryable.
fn internal_error_frame(id: u64, msg: &str) -> Json {
    error_frame(Some(id), &ServeError::shard_fatal(msg.to_string()))
}

// ---------------- server side -------------------------------------------

/// Per-connection outbound handle: a BOUNDED frame queue shared by the
/// reader and every pump thread, plus the machinery to declare the
/// client slow and tear the connection down (see the module docs'
/// "Slow-client protection").
#[derive(Clone)]
struct ConnTx {
    tx: SyncSender<Json>,
    /// how long a sender may wait for queue space before the client is
    /// declared slow
    stall: Duration,
    /// streams this connection still owns, by id — the `cancel` verb,
    /// the disconnect sweep and slow-client teardown all drain it
    active: Arc<Mutex<HashMap<u64, StreamCancel>>>,
    /// the raw socket, for severing a slow connection (unblocks the
    /// reader)
    sock: Arc<TcpStream>,
    /// latched once the connection has been declared slow
    dead: Arc<AtomicBool>,
}

impl ConnTx {
    /// Queue `frame` for the writer, waiting up to `stall` for space.
    /// Returns false when the connection is gone — including when this
    /// very call declared it slow: a queue that stays full past the
    /// stall budget triggers [`ConnTx::kill_slow`], so the caller must
    /// simply stop, never block.
    fn send(&self, frame: Json) -> bool {
        if self.dead.load(Ordering::Relaxed) {
            return false;
        }
        let deadline = Instant::now() + self.stall;
        let mut frame = frame;
        loop {
            match self.tx.try_send(frame) {
                Ok(()) => return true,
                Err(TrySendError::Disconnected(_)) => return false,
                Err(TrySendError::Full(f)) => {
                    if Instant::now() >= deadline {
                        self.kill_slow();
                        return false;
                    }
                    frame = f;
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    /// Slow-client teardown: cancel every stream the connection owns
    /// (frees shard slots through the normal cancel path) and sever
    /// the socket so both the reader and the writer unwind.  Latched:
    /// concurrent senders hitting the stall race to one teardown.
    fn kill_slow(&self) {
        if self.dead.swap(true, Ordering::Relaxed) {
            return;
        }
        let cancels: Vec<StreamCancel> =
            lock_recover(&self.active).drain().map(|(_, c)| c).collect();
        crate::warn_!(
            "slow client: outbound queue stalled over {:?}; cancelling \
             {} stream(s) and dropping the connection",
            self.stall, cancels.len());
        for c in cancels {
            c.cancel();
        }
        let _ = self.sock.shutdown(Shutdown::Both);
    }
}

/// The unsolicited drain notice pushed to connections when the server
/// begins draining.
fn goaway_frame() -> Json {
    Json::obj()
        .push("type", "goaway")
        .push("reason",
              "server draining: in-flight streams will complete; do \
               not submit again on this connection")
}

/// The listening half: accepts connections and serves the protocol
/// against a [`Gateway`].  Owned by [`super::server::Server`]; tests
/// start one over a mock-backed gateway directly.
pub struct NetFrontend {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// live connections by accept ordinal, for [`Self::announce_drain`]
    conns: Arc<Mutex<HashMap<u64, ConnTx>>>,
    draining: Arc<AtomicBool>,
}

impl NetFrontend {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start the accept loop.
    pub fn start(gateway: Arc<Gateway>, addr: &str) -> Result<NetFrontend> {
        NetFrontend::start_with_faults(gateway, addr, FaultPlan::none())
    }

    /// [`NetFrontend::start`] with a fault plan: each accepted
    /// connection gets a deterministic net-site [`FaultInjector`]
    /// keyed by its accept ordinal, so `drop-conn` chaos runs replay
    /// per (plan, seed).
    pub fn start_with_faults(gateway: Arc<Gateway>, addr: &str,
                             plan: FaultPlan) -> Result<NetFrontend> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let conns: Arc<Mutex<HashMap<u64, ConnTx>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let conns2 = Arc::clone(&conns);
        let draining = Arc::new(AtomicBool::new(false));
        let draining2 = Arc::clone(&draining);
        let accept_thread = std::thread::Builder::new()
            .name("sla2-net-accept".into())
            .spawn(move || {
                let mut conn_ordinal: u64 = 0;
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match conn {
                        Ok(sock) => {
                            let gw = Arc::clone(&gateway);
                            let injector = if plan.has_net_faults() {
                                plan.net_injector(conn_ordinal)
                            } else {
                                FaultInjector::inert()
                            };
                            let ordinal = conn_ordinal;
                            conn_ordinal += 1;
                            let registry = Arc::clone(&conns2);
                            let draining = Arc::clone(&draining2);
                            // connection threads are detached: they
                            // exit when their socket closes or the
                            // queue shuts down
                            let _ = std::thread::Builder::new()
                                .name("sla2-net-conn".into())
                                .spawn(move || {
                                    handle_conn(gw, sock, injector,
                                                registry, ordinal,
                                                draining)
                                });
                        }
                        Err(e) => {
                            crate::warn_!("accept failed: {e}");
                        }
                    }
                }
            })?;
        Ok(NetFrontend { local_addr, stop,
                         accept_thread: Some(accept_thread),
                         conns, draining })
    }

    /// The bound address (port 0 resolved to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Push a `goaway` frame to every live connection and mark the
    /// frontend draining (connections accepted from now on get the
    /// goaway as their first frame).  Best-effort and non-blocking: a
    /// connection whose outbound queue is full (a slow client mid
    /// teardown) is skipped — its submits get typed `shutting_down`
    /// rejections anyway.  Admission itself is flipped by the caller
    /// ([`super::server::Server::drain`] / the `drain` verb).
    pub fn announce_drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
        let conns = lock_recover(&self.conns);
        crate::info!("net: goaway to {} connection(s)", conns.len());
        for conn in conns.values() {
            let _ = conn.tx.try_send(goaway_frame());
        }
    }

    /// Stop accepting.  Existing connections wind down on their own
    /// when their sockets close or the server's queue shuts down.
    pub fn shutdown(&mut self) {
        if let Some(h) = self.accept_thread.take() {
            self.stop.store(true, Ordering::Relaxed);
            // the accept loop only observes `stop` on its next
            // connection: poke it awake
            let mut wake = self.local_addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
            }
            let _ = TcpStream::connect(wake);
            let _ = h.join();
        }
    }
}

impl Drop for NetFrontend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One connection: read request frames, fan responses back through a
/// single writer thread (one frame at a time, whatever request it
/// belongs to).  The writer is also the connection's fault-injection
/// site: each outbound frame is one net-framing event, so a
/// `drop-conn` clause severs the connection mid-conversation exactly
/// where a flaky network would, and a `slow-client` clause stalls the
/// writes so the bounded outbound queue backs up like a stuck reader.
fn handle_conn(gw: Arc<Gateway>, sock: TcpStream,
               mut injector: FaultInjector,
               registry: Arc<Mutex<HashMap<u64, ConnTx>>>, ordinal: u64,
               draining: Arc<AtomicBool>) {
    let _ = sock.set_nodelay(true);
    let (write_sock, raw_sock) = match (sock.try_clone(),
                                        sock.try_clone()) {
        (Ok(w), Ok(r)) => (w, r),
        (Err(e), _) | (_, Err(e)) => {
            crate::warn_!("connection clone failed: {e}");
            return;
        }
    };
    let serve = gw.serve_config();
    let (out_tx, out_rx) =
        sync_channel::<Json>(serve.net_send_queue.max(1));
    let writer = std::thread::Builder::new()
        .name("sla2-net-write".into())
        .spawn(move || {
            let mut w = BufWriter::new(write_sock);
            while let Ok(frame) = out_rx.recv() {
                match injector.check() {
                    FaultAction::DropConn => {
                        // kill BOTH halves so the reader unblocks and
                        // the cancel-on-disconnect sweep runs
                        let _ = w.get_ref().shutdown(Shutdown::Both);
                        break;
                    }
                    // slow-client chaos: the WRITE stalls, frames pile
                    // up in the bounded queue, senders hit the stall
                    // budget — exactly how a peer that stopped reading
                    // presents
                    FaultAction::Slow(d)
                    | FaultAction::SlowClient(d) => std::thread::sleep(d),
                    FaultAction::Panic | FaultAction::Hang
                    | FaultAction::None => {}
                }
                if write_frame(&mut w, &frame).is_err()
                    || w.flush().is_err()
                {
                    break; // peer gone; reader will notice too
                }
            }
        });
    let conn = ConnTx {
        tx: out_tx,
        stall: Duration::from_millis(serve.write_stall_ms.max(1)),
        active: Arc::new(Mutex::new(HashMap::new())),
        sock: Arc::new(raw_sock),
        dead: Arc::new(AtomicBool::new(false)),
    };
    lock_recover(&registry).insert(ordinal, conn.clone());
    if draining.load(Ordering::Relaxed) {
        // the server is already draining: say so up front
        conn.send(goaway_frame());
    }
    let mut reader = BufReader::new(sock);
    loop {
        match read_frame(&mut reader, MAX_FRAME_LEN) {
            Ok(None) => break, // client closed
            Ok(Some(req)) => {
                handle_request(&gw, &req, &conn);
            }
            Err(e) => {
                // framing is broken: tell the client WHY with a typed
                // bad_request frame, then drop the connection (the
                // writer drains the channel before exiting, so the
                // frame goes out first)
                conn.send(error_frame(
                    None, &ServeError::BadRequest(format!("{e:#}"))));
                break;
            }
        }
    }
    // cancel-on-disconnect: whatever this client still had in flight
    // is dead work now
    for (_, cancel) in lock_recover(&conn.active).drain() {
        cancel.cancel();
    }
    // deregister BEFORE joining the writer: the registry holds a
    // ConnTx clone, and the writer only exits once every sender of
    // the bounded queue is gone
    lock_recover(&registry).remove(&ordinal);
    drop(conn);
    if let Ok(w) = writer {
        let _ = w.join();
    }
}

fn handle_request(gw: &Arc<Gateway>, req: &Json, conn: &ConnTx) {
    match req.get("op").and_then(|v| v.as_str()) {
        Some("submit") => handle_submit(gw, req, conn),
        Some("metrics") => {
            conn.send(Json::obj()
                .push("type", "metrics")
                .push("snapshot", gw.metrics_snapshot()));
        }
        Some("health") => {
            // the snapshot's health section IS the probe payload:
            // live / ready / draining, derived from the same state
            // the operator sees in `metrics`
            let snap = gw.metrics_snapshot();
            let health = snap.get("health").cloned()
                .unwrap_or_else(Json::obj);
            conn.send(Json::obj()
                .push("type", "health")
                .push("health", health));
        }
        Some("drain") => {
            gw.begin_drain();
            conn.send(Json::obj()
                .push("type", "drain_ok")
                .push("draining", true));
        }
        Some("cancel") => {
            let id = req.get("id").and_then(|v| v.as_usize())
                .unwrap_or(0) as u64;
            let found = match lock_recover(&conn.active).get(&id) {
                Some(c) => {
                    c.cancel();
                    true
                }
                None => false,
            };
            conn.send(Json::obj()
                .push("type", "cancel_ok")
                .push("id", id as usize)
                .push("found", found));
        }
        Some(op) => {
            conn.send(error_frame(
                None, &ServeError::BadRequest(format!(
                    "unknown op {op:?} (valid: submit, cancel, \
                     metrics, health, drain)"))));
        }
        None => {
            conn.send(error_frame(
                None,
                &ServeError::BadRequest("request has no \"op\"".into())));
        }
    }
}

fn handle_submit(gw: &Arc<Gateway>, req: &Json, conn: &ConnTx) {
    let serve = gw.serve_config();
    let class = req.get("class").and_then(|v| v.as_i64()).unwrap_or(0)
        as i32;
    let seed = req.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0)
        as u64;
    let steps = req.get("steps").and_then(|v| v.as_usize())
        .unwrap_or(serve.sample_steps);
    let tier = req.get("tier").and_then(|v| v.as_str())
        .unwrap_or(&serve.tier).to_string();
    let streaming = req.get("stream").and_then(|v| v.as_bool())
        .unwrap_or(true);
    let opts = SubmitOpts {
        deadline_ms: req.get("deadline_ms").and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64,
        allow_degrade: req.get("allow_degrade").and_then(|v| v.as_bool())
            .unwrap_or(false),
        // absent = serve the server's configured default variant; an
        // unknown name comes back as a typed bad_request reject frame
        // (gateway admission validates against the backend's set)
        variant: req.get("variant").and_then(|v| v.as_str())
            .map(String::from),
    };
    if steps == 0 || steps > MAX_NET_STEPS {
        conn.send(rejected_frame(&ServeError::BadRequest(
            format!("steps {steps} out of range (1..={MAX_NET_STEPS})"))));
        return;
    }
    if streaming {
        match gw.submit_streaming_with(class, seed, steps, &tier, opts) {
            Ok(stream) => {
                let id = stream.id();
                lock_recover(&conn.active)
                    .insert(id, stream.cancel_handle());
                conn.send(Json::obj()
                    .push("type", "accepted")
                    .push("id", id as usize));
                let out = conn.clone();
                let _ = std::thread::Builder::new()
                    .name("sla2-net-pump".into())
                    .spawn(move || {
                        pump_stream(id, stream, &out);
                        lock_recover(&out.active).remove(&id);
                    });
            }
            Err(e) => {
                conn.send(rejected_frame(&e));
            }
        }
    } else {
        match gw.submit_tracked_with(class, seed, steps, &tier, opts) {
            Ok((id, rx)) => {
                // ack with the real gateway id: clip/error frames are
                // tagged with it, so pipelined one-shot submits on one
                // connection stay correlatable even though pump
                // threads race to the writer in completion order
                conn.send(Json::obj()
                    .push("type", "accepted")
                    .push("id", id as usize));
                let out = conn.clone();
                let _ = std::thread::Builder::new()
                    .name("sla2-net-pump".into())
                    .spawn(move || {
                        let frame = match rx.recv() {
                            Ok(Ok(resp)) => clip_frame(&resp),
                            Ok(Err(e)) => error_frame(Some(id), &e),
                            Err(_) => internal_error_frame(
                                id, "server dropped the request"),
                        };
                        out.send(frame);
                    });
            }
            Err(e) => {
                conn.send(rejected_frame(&e));
            }
        }
    }
}

fn clip_frame(resp: &GenResponse) -> Json {
    match tensor_to_json(&resp.clip) {
        Ok(t) => Json::obj()
            .push("type", "clip")
            .push("id", resp.id as usize)
            .push("clip", t)
            .push("metrics", metrics_to_json(&resp.metrics)),
        Err(e) => internal_error_frame(resp.id, &format!("{e:#}")),
    }
}

/// Move chunks from a [`ClipStream`] to the connection writer until
/// the stream ends, then emit the `done` terminal.  A send that fails
/// means the connection is gone or was just declared slow — either
/// way the pump stops and dropping the stream cancels the request.
fn pump_stream(id: u64, stream: stream::ClipStream, out: &ConnTx) {
    let mut complete = false;
    while let Some(item) = stream.recv() {
        match item {
            Ok(chunk) => {
                complete = chunk.last;
                let frame = match chunk_to_json(&chunk) {
                    Ok(f) => f,
                    Err(e) => internal_error_frame(id, &format!("{e:#}")),
                };
                if !out.send(frame) {
                    return; // connection gone; drop cancels the stream
                }
            }
            Err(e) => {
                // typed terminal failure (deadline, shard death, shed
                // on retry-requeue, ...) — forwarded verbatim
                out.send(error_frame(Some(id), &e));
                break;
            }
        }
    }
    out.send(Json::obj()
        .push("type", "done")
        .push("id", id as usize)
        .push("complete", complete));
}

/// Decode the typed failure carried by a `rejected` / `error` frame
/// back into a [`ServeError`] (frames from servers predating the
/// `code` field decode as non-retryable `shard_failed`).
pub fn error_from_frame(f: &Json) -> ServeError {
    ServeError::from_wire(
        f.get("code").and_then(|v| v.as_str()).unwrap_or(""),
        f.get("error").and_then(|v| v.as_str()).unwrap_or("unknown"),
        f.get("retryable").and_then(|v| v.as_bool()).unwrap_or(false),
        f.get("retry_after_ms").and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64)
}

// ---------------- client side -------------------------------------------

/// Minimal blocking client for the wire protocol, used by the
/// `sla2-stream-client` binary and the integration tests.  Designed
/// for sequential use: submit, then consume that request's frames;
/// frames for other requests encountered while scanning are buffered
/// and replayed in order.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    pending: VecDeque<Json>,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let sock = TcpStream::connect(addr)
            .with_context(|| format!("connect {addr}"))?;
        let _ = sock.set_nodelay(true);
        let writer = sock.try_clone()?;
        Ok(NetClient { reader: BufReader::new(sock), writer,
                       pending: VecDeque::new() })
    }

    pub fn send(&mut self, frame: &Json) -> Result<()> {
        write_frame(&mut self.writer, frame)
    }

    /// Next frame: replays buffered frames first, then reads the wire.
    pub fn next_frame(&mut self) -> Result<Json> {
        if let Some(f) = self.pending.pop_front() {
            return Ok(f);
        }
        read_frame(&mut self.reader, MAX_FRAME_LEN)?
            .context("connection closed")
    }

    /// Read until `pred` matches, buffering everything else in order.
    fn wait_for(&mut self, pred: impl Fn(&Json) -> bool) -> Result<Json> {
        for i in 0..self.pending.len() {
            if pred(&self.pending[i]) {
                if let Some(f) = self.pending.remove(i) {
                    return Ok(f);
                }
            }
        }
        loop {
            let f = read_frame(&mut self.reader, MAX_FRAME_LEN)?
                .context("connection closed")?;
            if pred(&f) {
                return Ok(f);
            }
            self.pending.push_back(f);
        }
    }

    /// Submit; `Ok(id)` on accept (streaming and one-shot submits both
    /// ack with the gateway-allocated request id).  On rejection the
    /// `Err` wraps the typed [`ServeError`] — downcast to inspect the
    /// code / `retry_after_ms`.
    pub fn submit(&mut self, class: i32, seed: u64, steps: usize,
                  tier: &str, streaming: bool) -> Result<u64> {
        self.submit_with(class, seed, steps, tier, streaming,
                         SubmitOpts::default())
    }

    /// [`NetClient::submit`] with per-request options (deadline,
    /// degradation opt-in) carried on the wire.
    pub fn submit_with(&mut self, class: i32, seed: u64, steps: usize,
                       tier: &str, streaming: bool, opts: SubmitOpts)
                       -> Result<u64> {
        self.send(&Json::obj()
            .push("op", "submit")
            .push("class", class as i64)
            .push("seed", seed as f64)
            .push("steps", steps)
            .push("tier", tier)
            .push("stream", streaming)
            .push("deadline_ms", opts.deadline_ms as usize)
            .push("allow_degrade", opts.allow_degrade)
            .push_opt("variant", opts.variant))?;
        let ack = self.wait_for(|f| {
            matches!(f.get("type").and_then(|v| v.as_str()),
                     Some("accepted") | Some("rejected"))
        })?;
        match ack.get("type").and_then(|v| v.as_str()) {
            Some("accepted") => Ok(ack.get("id")
                .and_then(|v| v.as_usize()).unwrap_or(0) as u64),
            _ => {
                let e = error_from_frame(&ack);
                Err(anyhow::Error::new(e.clone())
                    .context(format!("submit rejected: {e}")))
            }
        }
    }

    /// Consume one stream to completion, invoking `on_chunk` per
    /// chunk, and reassemble the clip (validating order and
    /// completeness).
    pub fn collect_stream_with(
        &mut self, id: u64, mut on_chunk: impl FnMut(&ClipChunk))
        -> Result<GenResponse> {
        let of_id = move |f: &Json| {
            f.get("id").and_then(|v| v.as_usize()).map(|v| v as u64)
                == Some(id)
        };
        let mut chunks: Vec<ClipChunk> = Vec::new();
        loop {
            let f = self.wait_for(|f| {
                of_id(f)
                    && matches!(f.get("type").and_then(|v| v.as_str()),
                                Some("chunk") | Some("done")
                                | Some("error"))
            })?;
            match f.get("type").and_then(|v| v.as_str()) {
                Some("chunk") => {
                    let c = chunk_from_json(&f)?;
                    on_chunk(&c);
                    chunks.push(c);
                }
                Some("done") => {
                    return stream::assemble_response(id, chunks);
                }
                _ => {
                    let e = error_from_frame(&f);
                    return Err(anyhow::Error::new(e.clone())
                        .context(format!("stream {id} failed: {e}")));
                }
            }
        }
    }

    pub fn collect_stream(&mut self, id: u64) -> Result<GenResponse> {
        self.collect_stream_with(id, |_| {})
    }

    /// Wait for one non-streaming submit's clip frame, matched by the
    /// id its ack returned (pump threads answer in completion order,
    /// not submit order).
    pub fn collect_clip(&mut self, id: u64) -> Result<GenResponse> {
        let f = self.wait_for(|f| {
            f.get("id").and_then(|v| v.as_usize()).map(|v| v as u64)
                == Some(id)
                && matches!(f.get("type").and_then(|v| v.as_str()),
                            Some("clip") | Some("error"))
        })?;
        match f.get("type").and_then(|v| v.as_str()) {
            Some("clip") => Ok(GenResponse {
                id,
                clip: tensor_from_json(f.req("clip")?)?,
                metrics: f.get("metrics").map(metrics_from_json)
                    .unwrap_or_default(),
            }),
            _ => {
                let e = error_from_frame(&f);
                Err(anyhow::Error::new(e.clone())
                    .context(format!("request {id} failed: {e}")))
            }
        }
    }

    /// Request and await a server metrics snapshot.
    pub fn metrics_snapshot(&mut self) -> Result<Json> {
        self.send(&Json::obj().push("op", "metrics"))?;
        let f = self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("metrics")
        })?;
        Ok(f.req("snapshot")?.clone())
    }

    /// Probe liveness/readiness; returns the server's health object
    /// (`{live, ready, draining}`).
    pub fn health(&mut self) -> Result<Json> {
        self.send(&Json::obj().push("op", "health"))?;
        let f = self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("health")
        })?;
        Ok(f.req("health")?.clone())
    }

    /// Ask the server to begin a graceful drain (admission flips to
    /// typed `shutting_down`; in-flight work completes).
    pub fn drain(&mut self) -> Result<()> {
        self.send(&Json::obj().push("op", "drain"))?;
        self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("drain_ok")
        })?;
        Ok(())
    }

    /// Cancel an in-flight streaming request; `Ok(found)`.
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        self.send(&Json::obj()
            .push("op", "cancel")
            .push("id", id as usize))?;
        let f = self.wait_for(|f| {
            f.get("type").and_then(|v| v.as_str()) == Some("cancel_ok")
                && f.get("id").and_then(|v| v.as_usize())
                    .map(|v| v as u64) == Some(id)
        })?;
        Ok(f.get("found").and_then(|v| v.as_bool()).unwrap_or(false))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let j = Json::obj().push("op", "metrics").push("x", 1.5);
        let mut buf = Vec::new();
        write_frame(&mut buf, &j).unwrap();
        let mut r = Cursor::new(buf);
        let back = read_frame(&mut r, MAX_FRAME_LEN).unwrap().unwrap();
        assert_eq!(back, j);
        // clean EOF after the frame
        assert!(read_frame(&mut r, MAX_FRAME_LEN).unwrap().is_none());
    }

    #[test]
    fn read_frame_rejects_oversized_and_malformed() {
        // oversized: length prefix beyond the cap
        let mut buf = Vec::new();
        buf.extend_from_slice(&(64u32).to_be_bytes());
        buf.extend_from_slice(&[b'{'; 64]);
        let err = read_frame(&mut Cursor::new(&buf), 16).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
        // malformed JSON body
        let mut buf = Vec::new();
        buf.extend_from_slice(&(3u32).to_be_bytes());
        buf.extend_from_slice(b"{x}");
        let err = read_frame(&mut Cursor::new(&buf), MAX_FRAME_LEN)
            .unwrap_err();
        assert!(err.to_string().contains("malformed"), "{err}");
        // truncated body
        let mut buf = Vec::new();
        buf.extend_from_slice(&(10u32).to_be_bytes());
        buf.extend_from_slice(b"{}");
        assert!(read_frame(&mut Cursor::new(&buf), MAX_FRAME_LEN)
                    .is_err());
    }

    #[test]
    fn typed_error_frames_roundtrip_through_the_wire() {
        let err = ServeError::Overloaded { retry_after_ms: 75 };
        let text = rejected_frame(&err).to_string();
        let f = Json::parse(&text).unwrap();
        assert_eq!(f.get("code").and_then(|v| v.as_str()),
                   Some("overloaded"));
        assert_eq!(f.get("retryable").and_then(|v| v.as_bool()),
                   Some(true));
        assert_eq!(error_from_frame(&f), err);

        let err = ServeError::BadRequest("no \"op\"".into());
        let f = Json::parse(&error_frame(None, &err).to_string()).unwrap();
        assert_eq!(f.get("code").and_then(|v| v.as_str()),
                   Some("bad_request"));
        assert_eq!(error_from_frame(&f), err);

        // legacy frame without a code decodes as terminal shard_failed
        let legacy = Json::obj().push("type", "error")
            .push("error", "boom");
        let back = error_from_frame(&legacy);
        assert_eq!(back.code(), "shard_failed");
        assert!(!back.retryable());
    }

    #[test]
    fn tensor_json_roundtrip_is_bit_exact() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(3);
        let t = Tensor::randn(&[2, 3, 4], &mut rng);
        // through the actual WIRE TEXT, not just the Json tree: the
        // f32 -> double -> shortest-decimal -> double -> f32 path
        // must be lossless
        let text = tensor_to_json(&t).unwrap().to_string();
        let back = tensor_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn chunk_json_roundtrip() {
        let c = ClipChunk {
            id: 7, seq: 2, frame_start: 2, frame_end: 3, total_frames: 4,
            last: false,
            frames: Tensor::from_f32(&[1, 2], vec![0.25, -1.5]).unwrap(),
            metrics: RequestMetrics { queue_ms: 1.0, compute_ms: 2.0,
                                      steps: 4, batch_size: 2 },
        };
        let text = chunk_to_json(&c).unwrap().to_string();
        let back = chunk_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.seq, 2);
        assert_eq!(back.frames, c.frames);
        assert_eq!(back.metrics.batch_size, 2);
        assert!(!back.last);
    }
}
