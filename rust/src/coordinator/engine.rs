//! The engine: owns the PJRT runtime and turns request batches into
//! clips by driving the diffusion sampling loop over denoise HLOs.
//!
//! Runs on ONE thread (PjRtClient is `Rc`-based); the sharded pool
//! (`coordinator::pool`) runs one engine per shard thread.  Model
//! parameters are converted to XLA literals once at startup and reused
//! across every step of every request; inside the sampling loop the
//! stacked-latent buffer, the per-step `ts` tensor and the label
//! literal are all allocated once per batch and reused across steps —
//! the per-step cost is only the literal conversion of the data that
//! actually changed.

use std::time::Instant;

use anyhow::{Context, Result};
use xla::Literal;

use super::batcher::{denoise_artifact_name, plan_batches,
                     supported_batch_sizes};
use super::pool::BatchProcessor;
use super::request::{GenRequest, RequestMetrics};
use crate::config::{ModelConfig, ServeConfig};
use crate::diffusion;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

pub struct Engine {
    runtime: Runtime,
    pub model: ModelConfig,
    pub serve: ServeConfig,
    /// model parameters, pre-converted to literals (hot-loop reuse)
    params: Vec<Literal>,
}

impl Engine {
    pub fn new(artifacts_dir: &str, serve: ServeConfig) -> Result<Engine> {
        let runtime = Runtime::load(artifacts_dir)?;
        let model = runtime.manifest().config(&serve.model)?.clone();
        // host-side parameter tensors are process-shared: the file
        // read + f32 decode happens once, not once per shard; only
        // the (Rc-based, thread-confined) literal conversion is ours
        let params = crate::runtime::shared()
            .params(runtime.manifest(), &serve.model)?;
        let params = params.iter()
            .map(crate::runtime::tensor_to_literal)
            .collect::<Result<Vec<_>>>()
            .context("params -> literals")?;
        Ok(Engine { runtime, model, serve, params })
    }

    /// Replace the parameter set (e.g. after training).
    pub fn set_params(&mut self, params: &[Tensor]) -> Result<()> {
        self.params = params.iter()
            .map(crate::runtime::tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn variant_for_tier<'a>(&'a self, tier: &str) -> &'a str {
        if tier == "dense" { "full" } else { &self.serve.variant }
    }

    /// Serve a set of COMPATIBLE requests (same tier + steps).
    /// Returns `(clip, metrics)` per request, input order preserved.
    pub fn generate(&self, reqs: &[GenRequest])
                    -> Result<Vec<(Tensor, RequestMetrics)>> {
        let mut out = Vec::with_capacity(reqs.len());
        self.generate_streaming(reqs, &mut |_, clip, rm| {
            out.push((clip, rm));
        })?;
        Ok(out)
    }

    /// Streaming core of [`Engine::generate`]: run the batch plan and
    /// hand each request's `(index, clip, metrics)` to `emit` the
    /// moment its sub-batch finishes sampling — requests in the first
    /// sub-batch are delivered while later sub-batches are still
    /// denoising.  Emission is in input order; an error aborts the
    /// remaining sub-batches but everything already emitted stands.
    pub fn generate_streaming(
        &self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Tensor, RequestMetrics))
        -> Result<()> {
        let first = reqs.first().context("empty batch")?;
        let tier = &first.tier;
        let variant = self.variant_for_tier(tier);
        let sizes = supported_batch_sizes(self.runtime.manifest(),
                                          &self.model.name, variant, tier);
        anyhow::ensure!(!sizes.is_empty(),
                        "no denoise artifacts for {}/{}/{} — re-run `make \
                         artifacts`", self.model.name, variant, tier);
        let plan = plan_batches(reqs.len(),
                                if sizes.contains(&1) { &sizes }
                                else { &[1] });
        let mut cursor = 0;
        let dispatch_start = Instant::now();
        for batch_size in plan {
            let chunk = &reqs[cursor..cursor + batch_size];
            let artifact = denoise_artifact_name(
                &self.model.name, variant, tier, batch_size);
            let t0 = Instant::now();
            // requests in later sub-batches waited in the engine for
            // the earlier ones: count that toward queue wait so no
            // latency goes unreported
            let chunk_wait_ms =
                t0.duration_since(dispatch_start).as_secs_f64() * 1e3;
            let clips = self.sample_batch(&artifact, chunk)?;
            let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
            for (j, (req, clip)) in chunk.iter().zip(clips).enumerate() {
                emit(cursor + j, clip, RequestMetrics {
                    // queue wait measured directly at dequeue (stamped
                    // by the queue) — never negative, never
                    // reconstructed from wall-clock arithmetic
                    queue_ms: req.queue_wait_ms() + chunk_wait_ms,
                    compute_ms,
                    steps: req.steps,
                    batch_size,
                });
            }
            cursor += batch_size;
        }
        Ok(())
    }

    /// The diffusion sampling loop for one fixed-size sub-batch.
    ///
    /// Allocation discipline: the stacked latent `x`, the per-step
    /// `ts` tensor and the label literal are each allocated ONCE and
    /// mutated/reused across all steps; the loop only converts the two
    /// tensors whose data changed into fresh literals.
    fn sample_batch(&self, artifact: &str, reqs: &[GenRequest])
                    -> Result<Vec<Tensor>> {
        let b = reqs.len();
        let [t, h, w, c] = self.model.video;
        let clip_len = t * h * w * c;
        // initial noise latents from per-request seeds, written
        // straight into the stacked buffer (deterministic: the value
        // stream per request is identical to stacking per-request
        // `Tensor::randn` results)
        let mut x = Tensor::zeros(&[b, t, h, w, c]);
        {
            let xs = x.f32s_mut()?;
            for (i, r) in reqs.iter().enumerate() {
                let mut rng = Pcg32::seeded(r.seed);
                for v in &mut xs[i * clip_len..(i + 1) * clip_len] {
                    *v = rng.normal();
                }
            }
        }
        let labels: Vec<i32> = reqs.iter().map(|r| r.class_label).collect();
        let ys_lit = crate::runtime::tensor_to_literal(
            &Tensor::from_i32(&[b], labels)?)?;
        let mut ts = Tensor::from_f32(&[b], vec![0.0; b])?;

        let grid = diffusion::timestep_grid(reqs[0].steps);
        for step in grid.windows(2) {
            let (t_cur, t_next) = (step[0], step[1]);
            for v in ts.f32s_mut()? {
                *v = t_cur;
            }
            let x_lit = crate::runtime::tensor_to_literal(&x)?;
            let ts_lit = crate::runtime::tensor_to_literal(&ts)?;
            let vel = self.runtime.execute_literal_refs_with_prefix(
                artifact, &self.params, &[&x_lit, &ts_lit, &ys_lit])?
                .into_iter().next()
                .context("denoise returned nothing")?;
            diffusion::euler_step(&mut x, &vel, t_cur, t_next);
        }
        x.unstack()
    }
}

impl BatchProcessor for Engine {
    fn process(&mut self, reqs: &[GenRequest])
               -> Result<Vec<(Tensor, RequestMetrics)>> {
        self.generate(reqs)
    }

    fn process_streaming(
        &mut self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Tensor, RequestMetrics))
        -> Result<()> {
        self.generate_streaming(reqs, emit)
    }

    fn counters(&self) -> (u64, u64) {
        let (compiles, executions) = self.runtime.counters();
        (compiles as u64, executions as u64)
    }
}
