//! The engine: owns a [`ComputeBackend`] and turns request batches
//! into clips by driving the diffusion sampling loop over denoise
//! forwards.
//!
//! Runs on ONE thread (the XLA backend's PjRtClient is `Rc`-based);
//! the sharded pool (`coordinator::pool`) runs one engine per shard
//! thread.  The engine is backend-agnostic: it owns noise init, the
//! batch-size plan, the Euler loop and the emission order, and asks
//! the backend for (a) its batch-size capability and (b) one velocity
//! evaluation per step.  Inside the sampling loop the stacked-latent
//! buffer and the per-step `ts` tensor are allocated once per batch
//! and mutated across steps — the per-step cost is the backend's
//! conversion/evaluation of the tensors that actually changed.

use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::plan_support;
use super::pool::BatchProcessor;
use super::request::{GenRequest, RequestMetrics};
use crate::config::{ModelConfig, ServeConfig};
use crate::diffusion;
use crate::runtime::ComputeBackend;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

pub struct Engine {
    backend: Box<dyn ComputeBackend>,
    pub model: ModelConfig,
    pub serve: ServeConfig,
}

impl Engine {
    /// Build the backend `serve.backend` names ("xla" | "native") and
    /// wrap it.  For "xla", `artifacts_dir` must hold a manifest; for
    /// "native" a manifest is used when present (shared weights with
    /// XLA) and a built-in config + seeded init otherwise.
    pub fn new(artifacts_dir: &str, serve: ServeConfig) -> Result<Engine> {
        let backend = crate::runtime::make_backend(artifacts_dir, &serve)?;
        let model = backend.model().clone();
        Ok(Engine { backend, model, serve })
    }

    /// Replace the parameter set (e.g. after training).  Tensors are
    /// in canonical flatten order.
    pub fn set_params(&mut self, params: &[Tensor]) -> Result<()> {
        self.backend.set_params(params)
    }

    /// The compute backend (platform, counters, capability queries).
    pub fn backend(&self) -> &dyn ComputeBackend {
        &*self.backend
    }

    fn variant_for_tier<'a>(&'a self, tier: &str) -> &'a str {
        if tier == "dense" { "full" } else { &self.serve.variant }
    }

    /// Serve a set of COMPATIBLE requests (same tier + steps).
    /// Returns `(clip, metrics)` per request, input order preserved.
    pub fn generate(&self, reqs: &[GenRequest])
                    -> Result<Vec<(Tensor, RequestMetrics)>> {
        let mut out = Vec::with_capacity(reqs.len());
        self.generate_streaming(reqs, &mut |_, clip, rm| {
            out.push((clip, rm));
        })?;
        Ok(out)
    }

    /// Streaming core of [`Engine::generate`]: run the batch plan and
    /// hand each request's `(index, clip, metrics)` to `emit` the
    /// moment its sub-batch finishes sampling — requests in the first
    /// sub-batch are delivered while later sub-batches are still
    /// denoising.  Emission is in input order; an error aborts the
    /// remaining sub-batches but everything already emitted stands.
    ///
    /// The sub-batch plan is a backend capability query: exact
    /// manifest sizes for XLA (min-launch cover), one single launch
    /// for the native backend ([`crate::runtime::BatchSupport::Any`]).
    pub fn generate_streaming(
        &self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Tensor, RequestMetrics))
        -> Result<()> {
        let first = reqs.first().context("empty batch")?;
        let tier = &first.tier;
        let variant = self.variant_for_tier(tier);
        let support = self.backend.supported_batch_sizes(variant, tier);
        let plan = plan_support(reqs.len(), &support)
            .with_context(|| format!("planning {}/{}/{}",
                                     self.model.name, variant, tier))?;
        let mut cursor = 0;
        let dispatch_start = Instant::now();
        for batch_size in plan {
            let chunk = &reqs[cursor..cursor + batch_size];
            let t0 = Instant::now();
            // requests in later sub-batches waited in the engine for
            // the earlier ones: count that toward queue wait so no
            // latency goes unreported
            let chunk_wait_ms =
                t0.duration_since(dispatch_start).as_secs_f64() * 1e3;
            let clips = self.sample_batch(variant, tier, chunk)?;
            let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
            for (j, (req, clip)) in chunk.iter().zip(clips).enumerate() {
                emit(cursor + j, clip, RequestMetrics {
                    // queue wait measured directly at dequeue (stamped
                    // by the queue) — never negative, never
                    // reconstructed from wall-clock arithmetic
                    queue_ms: req.queue_wait_ms() + chunk_wait_ms,
                    compute_ms,
                    steps: req.steps,
                    batch_size,
                });
            }
            cursor += batch_size;
        }
        Ok(())
    }

    /// The diffusion sampling loop for one fixed-size sub-batch.
    ///
    /// Allocation discipline: the stacked latent `x` and the per-step
    /// `ts` tensor are each allocated ONCE and mutated/reused across
    /// all steps; per-step conversion of the changed tensors is the
    /// backend's concern.
    fn sample_batch(&self, variant: &str, tier: &str, reqs: &[GenRequest])
                    -> Result<Vec<Tensor>> {
        let b = reqs.len();
        // warm the backend BEFORE building noise: XLA compiles the
        // executable here (instead of inside step 1), and the native
        // backend rejects an unimplemented variant/tier before any
        // per-request work happens
        self.backend.compile(variant, tier, b)?;
        let [t, h, w, c] = self.model.video;
        let clip_len = t * h * w * c;
        // initial noise latents from per-request seeds, written
        // straight into the stacked buffer (deterministic: the value
        // stream per request is identical to stacking per-request
        // `Tensor::randn` results)
        let mut x = Tensor::zeros(&[b, t, h, w, c]);
        {
            let xs = x.f32s_mut()?;
            for (i, r) in reqs.iter().enumerate() {
                let mut rng = Pcg32::seeded(r.seed);
                for v in &mut xs[i * clip_len..(i + 1) * clip_len] {
                    *v = rng.normal();
                }
            }
        }
        let labels: Vec<i32> = reqs.iter().map(|r| r.class_label).collect();
        let ys = Tensor::from_i32(&[b], labels)?;
        let mut ts = Tensor::from_f32(&[b], vec![0.0; b])?;

        let grid = diffusion::timestep_grid(reqs[0].steps);
        for step in grid.windows(2) {
            let (t_cur, t_next) = (step[0], step[1]);
            for v in ts.f32s_mut()? {
                *v = t_cur;
            }
            let vel = self.backend.execute(variant, tier, &x, &ts, &ys)?;
            diffusion::euler_step(&mut x, &vel, t_cur, t_next);
        }
        x.unstack()
    }
}

impl BatchProcessor for Engine {
    fn process(&mut self, reqs: &[GenRequest])
               -> Result<Vec<(Tensor, RequestMetrics)>> {
        self.generate(reqs)
    }

    fn process_streaming(
        &mut self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Tensor, RequestMetrics))
        -> Result<()> {
        self.generate_streaming(reqs, emit)
    }

    fn counters(&self) -> (u64, u64) {
        self.backend.counters()
    }
}
