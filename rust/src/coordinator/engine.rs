//! The engine: owns the PJRT runtime and turns request batches into
//! clips by driving the diffusion sampling loop over denoise HLOs.
//!
//! Runs on ONE thread (PjRtClient is `Rc`-based).  Model parameters
//! are converted to XLA literals once at startup and reused across
//! every step of every request — the hot loop only materializes the
//! small per-batch tensors (latents, t, labels).

use std::time::Instant;

use anyhow::{Context, Result};
use xla::Literal;

use super::batcher::{denoise_artifact_name, plan_batches,
                     supported_batch_sizes};
use super::request::{GenRequest, RequestMetrics};
use crate::config::{ModelConfig, ServeConfig};
use crate::diffusion;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

pub struct Engine {
    runtime: Runtime,
    pub model: ModelConfig,
    pub serve: ServeConfig,
    /// model parameters, pre-converted to literals (hot-loop reuse)
    params: Vec<Literal>,
}

impl Engine {
    pub fn new(artifacts_dir: &str, serve: ServeConfig) -> Result<Engine> {
        let runtime = Runtime::load(artifacts_dir)?;
        let model = runtime.manifest().config(&serve.model)?.clone();
        let params = runtime.manifest().load_params(&serve.model)?;
        let params = params.iter()
            .map(crate::runtime::tensor_to_literal)
            .collect::<Result<Vec<_>>>()
            .context("params -> literals")?;
        Ok(Engine { runtime, model, serve, params })
    }

    /// Replace the parameter set (e.g. after training).
    pub fn set_params(&mut self, params: &[Tensor]) -> Result<()> {
        self.params = params.iter()
            .map(crate::runtime::tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn variant_for_tier<'a>(&'a self, tier: &str) -> &'a str {
        if tier == "dense" { "full" } else { &self.serve.variant }
    }

    /// Serve a set of COMPATIBLE requests (same tier + steps).
    /// Returns `(clip, metrics)` per request, input order preserved.
    pub fn generate(&self, reqs: &[GenRequest])
                    -> Result<Vec<(Tensor, RequestMetrics)>> {
        let first = reqs.first().context("empty batch")?;
        let tier = &first.tier;
        let variant = self.variant_for_tier(tier);
        let sizes = supported_batch_sizes(self.runtime.manifest(),
                                          &self.model.name, variant, tier);
        anyhow::ensure!(!sizes.is_empty(),
                        "no denoise artifacts for {}/{}/{} — re-run `make \
                         artifacts`", self.model.name, variant, tier);
        let plan = plan_batches(reqs.len(),
                                if sizes.contains(&1) { &sizes }
                                else { &[1] });
        let mut out = Vec::with_capacity(reqs.len());
        let mut cursor = 0;
        for batch_size in plan {
            let chunk = &reqs[cursor..cursor + batch_size];
            cursor += batch_size;
            let artifact = denoise_artifact_name(
                &self.model.name, variant, tier, batch_size);
            let t0 = Instant::now();
            let clips = self.sample_batch(&artifact, chunk)?;
            let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
            for (req, clip) in chunk.iter().zip(clips) {
                out.push((clip, RequestMetrics {
                    queue_ms: req.submitted_at.elapsed().as_secs_f64()
                        * 1e3 - compute_ms,
                    compute_ms,
                    steps: req.steps,
                    batch_size,
                }));
            }
        }
        Ok(out)
    }

    /// The diffusion sampling loop for one fixed-size sub-batch.
    fn sample_batch(&self, artifact: &str, reqs: &[GenRequest])
                    -> Result<Vec<Tensor>> {
        let b = reqs.len();
        let [t, h, w, c] = self.model.video;
        // initial noise latents from per-request seeds (deterministic)
        let latents: Vec<Tensor> = reqs.iter()
            .map(|r| Tensor::randn(&[t, h, w, c],
                                   &mut Pcg32::seeded(r.seed)))
            .collect();
        let mut x = Tensor::stack(&latents.iter().collect::<Vec<_>>())?;
        let labels: Vec<i32> = reqs.iter().map(|r| r.class_label).collect();
        let ys = Tensor::from_i32(&[b], labels)?;
        let ys_lit = crate::runtime::tensor_to_literal(&ys)?;

        let grid = diffusion::timestep_grid(reqs[0].steps);
        for step in grid.windows(2) {
            let (t_cur, t_next) = (step[0], step[1]);
            let ts = Tensor::from_f32(&[b], vec![t_cur; b])?;
            let inputs = [crate::runtime::tensor_to_literal(&x)?,
                          crate::runtime::tensor_to_literal(&ts)?,
                          ys_lit.clone()];
            let vel = self.runtime.execute_literals_with_prefix(
                artifact, &self.params, &inputs)?
                .into_iter().next()
                .context("denoise returned nothing")?;
            diffusion::euler_step(&mut x, &vel, t_cur, t_next);
        }
        x.unstack()
    }
}
