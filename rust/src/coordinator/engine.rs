//! The engine: owns a [`ComputeBackend`] and turns request batches
//! into clips by driving the diffusion sampling loop over denoise
//! forwards.
//!
//! Runs on ONE thread (the XLA backend's PjRtClient is `Rc`-based);
//! the sharded pool (`coordinator::pool`) runs one engine per shard
//! thread.  The engine is backend-agnostic: it owns noise init, the
//! batch-size plan, the Euler loop and the emission order, and asks
//! the backend for (a) its batch-size capability and (b) one velocity
//! evaluation per step.  Inside the sampling loop the stacked-latent
//! buffer and the per-step `ts` tensor are allocated once per batch
//! and mutated across steps — the per-step cost is the backend's
//! conversion/evaluation of the tensors that actually changed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::plan_support;
use super::error::ServeError;
use super::pool::BatchProcessor;
use super::request::{GenRequest, RequestMetrics};
use crate::config::{ModelConfig, ServeConfig};
use crate::diffusion;
use crate::runtime::{ComputeBackend, FaultyBackend};
use crate::tensor::Tensor;
use crate::util::faults::FaultInjector;
use crate::util::rng::Pcg32;

pub struct Engine {
    backend: Box<dyn ComputeBackend>,
    pub model: ModelConfig,
    pub serve: ServeConfig,
    /// pool-watchdog heartbeat, installed via
    /// [`BatchProcessor::set_beat`]; stamped after every compile and
    /// denoise-step execute so a long batch reads as alive while a
    /// wedged backend call goes silent
    beat: Option<Arc<AtomicU64>>,
}

impl Engine {
    /// Build the backend `serve.backend` names ("xla" | "native") and
    /// wrap it.  For "xla", `artifacts_dir` must hold a manifest; for
    /// "native" a manifest is used when present (shared weights with
    /// XLA) and a built-in config + seeded init otherwise.
    pub fn new(artifacts_dir: &str, serve: ServeConfig) -> Result<Engine> {
        Engine::new_with_injector(artifacts_dir, serve,
                                  FaultInjector::inert())
    }

    /// [`Engine::new`] with a deterministic fault injector wrapped
    /// around the backend (chaos testing; see [`crate::util::faults`]).
    /// An inert injector adds no wrapper and no per-call overhead.
    pub fn new_with_injector(artifacts_dir: &str, serve: ServeConfig,
                             injector: FaultInjector) -> Result<Engine> {
        let mut backend =
            crate::runtime::make_backend(artifacts_dir, &serve)?;
        if !injector.is_inert() {
            backend = Box::new(FaultyBackend::new(backend, injector));
        }
        let model = backend.model().clone();
        Ok(Engine { backend, model, serve, beat: None })
    }

    /// Stamp the shard's progress heartbeat, when serving under a
    /// pool watchdog (no-op otherwise).
    fn stamp_beat(&self) {
        if let Some(b) = &self.beat {
            b.store(super::pool::now_ms(), Ordering::Relaxed);
        }
    }

    /// Replace the parameter set (e.g. after training).  Tensors are
    /// in canonical flatten order.
    pub fn set_params(&mut self, params: &[Tensor]) -> Result<()> {
        self.backend.set_params(params)
    }

    /// The compute backend (platform, counters, capability queries).
    pub fn backend(&self) -> &dyn ComputeBackend {
        &*self.backend
    }

    /// The attention variant a request actually runs: the dense tier
    /// always serves full softmax (a sparse variant at keep-everything
    /// would waste the routing work), otherwise the request's own
    /// override wins and the server-wide `--variant` knob is the
    /// fallback.  Batches are class-homogeneous (variant is part of
    /// [`GenRequest::compatible`] and the scheduler's `ClassKey`), so
    /// resolving from any one request of a batch is resolving for all.
    fn effective_variant<'a>(&'a self, req: &'a GenRequest) -> &'a str {
        if req.tier == "dense" {
            "full"
        } else {
            req.variant.as_deref().unwrap_or(&self.serve.variant)
        }
    }

    /// Serve a set of COMPATIBLE requests (same tier, steps and
    /// variant).
    /// Returns `(clip, metrics)` per request, input order preserved.
    /// A typed per-request failure (a mid-flight deadline expiry)
    /// fails the whole call — direct callers (benches, tests) do not
    /// set deadlines, so this path never sees one in practice.
    pub fn generate(&self, reqs: &[GenRequest])
                    -> Result<Vec<(Tensor, RequestMetrics)>> {
        let mut out = Vec::with_capacity(reqs.len());
        let mut failed: Option<ServeError> = None;
        self.generate_streaming(reqs, &mut |_, result, rm| {
            match result {
                Ok(clip) => out.push((clip, rm)),
                Err(e) => failed = failed.take().or(Some(e)),
            }
        })?;
        match failed {
            Some(e) => Err(e.into()),
            None => Ok(out),
        }
    }

    /// Streaming core of [`Engine::generate`]: run the batch plan and
    /// hand each request's `(index, Ok(clip) | Err(failure), metrics)`
    /// to `emit` the moment its sub-batch finishes sampling — requests
    /// in the first sub-batch are delivered while later sub-batches
    /// are still denoising.  Emission is in input order; an error
    /// aborts the remaining sub-batches but everything already emitted
    /// stands.
    ///
    /// Deadline semantics: requests already expired when their
    /// sub-batch starts resolve to `Err(DeadlineExceeded)`.  A
    /// sub-batch whose EVERY request has expired skips its denoise
    /// launches entirely, and [`Engine::sample_batch`] re-checks
    /// between denoise steps so a deadline passing mid-loop aborts the
    /// remaining steps — both paths hand the shard slot back early
    /// instead of finishing work nobody can use.  (When only some of
    /// a sub-batch's requests expire, the launch still runs — the
    /// batch shares one tensor layout — and only the live requests
    /// get clips.)
    ///
    /// The sub-batch plan is a backend capability query: exact
    /// manifest sizes for XLA (min-launch cover), one single launch
    /// for the native backend ([`crate::runtime::BatchSupport::Any`]).
    pub fn generate_streaming(
        &self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Result<Tensor, ServeError>,
                             RequestMetrics))
        -> Result<()> {
        let first = reqs.first().context("empty batch")?;
        let tier = &first.tier;
        let variant = self.effective_variant(first);
        let support = self.backend.supported_batch_sizes(variant, tier);
        let plan = plan_support(reqs.len(), &support)
            .with_context(|| format!("planning {}/{}/{}",
                                     self.model.name, variant, tier))?;
        let mut cursor = 0;
        let dispatch_start = Instant::now();
        for batch_size in plan {
            let chunk = &reqs[cursor..cursor + batch_size];
            let t0 = Instant::now();
            // requests in later sub-batches waited in the engine for
            // the earlier ones: count that toward queue wait so no
            // latency goes unreported
            let chunk_wait_ms =
                t0.duration_since(dispatch_start).as_secs_f64() * 1e3;
            let rm_for = |req: &GenRequest, compute_ms: f64| {
                RequestMetrics {
                    // queue wait measured directly at dequeue (stamped
                    // by the queue) — never negative, never
                    // reconstructed from wall-clock arithmetic
                    queue_ms: req.queue_wait_ms() + chunk_wait_ms,
                    compute_ms,
                    steps: req.steps,
                    batch_size,
                }
            };
            let expired_now: Vec<bool> =
                chunk.iter().map(|r| r.expired(t0)).collect();
            if expired_now.iter().all(|&e| e) {
                // the whole sub-batch is dead on arrival: resolve it
                // without a single denoise launch
                for (j, req) in chunk.iter().enumerate() {
                    emit(cursor + j, Err(ServeError::DeadlineExceeded),
                         rm_for(req, 0.0));
                }
                cursor += batch_size;
                continue;
            }
            match self.sample_batch(variant, tier, chunk)? {
                Some(clips) => {
                    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
                    for (j, (req, clip)) in
                        chunk.iter().zip(clips).enumerate() {
                        let result = if expired_now[j] {
                            Err(ServeError::DeadlineExceeded)
                        } else {
                            Ok(clip)
                        };
                        emit(cursor + j, result, rm_for(req, compute_ms));
                    }
                }
                None => {
                    // every deadline in the sub-batch passed mid-loop;
                    // the remaining steps were abandoned
                    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
                    for (j, req) in chunk.iter().enumerate() {
                        emit(cursor + j, Err(ServeError::DeadlineExceeded),
                             rm_for(req, compute_ms));
                    }
                }
            }
            cursor += batch_size;
        }
        Ok(())
    }

    /// The diffusion sampling loop for one fixed-size sub-batch.
    /// `Ok(None)` means every request's deadline passed mid-loop and
    /// the remaining steps were abandoned (the early-slot-release
    /// path); `Ok(Some(clips))` is the normal result.
    ///
    /// Allocation discipline: the stacked latent `x` and the per-step
    /// `ts` tensor are each allocated ONCE and mutated/reused across
    /// all steps; per-step conversion of the changed tensors is the
    /// backend's concern.
    fn sample_batch(&self, variant: &str, tier: &str, reqs: &[GenRequest])
                    -> Result<Option<Vec<Tensor>>> {
        let b = reqs.len();
        // warm the backend BEFORE building noise: XLA compiles the
        // executable here (instead of inside step 1), and the native
        // backend rejects an unimplemented variant/tier before any
        // per-request work happens
        self.backend.compile(variant, tier, b)?;
        // a first-time compile can dwarf a denoise step; it finishing
        // is progress the watchdog should see
        self.stamp_beat();
        let [t, h, w, c] = self.model.video;
        let clip_len = t * h * w * c;
        // initial noise latents from per-request seeds, written
        // straight into the stacked buffer (deterministic: the value
        // stream per request is identical to stacking per-request
        // `Tensor::randn` results)
        let mut x = Tensor::zeros(&[b, t, h, w, c]);
        {
            let xs = x.f32s_mut()?;
            for (i, r) in reqs.iter().enumerate() {
                let mut rng = Pcg32::seeded(r.seed);
                for v in &mut xs[i * clip_len..(i + 1) * clip_len] {
                    *v = rng.normal();
                }
            }
        }
        let labels: Vec<i32> = reqs.iter().map(|r| r.class_label).collect();
        let ys = Tensor::from_i32(&[b], labels)?;
        let mut ts = Tensor::from_f32(&[b], vec![0.0; b])?;

        // deadline re-check between steps only matters if any request
        // actually carries one — the common no-deadline batch pays a
        // single bool check per step
        let any_deadline = reqs.iter().any(|r| r.deadline.is_some());
        let grid = diffusion::timestep_grid(reqs[0].steps);
        for step in grid.windows(2) {
            if any_deadline
                && reqs.iter().all(|r| r.expired(Instant::now())) {
                return Ok(None);
            }
            let (t_cur, t_next) = (step[0], step[1]);
            for v in ts.f32s_mut()? {
                *v = t_cur;
            }
            let vel = self.backend.execute(variant, tier, &x, &ts, &ys)?;
            self.stamp_beat();
            diffusion::euler_step(&mut x, &vel, t_cur, t_next);
        }
        x.unstack().map(Some)
    }
}

impl BatchProcessor for Engine {
    fn process(&mut self, reqs: &[GenRequest])
               -> Result<Vec<(Tensor, RequestMetrics)>> {
        self.generate(reqs)
    }

    fn process_streaming(
        &mut self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Result<Tensor, ServeError>,
                             RequestMetrics))
        -> Result<()> {
        self.generate_streaming(reqs, emit)
    }

    fn counters(&self) -> (u64, u64) {
        self.backend.counters()
    }

    fn set_beat(&mut self, beat: Arc<AtomicU64>) {
        self.beat = Some(beat);
    }
}
