//! The server: frontend handle + engine thread + lifecycle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::engine::Engine;
use super::metrics::ServerMetrics;
use super::queue::{QueueError, RequestQueue};
use super::request::{Envelope, GenRequest, GenResponse};
use crate::config::ServeConfig;

pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Mutex<ServerMetrics>>,
    next_id: AtomicU64,
    engine_thread: Option<JoinHandle<()>>,
    serve: ServeConfig,
}

impl Server {
    /// Start the engine thread (it builds the PJRT runtime locally —
    /// `PjRtClient` cannot cross threads).  Blocks until the engine is
    /// ready or failed, so callers get load errors synchronously.
    pub fn start(artifacts_dir: &str, serve: ServeConfig) -> Result<Server> {
        let queue = Arc::new(RequestQueue::new(serve.queue_capacity));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let q = Arc::clone(&queue);
        let m = Arc::clone(&metrics);
        let dir = artifacts_dir.to_string();
        let cfg = serve.clone();
        let engine_thread = std::thread::Builder::new()
            .name("sla2-engine".into())
            .spawn(move || {
                let engine = match Engine::new(&dir, cfg.clone()) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(engine, &cfg, &q, &m);
            })?;
        ready_rx.recv()??;
        Ok(Server { queue, metrics, next_id: AtomicU64::new(1),
                    engine_thread: Some(engine_thread), serve })
    }

    /// Submit a generation request; returns the reply channel.
    /// `Err` = backpressure (queue full) or shutdown.
    pub fn submit(&self, class_label: i32, seed: u64, steps: usize,
                  tier: &str)
                  -> Result<Receiver<Result<GenResponse>>, QueueError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let req = GenRequest::new(id, class_label, seed, steps, tier);
        self.metrics.lock().unwrap().requests += 1;
        match self.queue.push(Envelope { request: req, reply: tx }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.metrics.lock().unwrap().rejected += 1;
                Err(e)
            }
        }
    }

    /// Submit with the server's default tier.
    pub fn submit_default(&self, class_label: i32, seed: u64)
                          -> Result<Receiver<Result<GenResponse>>,
                                    QueueError> {
        self.submit(class_label, seed, self.serve.sample_steps,
                    &self.serve.tier.clone())
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        self.metrics.lock().unwrap().snapshot()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Graceful shutdown: close the queue and join the engine.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

fn engine_loop(engine: Engine, cfg: &ServeConfig,
               queue: &RequestQueue,
               metrics: &Mutex<ServerMetrics>) {
    crate::info!("engine up: model={} variant={} tier={} platform={}",
                 engine.model.name, engine.serve.variant, engine.serve.tier,
                 engine.runtime().platform());
    loop {
        let batch = match queue.pop_batch(
            cfg.max_batch,
            Duration::from_millis(100),
            Duration::from_millis(cfg.batch_window_ms)) {
            None => break, // closed + drained
            Some(b) if b.is_empty() => continue, // poll timeout
            Some(b) => b,
        };
        let reqs: Vec<_> = batch.iter().map(|e| e.request.clone()).collect();
        match engine.generate(&reqs) {
            Ok(results) => {
                let mut m = metrics.lock().unwrap();
                for (env, (clip, rm)) in batch.into_iter().zip(results) {
                    m.record_batch(rm.batch_size, rm.steps, rm.compute_ms);
                    m.record_completion(rm.queue_ms.max(0.0));
                    let _ = env.reply.send(Ok(GenResponse {
                        id: env.request.id, clip, metrics: rm }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                crate::warn_!("batch failed: {msg}");
                for env in batch {
                    let _ = env.reply.send(Err(anyhow::anyhow!(
                        "generation failed: {msg}")));
                }
            }
        }
    }
    crate::info!("engine shut down");
}
