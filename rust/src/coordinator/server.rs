//! The server: frontend handle + sharded engine pool + lifecycle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::engine::Engine;
use super::metrics::ServerMetrics;
use super::pool::EnginePool;
use super::queue::{QueueError, RequestQueue, SchedPolicy};
use super::request::{Envelope, GenRequest, GenResponse};
use crate::config::ServeConfig;

pub struct Server {
    queue: Arc<RequestQueue>,
    metrics: Arc<Mutex<ServerMetrics>>,
    next_id: AtomicU64,
    pool: Option<EnginePool>,
    serve: ServeConfig,
}

impl Server {
    /// Start `serve.num_shards` engine shards (each builds its PJRT
    /// runtime on its own thread — `PjRtClient` cannot cross threads).
    /// Blocks until every shard is ready or failed, so callers get
    /// load errors synchronously.
    pub fn start(artifacts_dir: &str, serve: ServeConfig) -> Result<Server> {
        let policy = SchedPolicy::from_config(&serve.scheduler,
                                              serve.bypass_threshold_ms);
        let queue = Arc::new(RequestQueue::with_policy(
            serve.queue_capacity, policy));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
        metrics.lock().unwrap().attach_queue(Arc::clone(&queue));
        let dir = artifacts_dir.to_string();
        let cfg = serve.clone();
        let pool = EnginePool::start_with(
            serve.num_shards.max(1),
            Arc::clone(&queue),
            Arc::clone(&metrics),
            serve.max_batch,
            Duration::from_millis(serve.batch_window_ms),
            move |shard| {
                let engine = Engine::new(&dir, cfg.clone())?;
                if shard == 0 {
                    crate::info!(
                        "engine up: model={} variant={} tier={} \
                         platform={}", engine.model.name,
                        engine.serve.variant, engine.serve.tier,
                        engine.runtime().platform());
                }
                Ok(engine)
            })?;
        Ok(Server { queue, metrics, next_id: AtomicU64::new(1),
                    pool: Some(pool), serve })
    }

    /// Submit a generation request; returns the reply channel.
    /// `Err` = backpressure (queue full) or shutdown.
    pub fn submit(&self, class_label: i32, seed: u64, steps: usize,
                  tier: &str)
                  -> Result<Receiver<Result<GenResponse>>, QueueError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let req = GenRequest::new(id, class_label, seed, steps, tier);
        self.metrics.lock().unwrap().requests += 1;
        match self.queue.push(Envelope { request: req, reply: tx }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.metrics.lock().unwrap().rejected += 1;
                Err(e)
            }
        }
    }

    /// Submit with the server's default tier.
    pub fn submit_default(&self, class_label: i32, seed: u64)
                          -> Result<Receiver<Result<GenResponse>>,
                                    QueueError> {
        self.submit(class_label, seed, self.serve.sample_steps,
                    &self.serve.tier.clone())
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        self.metrics.lock().unwrap().snapshot()
    }

    pub fn num_shards(&self) -> usize {
        self.pool.as_ref().map(|p| p.num_shards()).unwrap_or(0)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Graceful shutdown: close the queue, then join the dispatcher
    /// and every shard (each finishes its in-flight batch first).
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(mut p) = self.pool.take() {
            p.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(mut p) = self.pool.take() {
            p.join();
        }
    }
}
