//! The server: frontend gateway + sharded engine pool + optional TCP
//! listener + lifecycle.
//!
//! [`Gateway`] is the transport-independent submission surface (id
//! allocation, metrics accounting, queue push); [`Server`] wires it to
//! an [`EnginePool`] of PJRT shards and — when
//! `ServeConfig::listen_addr` is set — a [`super::net::NetFrontend`]
//! that exposes the same verbs over length-prefixed JSON-over-TCP.
//! Tests drive `Gateway` + a mock pool directly, so the whole reply
//! path (including the network frontend) is exercised without
//! artifacts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::engine::Engine;
use super::metrics::ServerMetrics;
use super::net::NetFrontend;
use super::pool::EnginePool;
use super::queue::{QueueError, RequestQueue, SchedPolicy};
use super::request::{Envelope, GenRequest, GenResponse};
use super::stream::{self, ClipStream};
use crate::config::ServeConfig;

/// Transport-independent request frontend: every submission surface
/// (in-process handles, the TCP frontend, load generators) goes
/// through here so ids, accounting and backpressure behave
/// identically.
pub struct Gateway {
    queue: Arc<RequestQueue>,
    metrics: Arc<Mutex<ServerMetrics>>,
    next_id: AtomicU64,
    serve: ServeConfig,
}

impl Gateway {
    pub fn new(queue: Arc<RequestQueue>,
               metrics: Arc<Mutex<ServerMetrics>>,
               serve: ServeConfig) -> Gateway {
        Gateway { queue, metrics, next_id: AtomicU64::new(1), serve }
    }

    pub fn serve_config(&self) -> &ServeConfig {
        &self.serve
    }

    /// Submit a generation request; returns the reply channel.
    /// `Err` = backpressure (queue full) or shutdown.
    pub fn submit(&self, class_label: i32, seed: u64, steps: usize,
                  tier: &str)
                  -> Result<Receiver<Result<GenResponse>>, QueueError> {
        self.submit_tracked(class_label, seed, steps, tier)
            .map(|(_, rx)| rx)
    }

    /// Like [`Gateway::submit`] but also returns the allocated request
    /// id, so multiplexing frontends can correlate the eventual reply.
    pub fn submit_tracked(&self, class_label: i32, seed: u64,
                          steps: usize, tier: &str)
                          -> Result<(u64, Receiver<Result<GenResponse>>),
                                    QueueError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let req = GenRequest::new(id, class_label, seed, steps, tier);
        self.metrics.lock().unwrap().requests += 1;
        match self.queue.push(Envelope::oneshot(req, tx)) {
            Ok(()) => Ok((id, rx)),
            Err(e) => {
                self.metrics.lock().unwrap().rejected += 1;
                Err(e)
            }
        }
    }

    /// Submit a generation request whose clip is delivered as a
    /// stream of frame-range chunks (`ServeConfig::chunk_frames` per
    /// chunk, buffer bounded by `ServeConfig::stream_buffer_chunks`).
    /// Dropping the returned [`ClipStream`] cancels the request.
    pub fn submit_streaming(&self, class_label: i32, seed: u64,
                            steps: usize, tier: &str)
                            -> Result<ClipStream, QueueError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (chunks, handle) = stream::channel(
            id, self.serve.chunk_frames, self.serve.stream_buffer_chunks);
        let req = GenRequest::new(id, class_label, seed, steps, tier);
        self.metrics.lock().unwrap().requests += 1;
        match self.queue.push(Envelope::stream(req, chunks)) {
            Ok(()) => {
                self.metrics.lock().unwrap().streams += 1;
                Ok(handle)
            }
            Err(e) => {
                self.metrics.lock().unwrap().rejected += 1;
                Err(e)
            }
        }
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        self.metrics.lock().unwrap().snapshot()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

pub struct Server {
    gateway: Arc<Gateway>,
    pool: Option<EnginePool>,
    net: Option<NetFrontend>,
}

impl Server {
    /// Start `serve.num_shards` engine shards (each builds its PJRT
    /// runtime on its own thread — `PjRtClient` cannot cross threads)
    /// and, when `serve.listen_addr` is non-empty, the TCP frontend.
    /// Blocks until every shard is ready or failed, so callers get
    /// load errors synchronously.
    pub fn start(artifacts_dir: &str, serve: ServeConfig) -> Result<Server> {
        let policy = SchedPolicy::from_config(&serve.scheduler,
                                              serve.bypass_threshold_ms);
        let queue = Arc::new(RequestQueue::with_policy(
            serve.queue_capacity, policy));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
        {
            let mut m = metrics.lock().unwrap();
            m.attach_queue(Arc::clone(&queue));
            m.attach_backend(&serve.backend);
            m.attach_quant_mode(&serve.quant_mode);
        }
        let dir = artifacts_dir.to_string();
        let cfg = serve.clone();
        let pool = EnginePool::start_with(
            serve.num_shards.max(1),
            Arc::clone(&queue),
            Arc::clone(&metrics),
            serve.max_batch,
            Duration::from_millis(serve.batch_window_ms),
            move |shard| {
                let engine = Engine::new(&dir, cfg.clone())?;
                if shard == 0 {
                    crate::info!(
                        "engine up: model={} variant={} tier={} \
                         backend={} platform={}", engine.model.name,
                        engine.serve.variant, engine.serve.tier,
                        engine.backend().name(),
                        engine.backend().platform());
                }
                Ok(engine)
            })?;
        let gateway = Arc::new(Gateway::new(queue, metrics, serve.clone()));
        let net = if serve.listen_addr.is_empty() {
            None
        } else {
            let frontend = NetFrontend::start(Arc::clone(&gateway),
                                              &serve.listen_addr)?;
            crate::info!("tcp frontend on {}", frontend.local_addr());
            Some(frontend)
        };
        Ok(Server { gateway, pool: Some(pool), net })
    }

    /// Submit a generation request; returns the reply channel.
    /// `Err` = backpressure (queue full) or shutdown.
    pub fn submit(&self, class_label: i32, seed: u64, steps: usize,
                  tier: &str)
                  -> Result<Receiver<Result<GenResponse>>, QueueError> {
        self.gateway.submit(class_label, seed, steps, tier)
    }

    /// Submit with the server's default tier.
    pub fn submit_default(&self, class_label: i32, seed: u64)
                          -> Result<Receiver<Result<GenResponse>>,
                                    QueueError> {
        let serve = self.gateway.serve_config();
        self.gateway.submit(class_label, seed, serve.sample_steps,
                            &serve.tier)
    }

    /// Streaming submit: chunks arrive on the returned [`ClipStream`]
    /// as the engine finishes them; dropping the stream cancels.
    pub fn submit_streaming(&self, class_label: i32, seed: u64,
                            steps: usize, tier: &str)
                            -> Result<ClipStream, QueueError> {
        self.gateway.submit_streaming(class_label, seed, steps, tier)
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        self.gateway.metrics_snapshot()
    }

    pub fn num_shards(&self) -> usize {
        self.pool.as_ref().map(|p| p.num_shards()).unwrap_or(0)
    }

    pub fn pending(&self) -> usize {
        self.gateway.pending()
    }

    /// Bound address of the TCP frontend, if one is listening
    /// (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> Option<std::net::SocketAddr> {
        self.net.as_ref().map(|n| n.local_addr())
    }

    /// Graceful shutdown: stop accepting connections, close the
    /// queue, then join the dispatcher and every shard (each finishes
    /// its in-flight batch first).
    pub fn shutdown(mut self) {
        self.wind_down();
    }

    fn wind_down(&mut self) {
        if let Some(mut n) = self.net.take() {
            n.shutdown();
        }
        self.gateway.queue.close();
        if let Some(mut p) = self.pool.take() {
            p.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.wind_down();
    }
}
