//! The server: frontend gateway + sharded engine pool + optional TCP
//! listener + lifecycle.
//!
//! [`Gateway`] is the transport-independent submission surface (id
//! allocation, admission control, metrics accounting, queue push);
//! [`Server`] wires it to an [`EnginePool`] of PJRT shards and — when
//! `ServeConfig::listen_addr` is set — a [`super::net::NetFrontend`]
//! that exposes the same verbs over length-prefixed JSON-over-TCP.
//! Tests drive `Gateway` + a mock pool directly, so the whole reply
//! path (including the network frontend) is exercised without
//! artifacts.
//!
//! Admission control runs BEFORE the queue push: when the queue is
//! past the configured depth/work watermarks, a submission is either
//! shed with a typed [`ServeError::Overloaded`] (carrying a
//! `retry_after_ms` hint that grows with the backlog) or — when the
//! caller opted in with [`SubmitOpts::allow_degrade`] — rerouted one
//! step down the sparsity-tier cost ladder instead of being turned
//! away.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::Engine;
use super::error::ServeError;
use super::metrics::ServerMetrics;
use super::net::NetFrontend;
use super::pool::{EnginePool, PoolConfig};
use super::queue::{QueueError, RequestQueue, SchedPolicy};
use super::request::{Envelope, GenRequest, GenResponse};
use super::stream::{self, ClipStream};
use crate::config::ServeConfig;
use crate::util::faults::FaultPlan;

/// Per-submission options beyond the core `(class, seed, steps,
/// tier)` tuple.  `Default` is the legacy behavior: no deadline
/// beyond the server-wide `ServeConfig::default_deadline_ms`, no
/// degradation, the server's configured attention variant.
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// per-request deadline in milliseconds from submission;
    /// 0 = fall back to `ServeConfig::default_deadline_ms`
    pub deadline_ms: u64,
    /// under overload, reroute to a cheaper sparsity tier instead of
    /// shedding (the original tier is recorded in
    /// `GenRequest::degraded_from`)
    pub allow_degrade: bool,
    /// attention-variant override (`"sla2"`, `"sparge2"`, `"svg_ear"`,
    /// ...); `None` = the server-wide `ServeConfig::variant`.
    /// Validated at admission against the backend's supported set —
    /// an unknown variant is a typed [`ServeError::BadRequest`], not
    /// a shard compile failure (which would burn retries and could
    /// quarantine healthy shards)
    pub variant: Option<String>,
}

/// Validate an attention-variant name against what `backend` can
/// compile.  The native backend's set is closed
/// ([`crate::runtime::native::model::SUPPORTED_VARIANTS`]); other
/// backends (xla) resolve variants from their artifact manifest at
/// compile time, so the gateway stays permissive for them.  Rejecting
/// here turns a client typo into a typed [`ServeError::BadRequest`]
/// instead of a repeated shard compile failure that would burn the
/// retry budget and could quarantine healthy shards.
fn validate_variant(backend: &str, variant: &str)
                    -> Result<(), ServeError> {
    use crate::runtime::native::model::SUPPORTED_VARIANTS;
    if backend == "native" && !SUPPORTED_VARIANTS.contains(&variant) {
        return Err(ServeError::BadRequest(format!(
            "unknown attention variant {variant:?} for the native \
             backend (supported: {})", SUPPORTED_VARIANTS.join(", "))));
    }
    Ok(())
}

/// One step down the tier cost ladder (the [`super::queue::ClassKey`]
/// cost ordering: dense is the most expensive, higher sparsity is
/// cheaper).  Tiers already at the bottom — and unknown tiers — have
/// nowhere to go.
fn degrade_tier(tier: &str) -> Option<&'static str> {
    match tier {
        "dense" => Some("s90"),
        "s90" => Some("s95"),
        "s95" => Some("s97"),
        _ => None,
    }
}

/// Transport-independent request frontend: every submission surface
/// (in-process handles, the TCP frontend, load generators) goes
/// through here so ids, accounting, admission control and
/// backpressure behave identically.
pub struct Gateway {
    queue: Arc<RequestQueue>,
    metrics: Arc<Mutex<ServerMetrics>>,
    next_id: AtomicU64,
    serve: ServeConfig,
    /// drain latch: once set, every new submission is rejected with a
    /// typed [`ServeError::ShuttingDown`] while in-flight work runs to
    /// completion.  Shared with the metrics snapshot (health section)
    /// and the TCP frontend (goaway frames).
    draining: Arc<AtomicBool>,
}

impl Gateway {
    pub fn new(queue: Arc<RequestQueue>,
               metrics: Arc<Mutex<ServerMetrics>>,
               serve: ServeConfig) -> Gateway {
        let draining = Arc::new(AtomicBool::new(false));
        ServerMetrics::lock(&metrics).attach_health(Arc::clone(&draining));
        Gateway { queue, metrics, next_id: AtomicU64::new(1), serve,
                  draining }
    }

    pub fn serve_config(&self) -> &ServeConfig {
        &self.serve
    }

    /// Flip admission to draining (idempotent): new work is rejected
    /// with [`ServeError::ShuttingDown`], in-flight work keeps going.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Admission decision for one request: `Ok(None)` = admit on the
    /// requested tier, `Ok(Some(t))` = admit degraded onto tier `t`,
    /// `Err(Overloaded)` = shed.  Watermarks at their defaults
    /// (`shed_watermark >= 1.0`, `work_watermark == 0`) admit
    /// everything — the queue's own capacity is then the only limit.
    fn admit(&self, tier: &str, allow_degrade: bool)
             -> Result<Option<String>, ServeError> {
        if self.draining.load(Ordering::Relaxed) {
            ServerMetrics::lock(&self.metrics).rejected += 1;
            return Err(ServeError::ShuttingDown);
        }
        let adm = self.queue.admission(self.serve.shed_watermark,
                                       self.serve.work_watermark);
        if !adm.overloaded {
            return Ok(None);
        }
        if allow_degrade {
            if let Some(cheaper) = degrade_tier(tier) {
                ServerMetrics::lock(&self.metrics).record_degraded();
                return Ok(Some(cheaper.to_string()));
            }
        }
        ServerMetrics::lock(&self.metrics).record_shed();
        Err(ServeError::Overloaded { retry_after_ms: adm.retry_after_ms })
    }

    /// Build the request a submission admits as: final tier (possibly
    /// degraded), effective deadline, variant override, degradation
    /// provenance.
    fn build_request(&self, id: u64, class_label: i32, seed: u64,
                     steps: usize, tier: &str, opts: SubmitOpts)
                     -> Result<GenRequest, ServeError> {
        if let Some(v) = &opts.variant {
            if let Err(e) = validate_variant(&self.serve.backend, v) {
                ServerMetrics::lock(&self.metrics).rejected += 1;
                return Err(e);
            }
        }
        let degraded_to = self.admit(tier, opts.allow_degrade)?;
        let final_tier =
            degraded_to.as_deref().unwrap_or(tier).to_string();
        let deadline_ms = if opts.deadline_ms > 0 {
            opts.deadline_ms
        } else {
            self.serve.default_deadline_ms
        };
        let mut req =
            GenRequest::new(id, class_label, seed, steps, &final_tier)
                .with_deadline_ms(deadline_ms)
                .with_allow_degrade(opts.allow_degrade)
                .with_variant(opts.variant);
        if degraded_to.is_some() {
            req.degraded_from = Some(tier.to_string());
        }
        Ok(req)
    }

    /// Map a queue-push failure to its typed error.  `Full` means the
    /// hard capacity bound fired (admission watermarks sit below it,
    /// when enabled), so the retry hint comes from the same backlog
    /// formula, floored so callers never get "retry in 0 ms" from a
    /// full queue.
    fn push_error(&self, e: QueueError) -> ServeError {
        match e {
            QueueError::Closed => ServeError::ShuttingDown,
            QueueError::Full(_) => {
                let adm = self.queue.admission(
                    self.serve.shed_watermark, self.serve.work_watermark);
                ServeError::Overloaded {
                    retry_after_ms: adm.retry_after_ms.max(25),
                }
            }
        }
    }

    /// Submit a generation request; returns the reply channel.
    /// `Err` = shed / backpressure ([`ServeError::Overloaded`]) or
    /// shutdown ([`ServeError::ShuttingDown`]).
    pub fn submit(&self, class_label: i32, seed: u64, steps: usize,
                  tier: &str)
                  -> Result<Receiver<Result<GenResponse, ServeError>>,
                            ServeError> {
        self.submit_with(class_label, seed, steps, tier,
                         SubmitOpts::default())
    }

    /// [`Gateway::submit`] with explicit per-request options.
    pub fn submit_with(&self, class_label: i32, seed: u64, steps: usize,
                       tier: &str, opts: SubmitOpts)
                       -> Result<Receiver<Result<GenResponse, ServeError>>,
                                 ServeError> {
        self.submit_tracked_with(class_label, seed, steps, tier, opts)
            .map(|(_, rx)| rx)
    }

    /// Like [`Gateway::submit`] but also returns the allocated request
    /// id, so multiplexing frontends can correlate the eventual reply.
    pub fn submit_tracked(&self, class_label: i32, seed: u64,
                          steps: usize, tier: &str)
                          -> Result<(u64,
                                     Receiver<Result<GenResponse,
                                                     ServeError>>),
                                    ServeError> {
        self.submit_tracked_with(class_label, seed, steps, tier,
                                 SubmitOpts::default())
    }

    /// [`Gateway::submit_tracked`] with explicit per-request options.
    pub fn submit_tracked_with(&self, class_label: i32, seed: u64,
                               steps: usize, tier: &str, opts: SubmitOpts)
                               -> Result<(u64,
                                          Receiver<Result<GenResponse,
                                                          ServeError>>),
                                         ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        ServerMetrics::lock(&self.metrics).requests += 1;
        let req = self.build_request(id, class_label, seed, steps, tier,
                                     opts)?;
        let (tx, rx) = channel();
        match self.queue.push(Envelope::oneshot(req, tx)) {
            Ok(()) => Ok((id, rx)),
            Err(e) => {
                ServerMetrics::lock(&self.metrics).rejected += 1;
                Err(self.push_error(e))
            }
        }
    }

    /// Submit a generation request whose clip is delivered as a
    /// stream of frame-range chunks (`ServeConfig::chunk_frames` per
    /// chunk, buffer bounded by `ServeConfig::stream_buffer_chunks`).
    /// Dropping the returned [`ClipStream`] cancels the request.
    pub fn submit_streaming(&self, class_label: i32, seed: u64,
                            steps: usize, tier: &str)
                            -> Result<ClipStream, ServeError> {
        self.submit_streaming_with(class_label, seed, steps, tier,
                                   SubmitOpts::default())
    }

    /// [`Gateway::submit_streaming`] with explicit per-request options.
    pub fn submit_streaming_with(&self, class_label: i32, seed: u64,
                                 steps: usize, tier: &str,
                                 opts: SubmitOpts)
                                 -> Result<ClipStream, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        ServerMetrics::lock(&self.metrics).requests += 1;
        let req = self.build_request(id, class_label, seed, steps, tier,
                                     opts)?;
        let (chunks, handle) = stream::channel(
            id, self.serve.chunk_frames, self.serve.stream_buffer_chunks);
        match self.queue.push(Envelope::stream(req, chunks)) {
            Ok(()) => {
                ServerMetrics::lock(&self.metrics).streams += 1;
                Ok(handle)
            }
            Err(e) => {
                ServerMetrics::lock(&self.metrics).rejected += 1;
                Err(self.push_error(e))
            }
        }
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        ServerMetrics::lock(&self.metrics).snapshot()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

pub struct Server {
    gateway: Arc<Gateway>,
    pool: Option<EnginePool>,
    net: Option<NetFrontend>,
}

impl Server {
    /// Start `serve.num_shards` engine shards (each builds its PJRT
    /// runtime on its own thread — `PjRtClient` cannot cross threads)
    /// and, when `serve.listen_addr` is non-empty, the TCP frontend.
    /// Blocks until every shard is ready or failed, so callers get
    /// load errors synchronously.
    ///
    /// When `serve.fault_plan` is non-empty it is parsed into a
    /// deterministic [`FaultPlan`]: execute-site clauses wrap each
    /// shard's backend, net-site clauses arm the TCP frontend's
    /// connection injectors.  A malformed plan fails startup.
    pub fn start(artifacts_dir: &str, serve: ServeConfig) -> Result<Server> {
        // fail fast on an unservable default variant instead of having
        // every shard's first compile fail at batch time
        validate_variant(&serve.backend, &serve.variant)
            .map_err(|e| anyhow::anyhow!("serve config: {e}"))?;
        let fault_plan = FaultPlan::parse(&serve.fault_plan,
                                          serve.fault_seed)?;
        let policy = SchedPolicy::from_config(&serve.scheduler,
                                              serve.bypass_threshold_ms);
        let queue = Arc::new(RequestQueue::with_policy(
            serve.queue_capacity, policy));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
        {
            let mut m = ServerMetrics::lock(&metrics);
            m.attach_queue(Arc::clone(&queue));
            m.attach_backend(&serve.backend);
            m.attach_quant_mode(&serve.quant_mode);
            if serve.backend == "native" {
                // resolve the ISA through `request` (not `active`): a
                // bare `active()` here would pin detection before the
                // shards' own `--kernel-isa` request could take effect
                let isa = crate::runtime::native::simd::request(
                    &serve.kernel_isa)
                    .map_err(|e| anyhow::anyhow!("serve config: {e}"))?;
                m.attach_kernel_isa(isa.name());
            }
            m.attach_variant(&serve.variant);
        }
        let pool_cfg = PoolConfig {
            max_batch: serve.max_batch,
            batch_window: Duration::from_millis(serve.batch_window_ms),
            retry_budget: serve.retry_budget,
            retry_backoff_ms: serve.retry_backoff_ms,
            quarantine_failures: serve.quarantine_failures,
            quarantine_window:
                Duration::from_millis(serve.quarantine_window_ms),
            quarantine_cooldown:
                Duration::from_millis(serve.quarantine_cooldown_ms),
            stall_threshold:
                Duration::from_millis(serve.stall_threshold_ms),
        };
        let dir = artifacts_dir.to_string();
        let cfg = serve.clone();
        let plan = fault_plan.clone();
        let pool = EnginePool::start_with_config(
            serve.num_shards.max(1),
            Arc::clone(&queue),
            Arc::clone(&metrics),
            pool_cfg,
            move |shard| {
                let engine = Engine::new_with_injector(
                    &dir, cfg.clone(), plan.execute_injector(shard))?;
                if shard == 0 {
                    crate::info!(
                        "engine up: model={} variant={} tier={} \
                         backend={} platform={}", engine.model.name,
                        engine.serve.variant, engine.serve.tier,
                        engine.backend().name(),
                        engine.backend().platform());
                }
                Ok(engine)
            })?;
        let gateway = Arc::new(Gateway::new(queue, metrics, serve.clone()));
        let net = if serve.listen_addr.is_empty() {
            None
        } else {
            let frontend = NetFrontend::start_with_faults(
                Arc::clone(&gateway), &serve.listen_addr, fault_plan)?;
            crate::info!("tcp frontend on {}", frontend.local_addr());
            Some(frontend)
        };
        Ok(Server { gateway, pool: Some(pool), net })
    }

    /// Submit a generation request; returns the reply channel.
    /// `Err` = shed / backpressure or shutdown.
    pub fn submit(&self, class_label: i32, seed: u64, steps: usize,
                  tier: &str)
                  -> Result<Receiver<Result<GenResponse, ServeError>>,
                            ServeError> {
        self.gateway.submit(class_label, seed, steps, tier)
    }

    /// [`Server::submit`] with explicit per-request options
    /// (deadline, degradation opt-in).
    pub fn submit_with(&self, class_label: i32, seed: u64, steps: usize,
                       tier: &str, opts: SubmitOpts)
                       -> Result<Receiver<Result<GenResponse, ServeError>>,
                                 ServeError> {
        self.gateway.submit_with(class_label, seed, steps, tier, opts)
    }

    /// Submit with the server's default tier.
    pub fn submit_default(&self, class_label: i32, seed: u64)
                          -> Result<Receiver<Result<GenResponse,
                                                    ServeError>>,
                                    ServeError> {
        let serve = self.gateway.serve_config();
        self.gateway.submit(class_label, seed, serve.sample_steps,
                            &serve.tier)
    }

    /// Streaming submit: chunks arrive on the returned [`ClipStream`]
    /// as the engine finishes them; dropping the stream cancels.
    pub fn submit_streaming(&self, class_label: i32, seed: u64,
                            steps: usize, tier: &str)
                            -> Result<ClipStream, ServeError> {
        self.gateway.submit_streaming(class_label, seed, steps, tier)
    }

    /// [`Server::submit_streaming`] with explicit per-request options.
    pub fn submit_streaming_with(&self, class_label: i32, seed: u64,
                                 steps: usize, tier: &str,
                                 opts: SubmitOpts)
                                 -> Result<ClipStream, ServeError> {
        self.gateway.submit_streaming_with(class_label, seed, steps,
                                           tier, opts)
    }

    pub fn metrics_snapshot(&self) -> crate::util::json::Json {
        self.gateway.metrics_snapshot()
    }

    pub fn num_shards(&self) -> usize {
        self.pool.as_ref().map(|p| p.num_shards()).unwrap_or(0)
    }

    pub fn pending(&self) -> usize {
        self.gateway.pending()
    }

    /// Bound address of the TCP frontend, if one is listening
    /// (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> Option<std::net::SocketAddr> {
        self.net.as_ref().map(|n| n.local_addr())
    }

    /// Whether admission has been flipped to `shutting_down` — set by
    /// [`Server::drain`] or the wire `drain` verb.  The serve loop
    /// polls this so a remote drain request triggers the full local
    /// drain-and-exit sequence.
    pub fn is_draining(&self) -> bool {
        self.gateway.is_draining()
    }

    /// Graceful drain: flip admission to [`ServeError::ShuttingDown`]
    /// (the TCP frontend additionally sends `goaway` to idle
    /// connections), then wait — up to `ServeConfig::drain_timeout_ms`
    /// — for the queue to empty and every shard to finish its
    /// in-flight batch.  Returns true when everything completed inside
    /// the window; false means the timeout fired with work still in
    /// flight (callers normally proceed to [`Server::shutdown`], which
    /// still drains queued work but blocks until it is done).
    ///
    /// Open [`ClipStream`]s are not cut off: their in-flight clips
    /// finish streaming and every stream ends with its normal terminal
    /// frame (final chunk or typed error) before this returns true.
    pub fn drain(&self) -> bool {
        self.gateway.begin_drain();
        if let Some(n) = &self.net {
            n.announce_drain();
        }
        crate::info!("drain: admission closed; waiting for in-flight \
                      work (timeout {} ms)",
                     self.gateway.serve.drain_timeout_ms);
        let timeout =
            Duration::from_millis(self.gateway.serve.drain_timeout_ms);
        let t0 = Instant::now();
        loop {
            let quiesced = self.gateway.pending() == 0
                && self.pool.as_ref()
                    .map(|p| p.in_flight() == 0)
                    .unwrap_or(true);
            if quiesced {
                crate::info!("drain complete in {:?}", t0.elapsed());
                return true;
            }
            if t0.elapsed() >= timeout {
                crate::warn_!("drain timeout after {timeout:?} with \
                               work still in flight");
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Graceful shutdown: stop accepting connections, close the
    /// queue, then join the dispatcher and every shard (each finishes
    /// its in-flight batch first).
    pub fn shutdown(mut self) {
        self.wind_down();
    }

    fn wind_down(&mut self) {
        if let Some(mut n) = self.net.take() {
            n.shutdown();
        }
        self.gateway.queue.close();
        if let Some(mut p) = self.pool.take() {
            p.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.wind_down();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn gateway_with(capacity: usize, serve: ServeConfig) -> Gateway {
        let queue = Arc::new(RequestQueue::new(capacity));
        let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
        Gateway::new(queue, metrics, serve)
    }

    #[test]
    fn default_watermarks_admit_up_to_capacity() {
        let g = gateway_with(2, ServeConfig::default());
        assert!(g.submit(0, 1, 4, "s90").is_ok());
        assert!(g.submit(0, 2, 4, "s90").is_ok());
        let err = g.submit(0, 3, 4, "s90").unwrap_err();
        assert_eq!(err.code(), "overloaded");
        assert!(err.retry_after_ms().unwrap() >= 25);
    }

    #[test]
    fn shed_watermark_sheds_with_typed_overloaded() {
        let serve = ServeConfig { shed_watermark: 0.5,
                                  ..ServeConfig::default() };
        let g = gateway_with(4, serve);
        assert!(g.submit(0, 1, 4, "s90").is_ok());
        assert!(g.submit(0, 2, 4, "s90").is_ok());
        // depth 2 >= ceil(0.5 * 4) -> shed
        let err = g.submit(0, 3, 4, "s90").unwrap_err();
        assert!(matches!(err, ServeError::Overloaded { .. }));
        let snap = g.metrics_snapshot();
        let failures = snap.get("failures").unwrap();
        assert_eq!(failures.get("shed").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn allow_degrade_reroutes_to_a_cheaper_tier_instead_of_shedding() {
        let serve = ServeConfig { shed_watermark: 0.25,
                                  ..ServeConfig::default() };
        let g = gateway_with(4, serve);
        assert!(g.submit(0, 1, 4, "dense").is_ok());
        // over the watermark: a degradable request is admitted one
        // tier cheaper...
        let opts = SubmitOpts { allow_degrade: true,
                                ..SubmitOpts::default() };
        assert!(g.submit_with(0, 2, 4, "dense", opts.clone()).is_ok());
        // ...and lands in the queue rather than being turned away
        assert_eq!(g.pending(), 2);
        let snap = g.metrics_snapshot();
        let failures = snap.get("failures").unwrap();
        assert_eq!(failures.get("degraded").unwrap()
                       .as_usize().unwrap(), 1);
        assert_eq!(failures.get("shed").unwrap().as_usize().unwrap(), 0);
        // a request already at the bottom of the ladder still sheds
        let err = g.submit_with(0, 3, 4, "s97", opts).unwrap_err();
        assert_eq!(err.code(), "overloaded");
    }

    #[test]
    fn degrade_ladder_walks_dense_to_s97() {
        assert_eq!(degrade_tier("dense"), Some("s90"));
        assert_eq!(degrade_tier("s90"), Some("s95"));
        assert_eq!(degrade_tier("s95"), Some("s97"));
        assert_eq!(degrade_tier("s97"), None);
        assert_eq!(degrade_tier("mystery"), None);
    }

    #[test]
    fn drain_rejects_new_work_with_typed_shutting_down() {
        let g = gateway_with(4, ServeConfig::default());
        assert!(g.submit(0, 1, 4, "s90").is_ok());
        assert!(!g.is_draining());
        g.begin_drain();
        g.begin_drain(); // idempotent
        assert!(g.is_draining());
        let err = g.submit(0, 2, 4, "s90").unwrap_err();
        assert_eq!(err.code(), "shutting_down");
        assert!(!err.retryable());
        // already-queued work is untouched by the admission flip
        assert_eq!(g.pending(), 1);
        let snap = g.metrics_snapshot();
        let health = snap.get("health").unwrap();
        assert!(health.get("draining").unwrap().as_bool().unwrap());
        assert!(!health.get("ready").unwrap().as_bool().unwrap());
    }

    #[test]
    fn native_gateway_rejects_unknown_variant_with_typed_bad_request() {
        let serve = ServeConfig { backend: "native".into(),
                                  ..ServeConfig::default() };
        let g = gateway_with(4, serve);
        let opts = SubmitOpts { variant: Some("vsa".into()),
                                ..SubmitOpts::default() };
        let err = g.submit_with(0, 1, 4, "s90", opts).unwrap_err();
        assert_eq!(err.code(), "bad_request");
        assert!(!err.retryable());
        // the reject names the full supported set so clients can
        // self-correct without a round trip to the docs
        for v in crate::runtime::native::model::SUPPORTED_VARIANTS {
            assert!(err.to_string().contains(v),
                    "reject should list {v:?}: {err}");
        }
        let snap = g.metrics_snapshot();
        assert_eq!(snap.get("rejected").unwrap().as_usize().unwrap(), 1);
        assert_eq!(g.pending(), 0, "nothing reached the queue");

        // a known variant override is admitted and stamped on the
        // request (so the scheduler/engine see it)
        let opts = SubmitOpts { variant: Some("sparge2".into()),
                                ..SubmitOpts::default() };
        let req = g.build_request(7, 0, 1, 4, "s90", opts).unwrap();
        assert_eq!(req.variant.as_deref(), Some("sparge2"));

        // non-native backends resolve variants at compile time, so
        // the gateway stays permissive for them
        let g = gateway_with(4, ServeConfig { backend: "xla".into(),
                                              ..ServeConfig::default() });
        let opts = SubmitOpts { variant: Some("vsa".into()),
                                ..SubmitOpts::default() };
        assert!(g.submit_with(0, 1, 4, "s90", opts).is_ok());
    }

    #[test]
    fn submit_opts_deadline_is_stamped_on_the_request() {
        let serve = ServeConfig { default_deadline_ms: 0,
                                  ..ServeConfig::default() };
        let g = gateway_with(4, serve);
        let opts = SubmitOpts { deadline_ms: 60_000,
                                ..SubmitOpts::default() };
        let req = g.build_request(1, 0, 1, 4, "s90", opts).unwrap();
        assert!(req.deadline.is_some());
        assert!(req.degraded_from.is_none());
        let req = g.build_request(
            2, 0, 1, 4, "s90", SubmitOpts::default()).unwrap();
        assert!(req.deadline.is_none(),
                "no per-request or server default deadline");
    }
}
