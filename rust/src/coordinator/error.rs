//! Typed failure taxonomy for the serving path.
//!
//! Every request submitted to the coordinator resolves to exactly one
//! of {clip, [`ServeError`]}.  The enum replaces the ad-hoc string
//! errors that used to travel through the reply channels: callers (and
//! the TCP frontend) can now branch on *kind* — retry `Overloaded`
//! after `retry_after_ms`, give up on `BadRequest`, resubmit a
//! retryable `ShardFailed` — instead of grepping messages.
//!
//! Wire mapping: [`ServeError::code`] is the stable machine-readable
//! string carried in the `code` field of `error`/`rejected` frames
//! (see the `coordinator::net` module docs); [`std::fmt::Display`]
//! keeps the human-readable message.

use thiserror::Error;

/// Terminal failure of a generation request.
#[derive(Debug, Clone, PartialEq, Eq, Error)]
pub enum ServeError {
    /// Admission control shed the request: the queue is past its
    /// watermark.  `retry_after_ms` is the server's drain estimate —
    /// clients should back off at least that long before resubmitting.
    #[error("server overloaded — retry in {retry_after_ms} ms")]
    Overloaded { retry_after_ms: u64 },

    /// The request's deadline passed before a clip could be delivered
    /// (dropped at dequeue, or aborted mid-flight between sub-batches
    /// or denoise steps).
    #[error("deadline exceeded")]
    DeadlineExceeded,

    /// The shard serving this request failed.  `retryable` is true for
    /// transient failures (a panic that took the batch down) where a
    /// resubmit may succeed on a healthy shard; false for deterministic
    /// failures (the same input would fail again) and exhausted retry
    /// budgets.
    #[error("generation failed: {reason}")]
    ShardFailed { retryable: bool, reason: String },

    /// The client cancelled the request (dropped stream, `cancel`
    /// verb, or disconnect).
    #[error("request cancelled")]
    Cancelled,

    /// The request itself was invalid (malformed frame, out-of-range
    /// parameter).  Never retryable: the same request fails again.
    #[error("bad request: {0}")]
    BadRequest(String),

    /// The server is winding down and no longer admits work.
    #[error("server shutting down")]
    ShuttingDown,

    /// The shard serving this request stopped making progress (its
    /// heartbeat went stale) and the watchdog failed the in-flight
    /// batch.  Always retryable: the replacement shard is healthy and
    /// the input was never the problem.
    #[error("shard stalled: {reason}")]
    ShardStalled { reason: String },

    /// The connection has not presented the server's access token, or
    /// presented a wrong one (`--auth-token`).  Not retryable on this
    /// connection: the server closes it — reconnect and open with a
    /// correct `hello`.
    #[error("unauthorized: {0}")]
    Unauthorized(String),

    /// The connection exceeded its submit budget (`--rate-limit`).
    /// Retryable: the token bucket refills — back off at least
    /// `retry_after_ms`.  Only the submit is shed; the connection and
    /// its in-flight streams are untouched.
    #[error("rate limited — retry in {retry_after_ms} ms")]
    RateLimited { retry_after_ms: u64 },
}

impl ServeError {
    /// Transient shard failure (a resubmit may land on a healthy
    /// shard).
    pub fn shard_transient(reason: impl Into<String>) -> ServeError {
        ServeError::ShardFailed { retryable: true, reason: reason.into() }
    }

    /// Deterministic shard failure (retrying cannot help).
    pub fn shard_fatal(reason: impl Into<String>) -> ServeError {
        ServeError::ShardFailed { retryable: false, reason: reason.into() }
    }

    /// Watchdog-detected stall (heartbeat stale past the threshold).
    pub fn shard_stalled(reason: impl Into<String>) -> ServeError {
        ServeError::ShardStalled { reason: reason.into() }
    }

    /// Stable machine-readable code (the wire protocol's `code`
    /// field).  Never reword these: clients branch on them.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::ShardFailed { .. } => "shard_failed",
            ServeError::Cancelled => "cancelled",
            ServeError::BadRequest(_) => "bad_request",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::ShardStalled { .. } => "shard_stalled",
            ServeError::Unauthorized(_) => "unauthorized",
            ServeError::RateLimited { .. } => "rate_limited",
        }
    }

    /// Whether resubmitting the same request can succeed.
    pub fn retryable(&self) -> bool {
        match self {
            ServeError::Overloaded { .. } => true,
            ServeError::DeadlineExceeded => false,
            ServeError::ShardFailed { retryable, .. } => *retryable,
            ServeError::Cancelled => false,
            ServeError::BadRequest(_) => false,
            ServeError::ShuttingDown => false,
            ServeError::ShardStalled { .. } => true,
            ServeError::Unauthorized(_) => false,
            ServeError::RateLimited { .. } => true,
        }
    }

    /// Suggested client backoff, when the server has one.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            ServeError::Overloaded { retry_after_ms }
            | ServeError::RateLimited { retry_after_ms } =>
                Some(*retry_after_ms),
            _ => None,
        }
    }

    /// Reconstruct a `ServeError` from its wire form (`code` plus the
    /// optional `retryable`/`retry_after_ms` fields and the human
    /// message).  Unknown codes map to a non-retryable `ShardFailed`
    /// so old clients still terminate.
    pub fn from_wire(code: &str, message: &str, retryable: bool,
                     retry_after_ms: u64) -> ServeError {
        match code {
            "overloaded" => ServeError::Overloaded { retry_after_ms },
            "deadline_exceeded" => ServeError::DeadlineExceeded,
            "cancelled" => ServeError::Cancelled,
            "bad_request" => ServeError::BadRequest(message.to_string()),
            "shutting_down" => ServeError::ShuttingDown,
            "shard_stalled" => ServeError::ShardStalled {
                reason: message.to_string(),
            },
            "unauthorized" =>
                ServeError::Unauthorized(message.to_string()),
            "rate_limited" => ServeError::RateLimited { retry_after_ms },
            _ => ServeError::ShardFailed {
                retryable,
                reason: message.to_string(),
            },
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_distinct() {
        let all = [
            ServeError::Overloaded { retry_after_ms: 10 },
            ServeError::DeadlineExceeded,
            ServeError::shard_transient("boom"),
            ServeError::Cancelled,
            ServeError::BadRequest("nope".into()),
            ServeError::ShuttingDown,
            ServeError::shard_stalled("no beat for 600 ms"),
            ServeError::Unauthorized("bad or missing token".into()),
            ServeError::RateLimited { retry_after_ms: 40 },
        ];
        let codes: Vec<&str> = all.iter().map(|e| e.code()).collect();
        assert_eq!(codes, ["overloaded", "deadline_exceeded",
                           "shard_failed", "cancelled", "bad_request",
                           "shutting_down", "shard_stalled",
                           "unauthorized", "rate_limited"]);
        let mut dedup = codes.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), codes.len());
    }

    #[test]
    fn retryability() {
        assert!(ServeError::Overloaded { retry_after_ms: 1 }.retryable());
        assert!(ServeError::shard_transient("panic").retryable());
        assert!(!ServeError::shard_fatal("bad shape").retryable());
        assert!(!ServeError::DeadlineExceeded.retryable());
        assert!(!ServeError::BadRequest("x".into()).retryable());
        assert!(!ServeError::Cancelled.retryable());
        assert!(!ServeError::ShuttingDown.retryable());
        assert!(ServeError::shard_stalled("stale beat").retryable(),
                "a stall is the shard's fault, never the request's");
        assert!(!ServeError::Unauthorized("bad token".into()).retryable(),
                "retrying with the same missing token cannot help");
        assert!(ServeError::RateLimited { retry_after_ms: 1 }.retryable(),
                "the token bucket refills");
        assert_eq!(ServeError::RateLimited { retry_after_ms: 35 }
                       .retry_after_ms(),
                   Some(35));
    }

    #[test]
    fn wire_roundtrip() {
        let cases = [
            ServeError::Overloaded { retry_after_ms: 250 },
            ServeError::DeadlineExceeded,
            ServeError::ShardFailed { retryable: true,
                                      reason: "generation failed: boom"
                                          .into() },
            ServeError::Cancelled,
            ServeError::BadRequest("bad request: oversized frame".into()),
            ServeError::ShuttingDown,
            ServeError::shard_stalled("no beat for 600 ms"),
            ServeError::Unauthorized("unauthorized: bad token".into()),
            ServeError::RateLimited { retry_after_ms: 40 },
        ];
        for e in cases {
            let back = ServeError::from_wire(
                e.code(), &e.to_string(), e.retryable(),
                e.retry_after_ms().unwrap_or(0));
            assert_eq!(back.code(), e.code());
            assert_eq!(back.retryable(), e.retryable());
            assert_eq!(back.retry_after_ms(), e.retry_after_ms());
        }
    }

    #[test]
    fn unknown_wire_code_degrades_to_shard_failed() {
        let e = ServeError::from_wire("martian", "???", false, 0);
        assert_eq!(e.code(), "shard_failed");
        assert!(!e.retryable());
    }

    #[test]
    fn messages_keep_the_legacy_prefix() {
        // pre-existing clients grep for "generation failed"
        let e = ServeError::shard_transient("batch processor panicked");
        assert!(e.to_string().contains("generation failed"));
        assert!(e.to_string().contains("batch processor panicked"));
    }
}
