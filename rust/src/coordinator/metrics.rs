//! Server-level metrics: counters + latency distributions + the
//! per-shard rollup (compiles, executions, batches, utilization,
//! health state) + scheduler observability (per-class queue depths,
//! warm/cold dispatch routing, compile-cache dedup) + streaming
//! delivery (streams opened, chunks sent, cancelled streams,
//! first-chunk latency) + the failure/overload rollup (sheds,
//! degrades, deadline expiries, retries, quarantine flaps).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use super::pool::{DispatchStats, ShardStats};
use super::queue::RequestQueue;
use crate::util::json::Json;
use crate::util::stats::Online;

#[derive(Debug)]
pub struct ServerMetrics {
    started: Instant,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    /// engine invocations (sub-batches after batch-size planning)
    pub batches: u64,
    pub denoise_steps: u64,
    pub queue_ms: Online,
    pub compute_ms: Online,
    pub batch_size: Online,
    /// streaming submits accepted (subset of `requests`)
    pub streams: u64,
    /// chunks delivered across all streams
    pub chunks_sent: u64,
    /// streams abandoned by their consumer before/during delivery
    pub cancelled_streams: u64,
    /// submit -> first-chunk-delivery latency, streaming requests only
    pub first_chunk_ms: Online,
    /// requests shed by admission control (typed `Overloaded` reply);
    /// disjoint from `rejected`, which counts hard queue-full/closed
    pub shed: u64,
    /// requests rerouted to a cheaper tier instead of being shed
    pub degraded: u64,
    /// requests that failed with `DeadlineExceeded` mid-flight (the
    /// queue's dequeue-time drops are reported separately from the
    /// attached queue)
    pub deadline_expired: u64,
    /// shard-panic survivors requeued for another attempt
    pub retries: u64,
    /// requests that terminally failed with `ShardFailed`
    pub failed: u64,
    /// per-shard counters, attached by the engine pool at startup
    shards: Vec<Arc<ShardStats>>,
    /// dispatcher routing counters, attached by the engine pool
    dispatch: Option<Arc<DispatchStats>>,
    /// the live queue, attached by the server for per-class depth
    /// gauges (lock order: metrics -> queue, never the reverse)
    queue: Option<Arc<RequestQueue>>,
    /// compute backend name ("xla" | "native"), attached by the server
    backend: Option<String>,
    /// the native backend's quant_mode knob ("int8" | "sim" | "off"),
    /// attached by the server alongside `backend`
    quant_mode: Option<String>,
    /// the SIMD instruction set the native kernel layer resolved at
    /// startup ("avx2" | "sse41" | "neon" | "scalar"), attached by the
    /// server alongside `backend`; `--kernel-isa` requests and the
    /// `SLA2_FORCE_SCALAR` override are already folded in
    kernel_isa: Option<String>,
    /// the server-wide default attention variant ("sla2" | "sparge2" |
    /// "svg_ear" | ...), attached by the server; per-request overrides
    /// show up in the per-class queue depths and the per-variant
    /// native-kernel counters instead
    variant: Option<String>,
    /// the gateway's drain latch, attached at gateway construction;
    /// drives the health section's `draining`/`ready` fields
    draining: Option<Arc<AtomicBool>>,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    /// Lock the shared metrics, RECOVERING from poison.  Shard threads
    /// take this lock inside `catch_unwind` scopes: a panic while
    /// holding it poisons the mutex, and a plain `.unwrap()` would
    /// then cascade that one panic into every other shard thread that
    /// touches metrics.  Metrics are monotonic counters and running
    /// means — a half-applied update is at worst one off — so
    /// recovering the guard is always safe.
    pub fn lock(m: &Mutex<ServerMetrics>) -> MutexGuard<'_, ServerMetrics> {
        m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    pub fn new() -> ServerMetrics {
        ServerMetrics {
            started: Instant::now(),
            requests: 0,
            completed: 0,
            rejected: 0,
            batches: 0,
            denoise_steps: 0,
            queue_ms: Online::new(),
            compute_ms: Online::new(),
            batch_size: Online::new(),
            streams: 0,
            chunks_sent: 0,
            cancelled_streams: 0,
            first_chunk_ms: Online::new(),
            shed: 0,
            degraded: 0,
            deadline_expired: 0,
            retries: 0,
            failed: 0,
            shards: Vec::new(),
            dispatch: None,
            queue: None,
            backend: None,
            quant_mode: None,
            kernel_isa: None,
            variant: None,
            draining: None,
        }
    }

    /// Wire in the pool's per-shard counters (called once at startup).
    pub fn attach_shards(&mut self, shards: Vec<Arc<ShardStats>>) {
        self.shards = shards;
    }

    /// Wire in the dispatcher's routing counters (engine pool startup).
    pub fn attach_dispatch(&mut self, dispatch: Arc<DispatchStats>) {
        self.dispatch = Some(dispatch);
    }

    /// Wire in the live queue so snapshots can report per-class depth.
    pub fn attach_queue(&mut self, queue: Arc<RequestQueue>) {
        self.queue = Some(queue);
    }

    /// Record which compute backend serves this server's requests;
    /// `"native"` additionally surfaces the process-wide native-kernel
    /// counters in every snapshot.
    pub fn attach_backend(&mut self, backend: &str) {
        self.backend = Some(backend.to_string());
    }

    /// Record the configured quant mode (surfaced next to `backend`
    /// for native servers, so dashboards can tell real-INT8 serving
    /// from the f32 simulation at a glance).
    pub fn attach_quant_mode(&mut self, mode: &str) {
        self.quant_mode = Some(mode.to_string());
    }

    /// Record the SIMD ISA the native kernel layer resolved at startup
    /// (surfaced next to `backend`, so a metrics scrape can tell an
    /// AVX2 box from a scalar-fallback or force-scalar run without
    /// shelling into the host).
    pub fn attach_kernel_isa(&mut self, isa: &str) {
        self.kernel_isa = Some(isa.to_string());
    }

    /// Record the server's default attention variant (surfaced next to
    /// `backend` so dashboards can tell a sparge2 shoot-out run from
    /// regular sla2 serving; per-request overrides surface through the
    /// per-class queue depths and per-variant kernel counters).
    pub fn attach_variant(&mut self, variant: &str) {
        self.variant = Some(variant.to_string());
    }

    /// Wire in the gateway's drain latch so snapshots report liveness
    /// and readiness (called from `Gateway::new`).
    pub fn attach_health(&mut self, draining: Arc<AtomicBool>) {
        self.draining = Some(draining);
    }

    pub fn record_batch(&mut self, size: usize, steps: usize,
                        compute_ms: f64) {
        self.batches += 1;
        self.denoise_steps += (steps * size) as u64;
        self.batch_size.push(size as f64);
        self.compute_ms.push(compute_ms);
    }

    pub fn record_completion(&mut self, queue_ms: f64) {
        self.completed += 1;
        self.queue_ms.push(queue_ms);
    }

    /// A stream finished delivery: `chunks` frames-ranges were sent,
    /// the first of them `first_chunk_ms` after submit.
    pub fn record_stream_delivery(&mut self, chunks: usize,
                                  first_chunk_ms: f64) {
        self.chunks_sent += chunks as u64;
        self.first_chunk_ms.push(first_chunk_ms);
    }

    /// A stream's consumer vanished before (or during) delivery.
    pub fn record_cancelled_stream(&mut self) {
        self.cancelled_streams += 1;
    }

    /// Admission control shed a request with a typed `Overloaded`.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Admission control rerouted a request to a cheaper tier.
    pub fn record_degraded(&mut self) {
        self.degraded += 1;
    }

    /// A request expired mid-flight (between sub-batches or steps).
    pub fn record_deadline_expired(&mut self) {
        self.deadline_expired += 1;
    }

    /// A shard-panic survivor was requeued for another attempt.
    pub fn record_retry(&mut self) {
        self.retries += 1;
    }

    /// A request terminally failed with `ShardFailed`.
    pub fn record_failed(&mut self) {
        self.failed += 1;
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Total (compiles, executions) summed over every shard.
    pub fn pool_counters(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(c, e), s| {
            (c + s.compiles.load(Ordering::Relaxed),
             e + s.executions.load(Ordering::Relaxed))
        })
    }

    pub fn snapshot(&self) -> Json {
        let uptime_s = self.started.elapsed().as_secs_f64();
        let (compiles, executions) = self.pool_counters();
        let mut j = Json::obj()
            .push("requests", self.requests as usize)
            .push("completed", self.completed as usize)
            .push("rejected", self.rejected as usize)
            .push("batches", self.batches as usize)
            .push("denoise_steps", self.denoise_steps as usize)
            .push("mean_batch_size", self.batch_size.mean())
            .push("mean_queue_ms", self.queue_ms.mean())
            .push("mean_compute_ms", self.compute_ms.mean())
            .push("throughput_rps", self.throughput_rps())
            .push("streaming", Json::obj()
                .push("streams", self.streams as usize)
                .push("chunks_sent", self.chunks_sent as usize)
                .push("cancelled_streams", self.cancelled_streams as usize)
                .push("mean_first_chunk_ms", self.first_chunk_ms.mean()));
        {
            let stalls: u64 = self.shards.iter()
                .map(|s| s.stalls.load(Ordering::Relaxed))
                .sum();
            let mut f = Json::obj()
                .push("shed", self.shed as usize)
                .push("degraded", self.degraded as usize)
                .push("deadline_expired", self.deadline_expired as usize)
                .push("retries", self.retries as usize)
                .push("failed", self.failed as usize)
                .push("stalls", stalls as usize);
            if let Some(q) = &self.queue {
                f = f.push("queue_expired_drops",
                           q.expired_drops() as usize);
            }
            j = j.push("failures", f);
        }
        {
            // liveness/readiness: `live` is trivially true when this
            // snapshot could be produced; `ready` means the server is
            // admitting work (not draining) and — when a pool is
            // attached — at least one shard is UP to serve it
            let draining = self.draining.as_ref()
                .map(|d| d.load(Ordering::Relaxed))
                .unwrap_or(false);
            let some_shard_up = self.shards.is_empty()
                || self.shards.iter().any(|s| {
                    s.state.load(Ordering::Relaxed) == super::pool::SHARD_UP
                });
            j = j.push("health", Json::obj()
                .push("live", true)
                .push("ready", !draining && some_shard_up)
                .push("draining", draining));
        }
        if !self.shards.is_empty() {
            j = j.push("num_shards", self.shards.len())
                .push("compiles", compiles as usize)
                .push("executions", executions as usize);
            let shards: Vec<Json> = self.shards.iter().enumerate()
                .map(|(i, s)| Json::obj()
                    .push("shard", i)
                    .push("batches",
                          s.batches.load(Ordering::Relaxed) as usize)
                    .push("requests",
                          s.requests.load(Ordering::Relaxed) as usize)
                    .push("compiles",
                          s.compiles.load(Ordering::Relaxed) as usize)
                    .push("executions",
                          s.executions.load(Ordering::Relaxed) as usize)
                    .push("busy_ms",
                          s.busy_us.load(Ordering::Relaxed) as f64 / 1e3)
                    .push("utilization", s.utilization(uptime_s))
                    .push("state", s.state_name())
                    .push("panics",
                          s.panics.load(Ordering::Relaxed) as usize)
                    .push("quarantines",
                          s.quarantines.load(Ordering::Relaxed) as usize)
                    .push("generation",
                          s.generation.load(Ordering::Relaxed) as usize)
                    .push("stalls",
                          s.stalls.load(Ordering::Relaxed) as usize)
                    // absent until the shard serves its first batch
                    .push_opt("last_beat_age_ms",
                              s.beat_age_ms().map(|a| a as usize)))
                .collect();
            j = j.push("shards", shards);
        }
        if let Some(d) = &self.dispatch {
            j = j.push("dispatch", Json::obj()
                .push("warm_hits",
                      d.warm_hits.load(Ordering::Relaxed) as usize)
                .push("cold_routes",
                      d.cold_routes.load(Ordering::Relaxed) as usize));
        }
        if let Some(b) = &self.backend {
            j = j.push("backend", b.as_str());
            if let Some(v) = &self.variant {
                j = j.push("variant", v.as_str());
            }
            // the native-kernel counters are process-wide (shared by
            // every native backend in this process, like the compile
            // cache) — surfaced whenever a native server is attached
            if b == "native" {
                if let Some(qm) = &self.quant_mode {
                    j = j.push("quant_mode", qm.as_str());
                }
                if let Some(isa) = &self.kernel_isa {
                    j = j.push("kernel_isa", isa.as_str());
                }
                j = j.push("native_kernels",
                           crate::runtime::native::stats().snapshot());
            }
        }
        if let Some(q) = &self.queue {
            let depths: Vec<Json> = q.class_depths().into_iter()
                .map(|(k, n)| Json::obj()
                    .push("tier", k.tier)
                    .push("steps", k.steps)
                    // absent = the server default variant
                    .push_opt("variant", k.variant)
                    .push("depth", n))
                .collect();
            j = j.push("scheduler", q.policy_name())
                .push("queue_depth_per_class", depths);
        }
        // process-wide compile-cache effectiveness (shared across
        // every runtime in this process, not just this server's)
        let cc = crate::runtime::shared().stats().snapshot();
        j.push("compile_cache", Json::obj()
            .push("compile_attempts", cc.compile_attempts as usize)
            .push("singleflight_waits", cc.singleflight_waits as usize)
            .push("manifest_loads", cc.manifest_loads as usize)
            .push("manifest_hits", cc.manifest_hits as usize)
            .push("params_loads", cc.params_loads as usize)
            .push("params_hits", cc.params_hits as usize))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let mut m = ServerMetrics::new();
        m.requests = 3;
        m.record_batch(2, 8, 120.0);
        m.record_batch(1, 8, 70.0);
        m.record_completion(4.0);
        m.record_completion(6.0);
        m.record_completion(2.0);
        assert_eq!(m.batches, 2);
        assert_eq!(m.denoise_steps, 24);
        assert!((m.batch_size.mean() - 1.5).abs() < 1e-9);
        let s = m.snapshot();
        assert_eq!(s.get("completed").unwrap().as_usize(), Some(3));
        assert!((s.get("mean_queue_ms").unwrap().as_f64().unwrap() - 4.0)
            .abs() < 1e-9);
        // no pool attached: no shard rollup keys
        assert!(s.get("shards").is_none());
        assert!(s.get("dispatch").is_none());
        assert!(s.get("queue_depth_per_class").is_none());
        assert!(s.get("backend").is_none());
        // the process-wide compile-cache section is always present
        assert!(s.get("compile_cache").is_some());
    }

    #[test]
    fn backend_section_surfaces_name_and_native_counters() {
        let mut m = ServerMetrics::new();
        m.attach_backend("xla");
        let s = m.snapshot();
        assert_eq!(s.get("backend").unwrap().as_str(), Some("xla"));
        assert!(s.get("native_kernels").is_none(),
                "xla servers must not imply native kernel activity");
        m.attach_backend("native");
        m.attach_quant_mode("int8");
        m.attach_kernel_isa("avx2");
        m.attach_variant("sparge2");
        let s = m.snapshot();
        assert_eq!(s.get("backend").unwrap().as_str(), Some("native"));
        assert_eq!(s.get("quant_mode").unwrap().as_str(), Some("int8"));
        assert_eq!(s.get("kernel_isa").unwrap().as_str(), Some("avx2"));
        assert_eq!(s.get("variant").unwrap().as_str(), Some("sparge2"));
        let nk = s.get("native_kernels").expect("native counters");
        assert!(nk.get("isa").is_some(),
                "kernel counters carry the resolved ISA too");
        assert!(nk.get("intra_head_splits").is_some());
        assert!(nk.get("sparse_tiles").is_some());
        assert!(nk.get("denoise_forwards").is_some());
        // per-mode counters: real-int8 vs simulated heads
        assert!(nk.get("int8_heads").is_some());
        assert!(nk.get("sim_heads").is_some());
        // per-variant head counters (the variant shoot-out dimension)
        assert!(nk.get("sla2_heads").is_some());
        assert!(nk.get("sparge2_heads").is_some());
        assert!(nk.get("svg_ear_heads").is_some());
        assert!(nk.get("ear_compensated_blocks").is_some());
    }

    #[test]
    fn streaming_section_tracks_deliveries_and_cancels() {
        let mut m = ServerMetrics::new();
        m.streams = 3;
        m.record_stream_delivery(4, 12.0);
        m.record_stream_delivery(2, 8.0);
        m.record_cancelled_stream();
        let s = m.snapshot();
        let st = s.get("streaming").unwrap();
        assert_eq!(st.get("streams").unwrap().as_usize(), Some(3));
        assert_eq!(st.get("chunks_sent").unwrap().as_usize(), Some(6));
        assert_eq!(st.get("cancelled_streams").unwrap().as_usize(),
                   Some(1));
        assert!((st.get("mean_first_chunk_ms").unwrap().as_f64()
                     .unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_reports_scheduler_and_dispatch_sections() {
        use crate::coordinator::queue::{RequestQueue, SchedPolicy};
        use crate::coordinator::request::{Envelope, GenRequest};
        use std::time::Duration;

        let mut m = ServerMetrics::new();
        let d = Arc::new(DispatchStats::default());
        d.warm_hits.store(7, Ordering::Relaxed);
        d.cold_routes.store(3, Ordering::Relaxed);
        m.attach_dispatch(Arc::clone(&d));
        let q = Arc::new(RequestQueue::with_policy(
            8,
            SchedPolicy::ClassAware {
                bypass_threshold: Duration::from_millis(50),
            }));
        let (tx, _rx) = std::sync::mpsc::channel();
        q.push(Envelope::oneshot(GenRequest::new(1, 0, 1, 8, "s90"), tx))
            .unwrap();
        let (tx, _rx2) = std::sync::mpsc::channel();
        q.push(Envelope::oneshot(
            GenRequest::new(2, 0, 1, 8, "s90")
                .with_variant(Some("svg_ear".into())), tx)).unwrap();
        m.attach_queue(Arc::clone(&q));

        let s = m.snapshot();
        let disp = s.get("dispatch").unwrap();
        assert_eq!(disp.get("warm_hits").unwrap().as_usize(), Some(7));
        assert_eq!(disp.get("cold_routes").unwrap().as_usize(), Some(3));
        assert_eq!(s.get("scheduler").unwrap().as_str(), Some("class"));
        let depths =
            s.get("queue_depth_per_class").unwrap().as_arr().unwrap();
        // the variant override splits the scheduling class, and the
        // override-tagged row carries a "variant" field while the
        // default-variant row omits it
        assert_eq!(depths.len(), 2);
        for row in depths {
            assert_eq!(row.get("tier").unwrap().as_str(), Some("s90"));
            assert_eq!(row.get("depth").unwrap().as_usize(), Some(1));
            if let Some(v) = row.get("variant") {
                assert_eq!(v.as_str(), Some("svg_ear"));
            } // absent = the default-variant class
        }
        assert_eq!(depths.iter()
                       .filter(|r| r.get("variant").is_some()).count(),
                   1);
    }

    #[test]
    fn failures_section_rolls_up_overload_and_deadline_counters() {
        let mut m = ServerMetrics::new();
        m.record_shed();
        m.record_shed();
        m.record_degraded();
        m.record_deadline_expired();
        m.record_retry();
        m.record_retry();
        m.record_retry();
        m.record_failed();
        let s = m.snapshot();
        let f = s.get("failures").unwrap();
        assert_eq!(f.get("shed").unwrap().as_usize(), Some(2));
        assert_eq!(f.get("degraded").unwrap().as_usize(), Some(1));
        assert_eq!(f.get("deadline_expired").unwrap().as_usize(), Some(1));
        assert_eq!(f.get("retries").unwrap().as_usize(), Some(3));
        assert_eq!(f.get("failed").unwrap().as_usize(), Some(1));
        assert_eq!(f.get("stalls").unwrap().as_usize(), Some(0));
        // no queue attached: the dequeue-drop gauge is absent
        assert!(f.get("queue_expired_drops").is_none());
    }

    #[test]
    fn health_section_tracks_drain_and_shard_readiness() {
        let mut m = ServerMetrics::new();
        // nothing attached: live and ready (mock/gateway-only servers)
        let h = m.snapshot();
        let h = h.get("health").unwrap();
        assert!(h.get("live").unwrap().as_bool().unwrap());
        assert!(h.get("ready").unwrap().as_bool().unwrap());
        assert!(!h.get("draining").unwrap().as_bool().unwrap());

        let draining = Arc::new(AtomicBool::new(false));
        m.attach_health(Arc::clone(&draining));
        let shard = Arc::new(ShardStats::default());
        m.attach_shards(vec![Arc::clone(&shard)]);
        let h = m.snapshot();
        assert!(h.get("health").unwrap()
                 .get("ready").unwrap().as_bool().unwrap());

        // every shard down -> not ready, still live
        shard.state.store(super::super::pool::SHARD_QUARANTINED,
                          Ordering::Relaxed);
        let h = m.snapshot();
        assert!(!h.get("health").unwrap()
                  .get("ready").unwrap().as_bool().unwrap());
        assert!(h.get("health").unwrap()
                 .get("live").unwrap().as_bool().unwrap());

        // draining -> not ready even with a healthy shard
        shard.state.store(super::super::pool::SHARD_UP, Ordering::Relaxed);
        draining.store(true, Ordering::Relaxed);
        let h = m.snapshot();
        let h = h.get("health").unwrap();
        assert!(!h.get("ready").unwrap().as_bool().unwrap());
        assert!(h.get("draining").unwrap().as_bool().unwrap());
    }

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(ServerMetrics::new()));
        let m2 = Arc::clone(&m);
        // poison the mutex by panicking while holding it
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        }).join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        let mut g = ServerMetrics::lock(&m);
        g.record_shed();
        assert_eq!(g.shed, 1);
    }

    #[test]
    fn shard_rollup_sums_counters() {
        let mut m = ServerMetrics::new();
        let a = Arc::new(ShardStats::default());
        let b = Arc::new(ShardStats::default());
        a.compiles.store(2, Ordering::Relaxed);
        a.executions.store(10, Ordering::Relaxed);
        a.batches.store(4, Ordering::Relaxed);
        b.compiles.store(1, Ordering::Relaxed);
        b.executions.store(5, Ordering::Relaxed);
        m.attach_shards(vec![a, b]);
        assert_eq!(m.pool_counters(), (3, 15));
        let s = m.snapshot();
        assert_eq!(s.get("num_shards").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("compiles").unwrap().as_usize(), Some(3));
        assert_eq!(s.get("executions").unwrap().as_usize(), Some(15));
        let shards = s.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("batches").unwrap().as_usize(), Some(4));
        // health fields ride on every shard row
        assert_eq!(shards[0].get("state").unwrap().as_str(), Some("up"));
        assert_eq!(shards[0].get("panics").unwrap().as_usize(), Some(0));
        assert_eq!(shards[0].get("quarantines").unwrap().as_usize(),
                   Some(0));
        // liveness fields too: generation/stalls always, beat age only
        // once the shard has stamped a heartbeat
        assert_eq!(shards[0].get("generation").unwrap().as_usize(),
                   Some(0));
        assert_eq!(shards[0].get("stalls").unwrap().as_usize(), Some(0));
        assert!(shards[0].get("last_beat_age_ms").is_none());
    }

    #[test]
    fn shard_row_reports_beat_age_once_stamped() {
        let mut m = ServerMetrics::new();
        let s = Arc::new(ShardStats::default());
        s.beat();
        m.attach_shards(vec![s]);
        let snap = m.snapshot();
        let shards = snap.get("shards").unwrap().as_arr().unwrap();
        let age = shards[0].get("last_beat_age_ms").unwrap()
            .as_usize().unwrap();
        assert!(age < 60_000, "a just-stamped beat must read as fresh");
    }
}
