//! Server-level metrics: counters + latency distributions.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Online;

#[derive(Debug)]
pub struct ServerMetrics {
    started: Instant,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub denoise_steps: u64,
    pub queue_ms: Online,
    pub compute_ms: Online,
    pub batch_size: Online,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            started: Instant::now(),
            requests: 0,
            completed: 0,
            rejected: 0,
            batches: 0,
            denoise_steps: 0,
            queue_ms: Online::new(),
            compute_ms: Online::new(),
            batch_size: Online::new(),
        }
    }

    pub fn record_batch(&mut self, size: usize, steps: usize,
                        compute_ms: f64) {
        self.batches += 1;
        self.denoise_steps += (steps * size) as u64;
        self.batch_size.push(size as f64);
        self.compute_ms.push(compute_ms);
    }

    pub fn record_completion(&mut self, queue_ms: f64) {
        self.completed += 1;
        self.queue_ms.push(queue_ms);
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn snapshot(&self) -> Json {
        Json::obj()
            .push("requests", self.requests as usize)
            .push("completed", self.completed as usize)
            .push("rejected", self.rejected as usize)
            .push("batches", self.batches as usize)
            .push("denoise_steps", self.denoise_steps as usize)
            .push("mean_batch_size", self.batch_size.mean())
            .push("mean_queue_ms", self.queue_ms.mean())
            .push("mean_compute_ms", self.compute_ms.mean())
            .push("throughput_rps", self.throughput_rps())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let mut m = ServerMetrics::new();
        m.requests = 3;
        m.record_batch(2, 8, 120.0);
        m.record_batch(1, 8, 70.0);
        m.record_completion(4.0);
        m.record_completion(6.0);
        m.record_completion(2.0);
        assert_eq!(m.batches, 2);
        assert_eq!(m.denoise_steps, 24);
        assert!((m.batch_size.mean() - 1.5).abs() < 1e-9);
        let s = m.snapshot();
        assert_eq!(s.get("completed").unwrap().as_usize(), Some(3));
        assert!((s.get("mean_queue_ms").unwrap().as_f64().unwrap() - 4.0)
            .abs() < 1e-9);
    }
}
