//! Sharded engine pool: N worker shards, each owning its own compute
//! backend (for XLA the `Rc`-based client never crosses threads, so
//! every shard compiles and caches its own executables; the native
//! backend has nothing to compile but keeps the same one-engine-per-
//! thread shape), fed by a dispatcher that pops compatible batches off
//! the shared [`RequestQueue`] and routes each to an idle shard.
//!
//! Dispatch policy: the dispatcher claims a free shard FIRST, then
//! pops a batch.  While every shard is busy, requests stay in the
//! queue, which (a) keeps the batch window coalescing stragglers into
//! bigger batches under load and (b) keeps the dequeue stamp — and
//! with it `queue_ms` — truthful: queue wait ends exactly when a
//! shard is about to serve the batch.
//!
//! **Warm-shard affinity** — executables cannot cross shard threads
//! (`Rc`-based), so the first batch of a compatibility class on a
//! shard pays that shard's compile.  The dispatcher therefore tracks
//! which classes each shard has already served and, when several
//! shards are idle, routes a batch to one that is already warm for
//! its class.  Steady state: each class sticks to the shard(s) that
//! compiled it, so total compiles across the pool stay near the
//! number of distinct classes instead of `classes x shards`.  A cold
//! shard is still used the moment no warm one is idle — affinity is a
//! preference, never a stall.
//!
//! **Streaming reply path** — `serve_batch` drives
//! [`BatchProcessor::process_streaming`], so each request's clip is
//! delivered through its [`ReplySink`] the moment its sub-batch
//! finishes (chunked for streams, whole-clip for one-shot — both via
//! the [`stream`] machinery).  A batch whose every stream was
//! abandoned is skipped without compute, and per-invocation metrics
//! are recorded on the emission stride.
//!
//! **Failure containment and recovery** — a panicking processor fails
//! only its own batch (`catch_unwind`); requests already emitted keep
//! their clips.  Surviving requests of a panicked batch are REQUEUED
//! with jittered backoff up to [`PoolConfig::retry_budget`] times
//! before they terminally fail with a typed
//! [`ServeError::ShardFailed`].  Each shard tracks its own panic
//! history: [`PoolConfig::quarantine_failures`] panics inside
//! [`PoolConfig::quarantine_window`] quarantine the shard — it stops
//! announcing idle (so the dispatcher simply never routes to it),
//! rebuilds its backend via the factory, waits out
//! [`PoolConfig::quarantine_cooldown`], and re-admits itself.  Shard
//! states and flap counters surface in `ServerMetrics::snapshot`.
//!
//! **Liveness (watchdog + generation fencing)** — crashes are loud,
//! stalls are silent: a wedged backend execute pins its shard thread
//! forever and `catch_unwind` never fires.  Every shard therefore
//! stamps a monotonic progress heartbeat (at batch start and, via
//! [`BatchProcessor::set_beat`], at every denoise step / backend
//! execute), and every dispatched batch is registered in a shared
//! per-shard IN-FLIGHT SLOT that holds the not-yet-resolved reply
//! envelopes.  When [`PoolConfig::stall_threshold`] is non-zero a
//! supervisor thread polls the beats; a shard with an in-flight batch
//! whose beat has gone stale is declared STALLED: the supervisor bumps
//! the shard's generation token (fencing the wedged thread), steals
//! the unresolved envelopes out of the slot and fails them with the
//! retryable [`ServeError::ShardStalled`] (requeued within the normal
//! retry budget), ABANDONS the wedged thread (it is never joined), and
//! spawns a replacement worker through the same factory/rebuild path
//! quarantine uses.  A zombie thread that later wakes finds every
//! reply sink revoked — its emissions take nothing out of the slot —
//! and exits at the next loop edge instead of re-announcing idle, so
//! no reply is ever delivered twice and no shard slot is released
//! twice.
//!
//! With `num_shards = 1` the pool degenerates to the old single
//! engine-thread behavior: one consumer, strict FIFO-compatible
//! batching, identical per-seed clips.
//!
//! Shutdown: closing the queue makes the dispatcher exit after the
//! drain; dropping its per-shard channels then winds down every shard
//! after it finishes its in-flight batch, so no reply channel is ever
//! dropped with a request still pending.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Receiver, SendError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use once_cell::sync::Lazy;

use super::error::ServeError;
use super::metrics::ServerMetrics;
use super::queue::{ClassKey, QueueError, RequestQueue};
use super::request::{Envelope, GenRequest, ReplySink, RequestMetrics};
use super::stream;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// What a shard needs to turn a batch of COMPATIBLE requests into
/// clips.  [`crate::coordinator::Engine`] implements this over PJRT;
/// tests substitute a host-only mock so pool dispatch is testable
/// without artifacts.
pub trait BatchProcessor {
    /// Serve the batch; returns `(clip, metrics)` per request, input
    /// order preserved, exactly one entry per request.
    ///
    /// Contract on `metrics.batch_size`: results must be grouped into
    /// CONTIGUOUS runs of engine invocations, each run's entries
    /// carrying that invocation's size (`Engine::generate`'s chunk
    /// layout).  `serve_batch` strides over `batch_size` to record
    /// one `ServerMetrics::record_batch` per invocation — a processor
    /// that reports sizes not matching its grouping skews the
    /// batches/compute distributions.
    fn process(&mut self, reqs: &[GenRequest])
               -> Result<Vec<(Tensor, RequestMetrics)>>;

    /// Cumulative (compiles, executions) for the metrics rollup.
    fn counters(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Streaming variant: emit each request's
    /// `(index, Ok(clip) | Err(typed failure), metrics)` AS SOON AS IT
    /// IS READY instead of returning everything at the end.  An `Err`
    /// emission resolves that request terminally (e.g. a mid-flight
    /// `DeadlineExceeded`); its metrics still carry the invocation's
    /// `batch_size` so the per-invocation stride stays intact.
    /// Emission must preserve input order and the `batch_size`
    /// grouping contract of [`BatchProcessor::process`].  The default
    /// delegates to `process` and emits the whole batch at completion,
    /// so non-streaming processors (mocks, simple engines) need no
    /// changes; [`crate::coordinator::Engine`] overrides it to emit
    /// per sub-batch, which is what makes time-to-first-chunk shorter
    /// than time-to-last-chunk for split batches.
    fn process_streaming(
        &mut self, reqs: &[GenRequest],
        emit: &mut dyn FnMut(usize, Result<Tensor, ServeError>,
                             RequestMetrics))
        -> Result<()> {
        for (i, (clip, rm)) in self.process(reqs)?.into_iter().enumerate()
        {
            emit(i, Ok(clip), rm);
        }
        Ok(())
    }

    /// Install the shard's progress-heartbeat stamp.  Called once when
    /// the shard (or a watchdog replacement) comes up; long-running
    /// processors stamp it at every denoise step / backend execute so
    /// the watchdog can tell slow-but-alive from wedged.  The default
    /// ignores it — simple processors are covered by the batch-start
    /// beat the shard loop stamps.
    fn set_beat(&mut self, _beat: Arc<AtomicU64>) {}
}

/// Milliseconds since the process-wide pool epoch — the heartbeat
/// clock.  Monotonic (`Instant`-backed) and cheap enough to stamp per
/// denoise step.  Never returns 0, so a zero beat always means "never
/// stamped".  `pub(crate)` so processors handed a beat via
/// [`BatchProcessor::set_beat`] stamp it on the same clock.
pub(crate) fn now_ms() -> u64 {
    static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);
    (EPOCH.elapsed().as_millis() as u64).max(1)
}

///// Lock, RECOVERING from poison: the liveness structures are touched
/// from inside `catch_unwind` scopes, and all of them tolerate a
/// half-applied update (the slot's take-semantics make double
/// resolution impossible regardless).
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Shard health states (the quarantine state machine's nodes).
pub const SHARD_UP: u8 = 0;
pub const SHARD_QUARANTINED: u8 = 1;

/// Per-shard counters, updated lock-free by the owning shard and read
/// by [`ServerMetrics::snapshot`].
#[derive(Debug, Default)]
pub struct ShardStats {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    pub compiles: AtomicU64,
    pub executions: AtomicU64,
    /// cumulative wall time spent serving batches, in microseconds
    pub busy_us: AtomicU64,
    /// processor panics contained on this shard
    pub panics: AtomicU64,
    /// times this shard was quarantined (the flap counter)
    pub quarantines: AtomicU64,
    /// current health state ([`SHARD_UP`] | [`SHARD_QUARANTINED`])
    pub state: AtomicU8,
    /// generation (fencing) token: bumped by the watchdog when it
    /// abandons a wedged worker, so the zombie thread can recognize
    /// that a replacement owns the shard and exit instead of
    /// re-announcing idle
    pub generation: AtomicU64,
    /// last progress heartbeat, in [`now_ms`] time; 0 = never stamped.
    /// `Arc`ed so [`BatchProcessor::set_beat`] can hand the stamp to
    /// the engine's denoise loop without threading `ShardStats`
    /// through it.
    pub last_beat: Arc<AtomicU64>,
    /// watchdog-detected stalls on this shard (each one fenced the
    /// previous worker generation)
    pub stalls: AtomicU64,
}

impl ShardStats {
    /// Busy fraction of `uptime_s` (the per-shard utilization metric).
    pub fn utilization(&self, uptime_s: f64) -> f64 {
        (self.busy_us.load(Ordering::Relaxed) as f64 / 1e6)
            / uptime_s.max(1e-9)
    }

    pub fn state_name(&self) -> &'static str {
        match self.state.load(Ordering::Relaxed) {
            SHARD_QUARANTINED => "quarantined",
            _ => "up",
        }
    }

    /// Stamp a progress heartbeat now.
    pub fn beat(&self) {
        self.last_beat.store(now_ms(), Ordering::Relaxed);
    }

    /// Milliseconds since the last heartbeat; `None` when the shard
    /// has never stamped one (it has not served anything yet).
    pub fn beat_age_ms(&self) -> Option<u64> {
        match self.last_beat.load(Ordering::Relaxed) {
            0 => None,
            beat => Some(now_ms().saturating_sub(beat)),
        }
    }
}

/// Shared per-shard in-flight tracking: the reply envelopes of the
/// batch currently being served, each taken (under the lock) by
/// whoever resolves it — the serving thread's emissions, the batch's
/// failure handling, or the watchdog's steal.  Take-semantics make
/// exactly-once resolution structural: once an envelope is gone, a
/// zombie emission for the same index is a no-op.
#[derive(Debug, Default)]
struct InFlight {
    /// generation that registered the current batch
    gen: u64,
    /// one entry per request; `None` once resolved
    envs: Vec<Option<Envelope>>,
    /// true from batch registration until the batch is fully resolved
    /// (or stolen by the watchdog)
    active: bool,
}

/// Dispatcher-level routing counters, updated lock-free by the
/// dispatcher and read by [`ServerMetrics::snapshot`].  A *warm hit*
/// routed a batch to a shard the dispatcher has ROUTED that class to
/// before (so its compile was at least attempted); a *cold route*
/// sent it to a shard seeing the class for the first time.  Warmth is
/// route-based, not success-based — the dispatcher gets no per-batch
/// result feedback — so a class whose artifact persistently fails
/// stays pinned to one shard (bounded blast radius) and still counts
/// warm hits; cross-check `ShardStats::compiles` / `completed` when
/// these numbers look too good.
#[derive(Debug, Default)]
pub struct DispatchStats {
    pub warm_hits: AtomicU64,
    pub cold_routes: AtomicU64,
}

/// Failure-handling knobs for the pool (retry + quarantine).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// requests per dispatched batch
    pub max_batch: usize,
    /// straggler-coalescing window after the first arrival
    pub batch_window: Duration,
    /// how many times a shard-panic survivor is requeued before it
    /// terminally fails (0 = fail on first panic)
    pub retry_budget: u32,
    /// base retry backoff; attempt `n` waits `base * 2^(n-1)` plus a
    /// deterministic jitter in `[0, base/2]`, capped at 2 s
    pub retry_backoff_ms: u64,
    /// panics within `quarantine_window` that trip a quarantine
    /// (0 disables quarantine entirely)
    pub quarantine_failures: u32,
    /// sliding window for counting a shard's recent panics
    pub quarantine_window: Duration,
    /// how long a quarantined shard sits out before re-admission
    pub quarantine_cooldown: Duration,
    /// heartbeat staleness past which the watchdog declares a busy
    /// shard STALLED and fences its worker.  `ZERO` (the default)
    /// disables the watchdog entirely — it must comfortably exceed the
    /// slowest legitimate single step (including a first-time compile)
    /// or healthy shards get shot.
    pub stall_threshold: Duration,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            max_batch: 8,
            batch_window: Duration::ZERO,
            retry_budget: 2,
            retry_backoff_ms: 20,
            quarantine_failures: 3,
            quarantine_window: Duration::from_secs(10),
            quarantine_cooldown: Duration::from_millis(250),
            stall_threshold: Duration::ZERO,
        }
    }
}

/// Everything a shard worker needs to run [`shard_loop`], bundled so
/// the original thread, watchdog replacements, and the watchdog itself
/// share one signature.  `Clone` hands each its own set of `Arc`s.
#[derive(Clone)]
struct ShardCtx {
    shard: usize,
    /// shared (not owned) so a watchdog replacement can take over
    /// consumption after the previous generation is abandoned
    batch_rx: Arc<Mutex<Receiver<Vec<Envelope>>>>,
    idle_tx: Sender<usize>,
    queue: Arc<RequestQueue>,
    cfg: PoolConfig,
    metrics: Arc<Mutex<ServerMetrics>>,
    stats: Arc<ShardStats>,
    inflight: Arc<Mutex<InFlight>>,
}

/// True when `my_gen` is no longer the shard's live generation: the
/// watchdog fenced this worker and a replacement owns the shard, so
/// the caller must exit without announcing idle or touching counters.
fn fenced(ctx: &ShardCtx, my_gen: u64) -> bool {
    ctx.stats.generation.load(Ordering::Relaxed) != my_gen
}

/// The running pool: shard worker threads + the dispatcher.
///
/// [`EnginePool::join`] (and `Drop`) closes the queue itself before
/// joining, so dropping a pool can never hang on an open queue; the
/// dispatcher exits once the queue is closed and drained.
pub struct EnginePool {
    queue: Arc<RequestQueue>,
    dispatcher: Option<JoinHandle<()>>,
    /// one slot per shard; the watchdog swaps in a replacement's
    /// handle when it abandons a wedged worker, `None` once joined
    handles: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    watchdog: Option<JoinHandle<()>>,
    watchdog_stop: Arc<AtomicBool>,
    stats: Vec<Arc<ShardStats>>,
    dispatch: Arc<DispatchStats>,
    inflights: Vec<Arc<Mutex<InFlight>>>,
    stall_threshold: Duration,
}

impl EnginePool {
    /// [`EnginePool::start_with_config`] with default failure knobs —
    /// the pre-existing entry point most callers use.
    pub fn start_with<P, F>(num_shards: usize, queue: Arc<RequestQueue>,
                            metrics: Arc<Mutex<ServerMetrics>>,
                            max_batch: usize, batch_window: Duration,
                            factory: F) -> Result<EnginePool>
    where
        P: BatchProcessor + 'static,
        F: Fn(usize) -> Result<P> + Clone + Send + 'static,
    {
        let cfg = PoolConfig { max_batch, batch_window,
                               ..PoolConfig::default() };
        Self::start_with_config(num_shards, queue, metrics, cfg, factory)
    }

    /// Spawn `num_shards` workers, each building its own processor via
    /// `factory(shard_id)` ON ITS OWN THREAD (so `Rc`-based runtimes
    /// never migrate), then start the dispatcher.  Blocks until every
    /// shard reports ready so callers get load errors synchronously;
    /// on any failure the already-started shards are wound down before
    /// the error is returned.  The factory is retained per shard for
    /// quarantine rebuilds.
    pub fn start_with_config<P, F>(num_shards: usize,
                                   queue: Arc<RequestQueue>,
                                   metrics: Arc<Mutex<ServerMetrics>>,
                                   cfg: PoolConfig, factory: F)
                                   -> Result<EnginePool>
    where
        P: BatchProcessor + 'static,
        F: Fn(usize) -> Result<P> + Clone + Send + 'static,
    {
        assert!(num_shards >= 1, "pool needs at least one shard");
        let (idle_tx, idle_rx) = channel::<usize>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let mut batch_txs: Vec<Sender<Vec<Envelope>>> = Vec::new();
        let mut shards = Vec::new();
        let mut stats = Vec::new();
        let mut inflights = Vec::new();
        let mut ctxs: Vec<ShardCtx> = Vec::new();
        for shard in 0..num_shards {
            let (batch_tx, batch_rx) = channel::<Vec<Envelope>>();
            batch_txs.push(batch_tx);
            let st = Arc::new(ShardStats::default());
            stats.push(Arc::clone(&st));
            let inf = Arc::new(Mutex::new(InFlight::default()));
            inflights.push(Arc::clone(&inf));
            let ctx = ShardCtx {
                shard,
                batch_rx: Arc::new(Mutex::new(batch_rx)),
                idle_tx: idle_tx.clone(),
                queue: Arc::clone(&queue),
                cfg: cfg.clone(),
                metrics: Arc::clone(&metrics),
                stats: st,
                inflight: inf,
            };
            ctxs.push(ctx.clone());
            let factory = factory.clone();
            let ready_tx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sla2-shard-{shard}"))
                .spawn(move || {
                    let proc = match factory(ctx.shard) {
                        Ok(p) => {
                            let _ = ready_tx.send(Ok(()));
                            p
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    // release our ready sender so a sibling shard that
                    // dies before reporting surfaces as a disconnect,
                    // not a startup hang
                    drop(ready_tx);
                    crate::info!("shard {} up", ctx.shard);
                    shard_loop(&ctx, proc, &factory, 0);
                    crate::info!("shard {} shut down", ctx.shard);
                })?;
            shards.push(handle);
        }
        drop(idle_tx);
        drop(ready_tx);

        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..num_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err = first_err.or_else(|| Some(anyhow::anyhow!(
                        "a shard exited before reporting ready")));
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            // wind down the shards that did come up: dropping their
            // batch channels (and the idle receiver) unblocks them
            drop(batch_txs);
            drop(idle_rx);
            for h in shards {
                let _ = h.join();
            }
            return Err(e).context("engine pool startup");
        }

        let dispatch = Arc::new(DispatchStats::default());
        {
            let mut m = ServerMetrics::lock(&metrics);
            m.attach_shards(stats.clone());
            m.attach_dispatch(Arc::clone(&dispatch));
        }
        let q = Arc::clone(&queue);
        let d = Arc::clone(&dispatch);
        let max_batch = cfg.max_batch;
        let batch_window = cfg.batch_window;
        let dispatcher = std::thread::Builder::new()
            .name("sla2-dispatch".into())
            .spawn(move || {
                dispatch_loop(&q, idle_rx, batch_txs, max_batch,
                              batch_window, &d);
            })?;

        let handles = Arc::new(Mutex::new(
            shards.into_iter().map(Some).collect::<Vec<_>>()));
        let watchdog_stop = Arc::new(AtomicBool::new(false));
        let stall_threshold = cfg.stall_threshold;
        let watchdog = if stall_threshold > Duration::ZERO {
            let factory = factory.clone();
            let handles = Arc::clone(&handles);
            let stop = Arc::clone(&watchdog_stop);
            Some(std::thread::Builder::new()
                .name("sla2-watchdog".into())
                .spawn(move || {
                    watchdog_loop(&ctxs, &factory, &handles, &stop,
                                  stall_threshold);
                })?)
        } else {
            None
        };
        Ok(EnginePool { queue, dispatcher: Some(dispatcher), handles,
                        watchdog, watchdog_stop, stats, dispatch,
                        inflights, stall_threshold })
    }

    pub fn num_shards(&self) -> usize {
        self.stats.len()
    }

    pub fn stats(&self) -> &[Arc<ShardStats>] {
        &self.stats
    }

    pub fn dispatch_stats(&self) -> &DispatchStats {
        &self.dispatch
    }

    /// Number of shards currently serving a batch — the drain path's
    /// "work still in flight" signal (queued work is counted by the
    /// queue itself).
    pub fn in_flight(&self) -> usize {
        self.inflights.iter()
            .filter(|inf| lock_recover(inf).active)
            .count()
    }

    /// True when a shard looks permanently stuck: an in-flight batch
    /// whose heartbeat is stale past the stall threshold.  Only
    /// meaningful with the watchdog enabled; without one we have no
    /// staleness definition and optimistically report healthy.
    fn wedged(&self, shard: usize) -> bool {
        if self.stall_threshold.is_zero() {
            return false;
        }
        let active = lock_recover(&self.inflights[shard]).active;
        active
            && match self.stats[shard].beat_age_ms() {
                Some(age) => age > self.stall_threshold.as_millis() as u64,
                None => false,
            }
    }

    /// Graceful shutdown: close the queue (idempotent), then join the
    /// dispatcher and every shard — each finishes its in-flight batch
    /// and already-queued requests are drained, not dropped.  The
    /// watchdog keeps running until the dispatcher is down (so a shard
    /// that wedges during the drain still gets replaced and the drain
    /// completes); a shard still wedged after that is ABANDONED, never
    /// joined — joining a thread stuck in a hung backend call would
    /// hang shutdown itself.
    pub fn join(&mut self) {
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        self.watchdog_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.watchdog.take() {
            let _ = h.join();
        }
        let handles: Vec<Option<JoinHandle<()>>> = {
            let mut hs = lock_recover(&self.handles);
            hs.iter_mut().map(|h| h.take()).collect()
        };
        for (shard, h) in handles.into_iter().enumerate() {
            let Some(h) = h else { continue };
            if self.wedged(shard) {
                crate::warn_!("shard {shard} still wedged at shutdown; \
                               abandoning its thread");
                drop(h);
                continue;
            }
            let _ = h.join();
        }
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.join();
    }
}

/// Dispatcher: claim an idle shard, pop a compatible batch, hand it
/// to a shard — preferring one already warm for the batch's class.
/// Exits when the queue closes (graceful shutdown) or every shard has
/// died (each remaining batch is failed, never dropped).
fn dispatch_loop(queue: &RequestQueue, idle_rx: Receiver<usize>,
                 batch_txs: Vec<Sender<Vec<Envelope>>>, max_batch: usize,
                 batch_window: Duration, stats: &DispatchStats) {
    let poll = Duration::from_millis(100);
    // idle tokens currently held (a shard appears at most once: it
    // only announces idle after receiving its previous batch)
    let mut idle: Vec<usize> = Vec::new();
    // classes each shard has served — and therefore compiled
    let mut warm: Vec<HashSet<ClassKey>> =
        (0..batch_txs.len()).map(|_| HashSet::new()).collect();
    loop {
        if idle.is_empty() {
            match idle_rx.recv() {
                Ok(i) => idle.push(i),
                Err(_) => break, // every shard is gone
            }
        }
        let mut batch = match queue.pop_batch(max_batch, poll, batch_window)
        {
            None => break,                       // closed + drained
            Some(b) if b.is_empty() => continue, // poll timeout
            Some(b) => b,
        };
        // drain idle announcements AFTER the (possibly long) pop so
        // the affinity pick sees every shard that went idle while we
        // blocked — draining before it would cold-route any class
        // whose warm shard finished during the wait
        loop {
            match idle_rx.try_recv() {
                Ok(i) => idle.push(i),
                Err(TryRecvError::Empty)
                | Err(TryRecvError::Disconnected) => break,
            }
        }
        let key = ClassKey::of(&batch[0].request);
        loop {
            // warm idle shard if any, else any idle shard, else block
            let shard = match idle.iter()
                .position(|&s| warm[s].contains(&key))
                .or(if idle.is_empty() { None } else { Some(0) })
            {
                Some(pos) => idle.swap_remove(pos),
                None => match idle_rx.recv() {
                    Ok(i) => i,
                    Err(_) => {
                        fail_batch(batch, ServeError::shard_fatal(
                            "engine pool has no live shards"));
                        return;
                    }
                },
            };
            match batch_txs[shard].send(batch) {
                Ok(()) => {
                    if warm[shard].insert(key.clone()) {
                        stats.cold_routes.fetch_add(1, Ordering::Relaxed);
                    } else {
                        stats.warm_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    break;
                }
                // the shard died between announcing idle and
                // receiving: take the batch back, forget its warm
                // set, try the next one
                Err(SendError(b)) => {
                    warm[shard].clear();
                    batch = b;
                }
            }
        }
    }
    // dropping batch_txs here winds down the shards
}

/// One shard: announce idle, serve the next batch, repeat — plus the
/// quarantine state machine.  `quarantine_failures` panics inside
/// `quarantine_window` flip the shard to QUARANTINED: it withholds its
/// idle announcement (so the dispatcher routes around it without any
/// dispatcher-side state), rebuilds its processor through the factory,
/// sleeps out the cooldown, and re-admits itself as UP.
///
/// `my_gen` is the fencing token this worker was born with (0 for the
/// original thread, the bumped generation for watchdog replacements).
/// Every loop edge checks it against the shard's live generation: a
/// mismatch means the watchdog declared this worker wedged and handed
/// the shard to a replacement — the zombie exits WITHOUT announcing
/// idle (the replacement owns that) and without touching counters.
fn shard_loop<P, F>(ctx: &ShardCtx, mut proc: P, factory: &F, my_gen: u64)
where
    P: BatchProcessor + 'static,
    F: Fn(usize) -> Result<P>,
{
    proc.set_beat(Arc::clone(&ctx.stats.last_beat));
    let mut recent_panics: Vec<Instant> = Vec::new();
    loop {
        if fenced(ctx, my_gen) {
            return;
        }
        if ctx.idle_tx.send(ctx.shard).is_err() {
            break; // dispatcher gone
        }
        // the receiver is shared with (potential) replacement workers;
        // hold its lock only for the recv — the slot machinery, not
        // this lock, is what serializes generations
        let batch = {
            let rx = lock_recover(&ctx.batch_rx);
            match rx.recv() {
                Ok(b) => b,
                Err(_) => break, // dispatcher gone
            }
        };
        let panicked = serve_batch(ctx, &mut proc, my_gen, batch);
        if fenced(ctx, my_gen) {
            return; // stolen mid-serve: a replacement owns the shard
        }
        let (compiles, executions) = proc.counters();
        ctx.stats.compiles.store(compiles, Ordering::Relaxed);
        ctx.stats.executions.store(executions, Ordering::Relaxed);
        if !panicked {
            continue;
        }
        ctx.stats.panics.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        recent_panics.push(now);
        recent_panics.retain(|t| now.duration_since(*t)
                             <= ctx.cfg.quarantine_window);
        if ctx.cfg.quarantine_failures == 0
            || recent_panics.len() < ctx.cfg.quarantine_failures as usize {
            continue;
        }
        // quarantine: this shard stops announcing idle, so the
        // dispatcher simply never routes to it while we recover
        crate::warn_!("shard {} quarantined after {} panics in \
                       {:?}; rebuilding backend",
                      ctx.shard, recent_panics.len(),
                      ctx.cfg.quarantine_window);
        ctx.stats.quarantines.fetch_add(1, Ordering::Relaxed);
        ctx.stats.state.store(SHARD_QUARANTINED, Ordering::Relaxed);
        recent_panics.clear();
        std::thread::sleep(ctx.cfg.quarantine_cooldown);
        match rebuild_processor(ctx, factory) {
            Some(p) => proc = p,
            None => return, // shutdown mid-rebuild
        }
        proc.set_beat(Arc::clone(&ctx.stats.last_beat));
        ctx.stats.state.store(SHARD_UP, Ordering::Relaxed);
        crate::info!("shard {} re-admitted after quarantine", ctx.shard);
    }
}

/// Rebuild a shard's processor through its factory, retrying with
/// cooldown sleeps until it succeeds; `None` means shutdown was
/// detected (dead dispatcher → disconnected batch channel) and the
/// caller should exit instead.
fn rebuild_processor<P, F>(ctx: &ShardCtx, factory: &F) -> Option<P>
where
    P: BatchProcessor + 'static,
    F: Fn(usize) -> Result<P>,
{
    loop {
        match factory(ctx.shard) {
            Ok(p) => return Some(p),
            Err(e) => {
                crate::warn_!("shard {} rebuild failed: {e:#}; \
                               retrying after cooldown", ctx.shard);
                let disconnected = matches!(
                    lock_recover(&ctx.batch_rx).try_recv(),
                    Err(TryRecvError::Disconnected));
                if disconnected {
                    return None;
                }
                std::thread::sleep(ctx.cfg.quarantine_cooldown);
            }
        }
    }
}

/// Serve one dispatched batch.  Returns true when the processor
/// PANICKED (the shard's quarantine accounting input); orderly errors
/// return false.
///
/// The reply envelopes live in the shard's shared in-flight slot for
/// the whole batch: every resolution — a clip or typed-error emission,
/// end-of-batch failure handling, or the watchdog's steal — TAKES the
/// envelope out under the slot lock and delivers outside it, so each
/// request resolves exactly once no matter which thread gets there
/// first.
fn serve_batch<P: BatchProcessor>(ctx: &ShardCtx, proc: &mut P,
                                  my_gen: u64, batch: Vec<Envelope>)
                                  -> bool {
    let metrics = &*ctx.metrics;
    // cancel fast path: a batch whose every consumer is gone is pure
    // dead work — release the shard slot without touching the engine
    if batch.iter().all(|e| e.reply.is_cancelled()) {
        let mut m = ServerMetrics::lock(metrics);
        for _ in &batch {
            m.record_cancelled_stream();
        }
        return false; // dropping the envelopes ends the streams
    }
    let reqs: Vec<GenRequest> =
        batch.iter().map(|e| e.request.clone()).collect();
    let n = batch.len();
    // register the batch in the slot and stamp the batch-start beat in
    // ONE critical section, so the watchdog can never observe an
    // active batch without a fresh heartbeat behind it
    {
        let mut inf = lock_recover(&ctx.inflight);
        if fenced(ctx, my_gen) {
            // fenced between recv and registration — a replacement
            // owns the shard; treat the whole batch as stalled work
            // (retryable) rather than serving under a dead generation
            drop(inf);
            resolve_failed(ctx, batch,
                           &ServeError::shard_stalled(
                               "batch landed on a fenced shard worker"));
            return false;
        }
        inf.gen = my_gen;
        inf.envs = batch.into_iter().map(Some).collect();
        inf.active = true;
        ctx.stats.beat();
    }
    let t0 = Instant::now();
    // delivery bookkeeping lives OUTSIDE the catch_unwind closure so a
    // mid-batch panic still knows which requests were already served
    let mut delivered = vec![false; n];
    let mut served = 0usize;
    // a panicking processor must not take the whole shard down: turn
    // the panic into per-request errors and keep serving.  Requests
    // emitted before the panic keep their (already delivered) clips.
    let outcome = {
        let delivered = &mut delivered;
        let served = &mut served;
        let mut emitted = 0usize;
        let mut next_invocation_start = 0usize;
        catch_unwind(AssertUnwindSafe(move || {
            let mut emit = |i: usize,
                            result: Result<Tensor, ServeError>,
                            rm: RequestMetrics| {
                if i >= n || delivered[i] {
                    crate::warn_!("processor emitted bogus index {i} for \
                                   a batch of {n}");
                    return;
                }
                let Some(env) = take_env(ctx, my_gen, i) else {
                    // the watchdog stole this envelope (and already
                    // failed it): the emission is a fenced no-op
                    return;
                };
                delivered[i] = true;
                *served += 1;
                // one record per ENGINE INVOCATION: the batch-size
                // planner may split a dispatched batch into
                // sub-batches, each with its own compute_ms —
                // emissions within a sub-batch are contiguous and
                // share batch_size, so stride over them.  Error
                // emissions advance the stride but only successful
                // invocations count as served batches.
                if emitted == next_invocation_start {
                    if result.is_ok() {
                        ServerMetrics::lock(metrics).record_batch(
                            rm.batch_size, rm.steps, rm.compute_ms);
                    }
                    next_invocation_start += rm.batch_size.max(1);
                }
                emitted += 1;
                match result {
                    Ok(clip) => deliver(&env, clip, rm, metrics),
                    Err(err) => deliver_error(&env, err, metrics),
                }
            };
            proc.process_streaming(&reqs, &mut emit)
        }))
    };
    let elapsed = t0.elapsed();
    ctx.stats.busy_us.fetch_add(elapsed.as_micros() as u64,
                                Ordering::Relaxed);
    // empty when every request was emitted — or when the watchdog
    // fenced us and owns whatever was left
    let leftover = take_remaining(ctx, my_gen);
    let (failure, panicked) = match outcome {
        Ok(Ok(())) => {
            if leftover.is_empty() {
                (None, false)
            } else {
                (Some(ServeError::shard_fatal(
                    "processor finished without emitting every request")),
                 false)
            }
        }
        Ok(Err(e)) => {
            crate::warn_!("batch failed: {e:#}");
            // an orderly error is deterministic: the same input would
            // fail the same way, so survivors are NOT requeued
            (Some(ServeError::shard_fatal(format!("{e:#}"))), false)
        }
        Err(_) => {
            crate::warn_!("batch processor panicked");
            (Some(ServeError::shard_transient("batch processor panicked")),
             true)
        }
    };
    if served > 0 {
        ctx.stats.batches.fetch_add(1, Ordering::Relaxed);
        ctx.stats.requests.fetch_add(served as u64, Ordering::Relaxed);
    }
    if let Some(err) = failure {
        resolve_failed(ctx, leftover, &err);
    }
    panicked
}

/// Take request `i`'s envelope out of the in-flight slot, if
/// generation `my_gen` still owns it.  `None` means it was already
/// resolved or the watchdog stole it — either way the caller's
/// delivery must become a no-op.
fn take_env(ctx: &ShardCtx, my_gen: u64, i: usize) -> Option<Envelope> {
    let mut inf = lock_recover(&ctx.inflight);
    if inf.gen != my_gen || fenced(ctx, my_gen) {
        return None;
    }
    inf.envs.get_mut(i).and_then(|e| e.take())
}

/// End-of-batch cleanup for generation `my_gen`: take every envelope
/// still unresolved and deactivate the slot.  Returns empty when the
/// watchdog fenced this generation — it stole the leftovers and owns
/// their resolution.
fn take_remaining(ctx: &ShardCtx, my_gen: u64) -> Vec<Envelope> {
    let mut inf = lock_recover(&ctx.inflight);
    if inf.gen != my_gen || fenced(ctx, my_gen) {
        return Vec::new();
    }
    inf.active = false;
    inf.envs.iter_mut().filter_map(|e| e.take()).collect()
}

/// Resolve a set of undelivered envelopes with `err`: consumers that
/// already cancelled are recorded as cancellations (never requeued —
/// nobody is listening), retryable failures re-enter the queue within
/// the retry budget, and everything else fails terminally.
fn resolve_failed(ctx: &ShardCtx, envs: Vec<Envelope>, err: &ServeError) {
    let retryable = err.retryable();
    for env in envs {
        if env.reply.is_cancelled() {
            ServerMetrics::lock(&ctx.metrics).record_cancelled_stream();
        } else if retryable {
            retry_or_fail(env, &ctx.queue, &ctx.cfg, &ctx.metrics, err);
        } else {
            ServerMetrics::lock(&ctx.metrics).record_failed();
            env.reply.fail(err.clone());
        }
    }
}

/// The pool supervisor: polls every shard's heartbeat and, when a
/// shard with an in-flight batch stops beating past `threshold`,
/// fences the wedged worker, fails its stolen work as retryable
/// [`ServeError::ShardStalled`], and brings up a replacement through
/// the factory.  The wedged thread is abandoned, never joined.
fn watchdog_loop<P, F>(ctxs: &[ShardCtx], factory: &F,
                       handles: &Mutex<Vec<Option<JoinHandle<()>>>>,
                       stop: &AtomicBool, threshold: Duration)
where
    P: BatchProcessor + 'static,
    F: Fn(usize) -> Result<P> + Clone + Send + 'static,
{
    let poll = (threshold / 4).clamp(Duration::from_millis(10),
                                     Duration::from_millis(250));
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(poll);
        for ctx in ctxs {
            let Some(stolen) = steal_if_stalled(ctx, threshold) else {
                continue;
            };
            let new_gen = ctx.stats.generation.load(Ordering::Relaxed);
            crate::warn_!("watchdog: shard {} stalled (no beat for over \
                           {} ms); fencing generation {} and spawning \
                           replacement",
                          ctx.shard, threshold.as_millis(), new_gen - 1);
            resolve_failed(ctx, stolen, &ServeError::shard_stalled(
                format!("no progress beat for over {} ms",
                        threshold.as_millis())));
            let replacement =
                spawn_replacement(ctx.clone(), factory.clone(), new_gen);
            // swapping the handle out drops the wedged thread's handle:
            // the zombie is detached and reaped at process exit
            lock_recover(handles)[ctx.shard] = replacement;
        }
    }
}

/// The trip condition and the fence, in ONE critical section on the
/// slot lock: if the shard has an in-flight batch of the current
/// generation whose heartbeat has gone stale past `threshold`, bump
/// the generation (revoking the wedged worker — any later emission or
/// cleanup of its generation no-ops), steal the unresolved envelopes,
/// and deactivate the slot.  `None` = healthy.
fn steal_if_stalled(ctx: &ShardCtx, threshold: Duration)
                    -> Option<Vec<Envelope>> {
    let mut inf = lock_recover(&ctx.inflight);
    let cur = ctx.stats.generation.load(Ordering::Relaxed);
    if !inf.active || inf.gen != cur {
        return None;
    }
    let stale = match ctx.stats.beat_age_ms() {
        Some(age) => age > threshold.as_millis() as u64,
        None => false,
    };
    if !stale {
        return None;
    }
    ctx.stats.generation.store(cur + 1, Ordering::Relaxed);
    ctx.stats.stalls.fetch_add(1, Ordering::Relaxed);
    ctx.stats.quarantines.fetch_add(1, Ordering::Relaxed);
    ctx.stats.state.store(SHARD_QUARANTINED, Ordering::Relaxed);
    inf.active = false;
    Some(inf.envs.iter_mut().filter_map(|e| e.take()).collect())
}

/// Bring up a replacement worker for a fenced shard: cooldown, rebuild
/// through the factory (retrying like the quarantine path), then run
/// the normal shard loop under the new generation.  The replacement is
/// tracked in the pool's handle table so shutdown joins it like any
/// other shard.
fn spawn_replacement<P, F>(ctx: ShardCtx, factory: F, my_gen: u64)
                           -> Option<JoinHandle<()>>
where
    P: BatchProcessor + 'static,
    F: Fn(usize) -> Result<P> + Clone + Send + 'static,
{
    let shard = ctx.shard;
    std::thread::Builder::new()
        .name(format!("sla2-shard-{shard}-g{my_gen}"))
        .spawn(move || {
            std::thread::sleep(ctx.cfg.quarantine_cooldown);
            let proc = match rebuild_processor(&ctx, &factory) {
                Some(p) => p,
                None => return, // shutdown mid-rebuild
            };
            ctx.stats.beat();
            ctx.stats.state.store(SHARD_UP, Ordering::Relaxed);
            crate::info!("shard {} replacement up (generation {})",
                         ctx.shard, my_gen);
            shard_loop(&ctx, proc, &factory, my_gen);
            crate::info!("shard {} generation {} shut down",
                         ctx.shard, my_gen);
        })
        .map_err(|e| {
            crate::warn_!("shard {shard} replacement thread failed to \
                           spawn: {e}");
        })
        .ok()
}

/// A retryable-failure survivor (shard panic or watchdog stall):
/// requeue it with jittered backoff if budget remains, else fail it
/// terminally with a typed error matching `cause`.  The backoff sleep
/// happens on a short-lived helper thread so the shard itself is never
/// blocked.
fn retry_or_fail(mut env: Envelope, queue: &Arc<RequestQueue>,
                 cfg: &PoolConfig, metrics: &Mutex<ServerMetrics>,
                 cause: &ServeError) {
    if env.request.retries >= cfg.retry_budget {
        let attempts = env.request.retries + 1;
        ServerMetrics::lock(metrics).record_failed();
        env.reply.fail(match cause {
            // keep the stall typed all the way to the terminal error
            // so clients can tell "your shard kept wedging" from
            // "your batch kept crashing"
            ServeError::ShardStalled { .. } => ServeError::ShardStalled {
                reason: format!("shard stalled; retry budget exhausted \
                                 after {attempts} attempts"),
            },
            _ => ServeError::ShardFailed {
                retryable: false,
                reason: format!("batch processor panicked; retry budget \
                                 exhausted after {attempts} attempts"),
            },
        });
        return;
    }
    env.request.retries += 1;
    env.request.dequeued_at = None;
    ServerMetrics::lock(metrics).record_retry();
    let backoff = retry_backoff(cfg.retry_backoff_ms, env.request.id,
                                env.request.retries);
    let queue = Arc::clone(queue);
    let spawned = std::thread::Builder::new()
        .name("sla2-retry".into())
        .spawn(move || {
            std::thread::sleep(backoff);
            if env.request.expired(Instant::now()) {
                env.reply.fail(ServeError::DeadlineExceeded);
                return;
            }
            if let Err((env, qe)) = queue.push_or_return(env) {
                let err = match qe {
                    QueueError::Closed => ServeError::ShuttingDown,
                    QueueError::Full(_) => ServeError::Overloaded {
                        retry_after_ms: backoff.as_millis() as u64,
                    },
                };
                env.reply.fail(err);
            }
        });
    if let Err(e) = spawned {
        crate::warn_!("retry helper thread failed to spawn: {e}");
        // the envelope moved into the closure that never ran — the
        // failed Builder::spawn returns only the io::Error, so the
        // reply channel closes and the client observes a drop.  This
        // path needs the system to be out of threads, at which point
        // serving is lost anyway.
    }
}

/// Deterministic jittered exponential backoff: `base * 2^(attempt-1)`
/// plus a `[0, base/2]` jitter seeded from (request id, attempt), all
/// capped at 2 s.  Determinism keeps the chaos suite replayable.
fn retry_backoff(base_ms: u64, id: u64, attempt: u32) -> Duration {
    let base = base_ms.max(1);
    let exp = base.saturating_mul(1u64 << (attempt.min(6) - 1).min(63));
    let jitter = Pcg32::new(id, attempt as u64).below(
        (base / 2 + 1) as u32) as u64;
    Duration::from_millis((exp + jitter).min(2_000))
}

/// Deliver one finished clip through its reply sink.  The one-shot
/// path is a thin wrapper over the stream machinery: the clip is run
/// through [`stream::chunk_clip`] / [`stream::assemble_response`]
/// (collapsed to a single whole-clip chunk) so both sinks share the
/// same invariants and failure modes.
fn deliver(env: &Envelope, clip: Tensor, rm: RequestMetrics,
           metrics: &Mutex<ServerMetrics>) {
    let queue_ms = rm.queue_ms;
    match &env.reply {
        ReplySink::Oneshot(tx) => {
            let resp = stream::chunk_clip(env.request.id, clip, &rm, 0)
                .and_then(|chunks| {
                    stream::assemble_response(env.request.id, chunks)
                });
            match resp {
                Ok(r) => {
                    // record BEFORE replying so a reader who saw the
                    // reply sees the records (the pre-streaming
                    // contract); chunk streams record post-delivery
                    // instead, since chunk/cancel counts are only
                    // known once delivery finishes
                    ServerMetrics::lock(metrics)
                        .record_completion(queue_ms);
                    let _ = tx.send(Ok(r));
                }
                Err(e) => {
                    let _ = tx.send(Err(ServeError::shard_fatal(
                        format!("{e:#}"))));
                }
            }
        }
        ReplySink::Stream(cs) => {
            // first-chunk latency is clocked at delivery start: the
            // send of chunk 0 is the next instruction
            let first_chunk_ms = env.request.submitted_at.elapsed()
                .as_secs_f64() * 1e3;
            match cs.send_clip(clip, &rm) {
                stream::SendOutcome::Delivered(chunks) => {
                    let mut m = ServerMetrics::lock(metrics);
                    m.record_stream_delivery(chunks, first_chunk_ms);
                    m.record_completion(queue_ms);
                }
                stream::SendOutcome::Cancelled => {
                    ServerMetrics::lock(metrics).record_cancelled_stream();
                }
            }
        }
    }
}

/// Resolve one request with a typed error emitted BY the processor
/// (e.g. a mid-flight deadline expiry) and account for it.
fn deliver_error(env: &Envelope, err: ServeError,
                 metrics: &Mutex<ServerMetrics>) {
    {
        let mut m = ServerMetrics::lock(metrics);
        match &err {
            ServeError::DeadlineExceeded => m.record_deadline_expired(),
            ServeError::Cancelled => m.record_cancelled_stream(),
            _ => m.record_failed(),
        }
    }
    env.reply.fail(err);
}

fn fail_batch(batch: Vec<Envelope>, err: ServeError) {
    for env in batch {
        env.reply.fail(err.clone());
    }
}
