//! L3 coordinator: the serving stack around the SLA2 denoiser.
//!
//! # Serving architecture
//!
//! vLLM-style, adapted to `!Send` PJRT and fanned out over a sharded
//! engine pool:
//!
//! ```text
//!  clients ──submit()──▶ RequestQueue (bounded, backpressure)
//!                            │  pop_batch: same-tier grouping,
//!                            │  batch window, dequeue stamping
//!                            ▼
//!                     dispatcher thread
//!                            │  claims an idle shard, then pops the
//!                            │  next compatible batch and routes it
//!              ┌─────────────┼─────────────┐
//!              ▼             ▼             ▼
//!          shard 0        shard 1  ...  shard N-1
//!        (own Runtime — PjRtClient is Rc; each shard compiles and
//!         caches its own executables, runs the sampling loop)
//!              │             │             │
//!              └─────────────┴─────────────┘
//!                            ▼
//!          per-request response channels + ServerMetrics
//!          (global counters + per-shard compiles/executions/
//!           batches/utilization rollup)
//! ```
//!
//! **Shard model** — `ServeConfig::num_shards` worker threads (default:
//! available cores minus one).  Each shard owns a full `Runtime` +
//! parameter set; nothing PJRT-related ever crosses a thread boundary.
//!
//! **Dispatch policy** — the dispatcher holds a free-shard token
//! BEFORE popping, so while every shard is busy, requests keep
//! coalescing in the queue (bigger batches under load) and `queue_ms`
//! stays truthful: it is stamped at dequeue, which coincides with the
//! start of service.  With `num_shards = 1` this reduces exactly to
//! the old single-engine FIFO-compatible behavior.
//!
//! **Metrics** — shards update lock-free `ShardStats` (batches,
//! requests, compiles, executions, busy time); `ServerMetrics::
//! snapshot` rolls them up next to the global latency distributions.
//!
//! Requests are whole video generations; all requests in a batch share
//! the timestep schedule (diffusion jobs are fixed-length, so static
//! per-batch scheduling is optimal — there is no analogue of
//! continuous batching's early-exit requests).

pub mod batcher;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod queue;
pub mod request;
pub mod server;

pub use batcher::plan_batches;
pub use engine::Engine;
pub use loadgen::{run_trace, TraceConfig, TraceReport};
pub use metrics::ServerMetrics;
pub use pool::{BatchProcessor, EnginePool, ShardStats};
pub use queue::RequestQueue;
pub use request::{GenRequest, GenResponse, RequestMetrics};
pub use server::Server;
