//! L3 coordinator: the serving stack around the SLA2 denoiser.
//!
//! # Serving architecture
//!
//! vLLM-style, adapted to `!Send` PJRT and fanned out over a sharded
//! engine pool:
//!
//! ```text
//!  remote clients ──frames──▶ net::NetFrontend (v0 JSON / v1 binary
//!                                  │ over TCP; wire::FrameDecoder)
//!                                  │ submit / cancel / metrics verbs
//!                                  ▼
//!  clients ──submit() / submit_streaming()──▶ server::Gateway
//!                            │
//!                            ▼
//!                     RequestQueue (bounded, backpressure)
//!                            │  class-keyed buckets (tier, steps);
//!                            │  pop_batch serves ONE class per the
//!                            │  SchedPolicy (fifo | class-aware
//!                            │  aging + cost bypass), batch window,
//!                            │  dequeue stamping
//!                            ▼
//!                     dispatcher thread
//!                            │  claims idle shards, pops the next
//!                            │  scheduled batch, routes it to a
//!                            │  WARM shard for its class when one
//!                            │  is free (else any idle shard)
//!              ┌─────────────┼─────────────┐
//!              ▼             ▼             ▼
//!          shard 0        shard 1  ...  shard N-1
//!        (own Runtime — PjRtClient is Rc; each shard compiles and
//!         caches its own executables, runs the sampling loop;
//!         manifest + params come from the process-wide
//!         runtime::SharedArtifacts, and compiles go through its
//!         per-artifact single-flight gate)
//!              │             │             │
//!              └─────────────┴─────────────┘
//!                            ▼
//!          per-request reply sinks (request::ReplySink):
//!          one-shot channels AND bounded chunk streams
//!          (stream::ClipStream — frame-range ClipChunks with
//!           cancel-on-drop), + ServerMetrics
//!          (global counters + per-shard compiles/executions/
//!           batches/utilization + per-class queue depths +
//!           warm/cold dispatch routing + compile-cache dedup +
//!           streaming chunk/first-chunk/cancel stats)
//! ```
//!
//! **Shard model** — `ServeConfig::num_shards` worker threads (default:
//! available cores minus one).  Each shard owns a full
//! [`crate::runtime::ComputeBackend`] (`ServeConfig::backend`: PJRT
//! `Runtime` for `"xla"`, the pure-Rust SLA2 implementation for
//! `"native"`); the `Send + Sync` halves of startup (manifest parse,
//! parameter decode) are process-shared, and nothing PJRT-related ever
//! crosses a thread boundary.  The native backend serves any batch
//! size in one launch, so its engines skip sub-batch splitting
//! entirely.
//!
//! **Scheduling** — requests are bucketed by compatibility class
//! `(tier, steps)` at push time ([`queue::ClassKey`]).  The
//! `ServeConfig::scheduler` knob picks the policy: `"fifo"` always
//! serves the class of the globally oldest request (bit-for-bit the
//! seed's single-deque behavior), `"class"` (default) adds a
//! cost-aware head-of-line bypass — a cheaper class whose head has
//! waited at least `ServeConfig::bypass_threshold_ms` jumps an
//! expensive class (canonically: sparse jumps a long dense backlog),
//! with consecutive jumps capped at [`queue::MAX_BYPASS_STREAK`] so
//! nothing starves.
//!
//! **Dispatch** — the dispatcher holds free-shard tokens BEFORE
//! popping, so while every shard is busy, requests keep coalescing in
//! the queue (bigger batches under load) and `queue_ms` stays
//! truthful: it is stamped at dequeue, which coincides with the start
//! of service.  Among idle shards it prefers one already WARM for the
//! batch's class (it compiled that class before), so steady-state
//! compiles across the pool track the number of distinct classes
//! rather than `classes x shards`.  With `num_shards = 1` and
//! `scheduler = "fifo"` this reduces exactly to the old single-engine
//! behavior.
//!
//! **Metrics** — shards update lock-free `ShardStats` (batches,
//! requests, compiles, executions, busy time); the dispatcher updates
//! `DispatchStats` (warm hits / cold routes); `ServerMetrics::
//! snapshot` rolls them up next to the global latency distributions,
//! per-class queue depths and the process-wide compile-cache stats.
//!
//! **Streaming** — every reply travels through a
//! [`request::ReplySink`]: the classic one-shot channel, or a bounded
//! [`stream::ClipStream`] of frame-range [`stream::ClipChunk`]s that
//! the engine feeds as each sub-batch finishes (the one-shot path is
//! itself a thin wrapper over the chunking machinery, so both share
//! invariants).  Dropping a stream cancels its request: the shard
//! stops emitting, all-cancelled batches skip compute entirely, and
//! the abandoned slot is freed.  The [`net`] module exposes submit /
//! streaming chunks / cancel / metrics over TCP
//! (`ServeConfig::listen_addr`) through a readiness-driven reactor
//! (`ServeConfig::net_workers` I/O threads, not thread-per-conn),
//! speaking either the debug-readable length-prefixed JSON v0 or the
//! binary v1 codec ([`wire`]), negotiated per connection by the first
//! byte — with optional token auth and per-connection submit rate
//! limiting.
//!
//! **Failure model** — every failure a caller can observe is a typed
//! [`error::ServeError`] (`overloaded`, `deadline_exceeded`,
//! `shard_failed`, `shard_stalled`, `cancelled`, `bad_request`,
//! `shutting_down`, `unauthorized`, `rate_limited`), and every
//! accepted request resolves to exactly
//! one of {clip, typed error}.  The gateway sheds load at configurable
//! queue-depth / estimated-work watermarks (or reroutes
//! `allow_degrade` requests to a cheaper sparsity tier instead);
//! expired deadlines are dropped at dequeue and re-checked between
//! sub-batches and denoise steps; a panicking shard is caught, its
//! batch retried within a bounded jittered-backoff budget, and a shard
//! failing repeatedly inside a window is quarantined (backend rebuilt,
//! then re-admitted).  A deterministic fault-injection plan
//! ([`crate::util::faults`], `--fault-plan`) drives the chaos test
//! suite over exactly these paths.
//!
//! **Liveness** — crashes are caught by `catch_unwind`; HANGS are
//! caught by the pool watchdog.  Shards stamp a monotonic progress
//! beat at batch start and after every compile / denoise-step execute;
//! when a beat goes stale past `ServeConfig::stall_threshold_ms` the
//! watchdog fences the shard (bumps its generation so any late
//! emission or slot release from the wedged thread is a no-op), fails
//! the stolen in-flight batch with retryable `shard_stalled`, abandons
//! the wedged thread (never joins it) and spawns a replacement worker
//! under the quarantine machinery.  Graceful shutdown mirrors this:
//! SIGTERM / ctrl-c / the `drain` wire verb flip admission to typed
//! `shutting_down`, in-flight work drains up to
//! `ServeConfig::drain_timeout_ms`, open streams are flushed with
//! their terminal frame and idle connections get a `goaway`.  The
//! `health` verb / metrics section reports live / ready / draining
//! plus per-shard state, generation and last-beat age.  On the output
//! side, the native backend refuses to emit a clip containing NaN/Inf
//! (typed shard failure + `nonfinite_outputs` counter) so numerical
//! corruption surfaces as an error, not as garbage video.
//!
//! Requests are whole video generations; all requests in a batch share
//! the timestep schedule (diffusion jobs are fixed-length, so static
//! per-batch scheduling is optimal — there is no analogue of
//! continuous batching's early-exit requests).

// The serving layer is the part of the codebase where a stray panic
// becomes an outage: unwraps are banned outside tests (each test
// module opts back in with an explicit `allow`).  Production paths use
// poison-recovering locks (`pool::lock_recover`, `ServerMetrics::
// lock`) and typed error propagation instead.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod engine;
pub mod error;
pub mod loadgen;
pub mod metrics;
pub mod net;
pub mod pool;
pub mod queue;
pub mod request;
pub mod server;
pub mod stream;
pub mod wire;

pub use batcher::{plan_batches, plan_batches_greedy, plan_support};
pub use engine::Engine;
pub use error::ServeError;
pub use loadgen::{run_trace, TraceConfig, TraceReport};
pub use metrics::ServerMetrics;
pub use net::{ClientOpts, NetClient, NetFrontend};
pub use pool::{BatchProcessor, DispatchStats, EnginePool, ShardStats};
pub use queue::{ClassKey, RequestQueue, SchedPolicy};
pub use request::{GenRequest, GenResponse, ReplySink, RequestMetrics};
pub use server::{Gateway, Server, SubmitOpts};
pub use stream::{ClipChunk, ClipStream, StreamCancel};
pub use wire::{FrameDecoder, WireFormat, WireFrame};
