//! L3 coordinator: the serving stack around the SLA2 denoiser.
//!
//! Architecture (vLLM-style, adapted to `!Send` PJRT):
//!
//! ```text
//!  clients ──submit()──▶ RequestQueue (bounded, backpressure)
//!                            │  pop_batch: same-tier grouping,
//!                            │  batch window, size planning
//!                            ▼
//!                     engine thread (owns Runtime — PjRtClient is Rc)
//!                            │  sampling loop: denoise HLO + Euler
//!                            ▼
//!                     per-request response channels + metrics
//! ```
//!
//! Requests are whole video generations; all requests in a batch share
//! the timestep schedule (diffusion jobs are fixed-length, so static
//! per-batch scheduling is optimal — there is no analogue of
//! continuous batching's early-exit requests).

pub mod batcher;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;

pub use batcher::plan_batches;
pub use engine::Engine;
pub use loadgen::{run_trace, TraceConfig, TraceReport};
pub use metrics::ServerMetrics;
pub use queue::RequestQueue;
pub use request::{GenRequest, GenResponse, RequestMetrics};
pub use server::Server;
