//! Poisson load generator: drive the server with a realistic open-loop
//! request trace and measure latency / throughput / rejection under
//! offered load — the serving-paper methodology for exercising the
//! dynamic batcher, admission control and backpressure path.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::error::ServeError;
use super::request::GenResponse;
use super::server::{Server, SubmitOpts};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// offered load, requests/second (Poisson arrivals)
    pub rps: f64,
    pub n_requests: usize,
    /// sparsity tiers sampled uniformly per request
    pub tiers: Vec<String>,
    pub steps: usize,
    pub seed: u64,
    /// per-request deadline carried on every submission (ms);
    /// 0 = none beyond the server default
    pub deadline_ms: u64,
    /// opt every request into tier degradation under overload
    pub allow_degrade: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rps: 4.0, n_requests: 16,
                      tiers: vec!["s90".into()], steps: 4, seed: 17,
                      deadline_ms: 0, allow_degrade: false }
    }
}

#[derive(Debug)]
pub struct TraceReport {
    pub offered: usize,
    pub accepted: usize,
    /// turned away at submit, any typed error (includes `shed`)
    pub rejected: usize,
    /// subset of `rejected` turned away by the admission watermarks
    /// (the server's `failures.shed` delta over the trace)
    pub shed: usize,
    /// accepted but rerouted to a cheaper tier by admission control
    /// (the server's `failures.degraded` delta over the trace)
    pub degraded: usize,
    pub completed: usize,
    /// accepted but resolved `deadline_exceeded`
    pub expired: usize,
    /// accepted but resolved with any other typed error
    pub failed: usize,
    /// end-to-end request latency (submit -> response), seconds —
    /// completed (admitted, non-expired) requests only, so `p99` is
    /// the p99 of ADMITTED work under shedding
    pub latency: Option<Summary>,
    pub wall_s: f64,
}

impl TraceReport {
    /// Completed requests per wall-clock second — the goodput the
    /// overload bench sweeps.
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .push("offered", self.offered)
            .push("accepted", self.accepted)
            .push("rejected", self.rejected)
            .push("shed", self.shed)
            .push("degraded", self.degraded)
            .push("completed", self.completed)
            .push("expired", self.expired)
            .push("failed", self.failed)
            .push("wall_s", self.wall_s)
            .push("throughput_rps", self.throughput_rps());
        if let Some(l) = &self.latency {
            j = j.push("latency_mean_ms", l.mean * 1e3)
                .push("latency_p50_ms", l.p50 * 1e3)
                .push("latency_p99_ms", l.p99 * 1e3);
        }
        j
    }
}

/// Read one counter out of a metrics snapshot's `failures` section.
fn failures_counter(snap: &Json, key: &str) -> usize {
    snap.get("failures")
        .and_then(|f| f.get(key))
        .and_then(|v| v.as_usize())
        .unwrap_or(0)
}

/// Replay a Poisson trace against a running server (open loop: arrivals
/// do not wait for completions, so overload genuinely queues/rejects).
pub fn run_trace(server: &Server, cfg: &TraceConfig) -> Result<TraceReport> {
    // shed/degraded are server-side decisions: read them as snapshot
    // deltas so the report works on a server that has already run
    // other traces
    let before = server.metrics_snapshot();
    let (shed0, degraded0) = (failures_counter(&before, "shed"),
                              failures_counter(&before, "degraded"));
    let opts = SubmitOpts { deadline_ms: cfg.deadline_ms,
                            allow_degrade: cfg.allow_degrade,
                            variant: None };
    let mut rng = Pcg32::seeded(cfg.seed);
    let start = Instant::now();
    let mut inflight: Vec<(Instant,
                           Receiver<Result<GenResponse, ServeError>>)> =
        Vec::new();
    let mut rejected = 0usize;
    let mut next_arrival = Instant::now();
    for i in 0..cfg.n_requests {
        // Poisson process: exponential inter-arrival gaps
        next_arrival += Duration::from_secs_f64(rng.exp(cfg.rps));
        if let Some(gap) = next_arrival.checked_duration_since(Instant::now())
        {
            std::thread::sleep(gap);
        }
        let tier = cfg.tiers[rng.below(cfg.tiers.len() as u32) as usize]
            .clone();
        let label = rng.below(10) as i32;
        match server.submit_with(label, cfg.seed + i as u64, cfg.steps,
                                 &tier, opts.clone()) {
            Ok(rx) => inflight.push((Instant::now(), rx)),
            Err(_) => rejected += 1, // shed/backpressure: keep offering
        }
    }
    let mut latencies = Vec::with_capacity(inflight.len());
    let mut expired = 0usize;
    let mut failed = 0usize;
    for (t0, rx) in inflight {
        match rx.recv() {
            Ok(Ok(_)) => latencies.push(t0.elapsed().as_secs_f64()),
            Ok(Err(ServeError::DeadlineExceeded)) => expired += 1,
            _ => failed += 1,
        }
    }
    let completed = latencies.len();
    let after = server.metrics_snapshot();
    Ok(TraceReport {
        offered: cfg.n_requests,
        accepted: cfg.n_requests - rejected,
        rejected,
        shed: failures_counter(&after, "shed").saturating_sub(shed0),
        degraded: failures_counter(&after, "degraded")
            .saturating_sub(degraded0),
        completed,
        expired,
        failed,
        latency: if latencies.is_empty() { None }
                 else { Some(Summary::of(&latencies)) },
        wall_s: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn trace_config_defaults_sane() {
        let c = TraceConfig::default();
        assert!(c.rps > 0.0 && c.n_requests > 0 && !c.tiers.is_empty());
        assert_eq!(c.deadline_ms, 0);
        assert!(!c.allow_degrade);
    }

    #[test]
    fn report_json_roundtrips() {
        let r = TraceReport {
            offered: 10, accepted: 8, rejected: 2, shed: 1, degraded: 1,
            completed: 7, expired: 0, failed: 1,
            latency: Some(Summary::of(&[0.1, 0.2, 0.3])),
            wall_s: 2.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("rejected").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("shed").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("degraded").unwrap().as_usize(), Some(1));
        assert!((j.get("throughput_rps").unwrap().as_f64().unwrap() - 3.5)
            .abs() < 1e-9);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("completed").unwrap().as_usize(), Some(7));
        assert_eq!(parsed.get("expired").unwrap().as_usize(), Some(0));
    }
}
