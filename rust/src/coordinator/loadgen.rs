//! Poisson load generator: drive the server with a realistic open-loop
//! request trace and measure latency / throughput / rejection under
//! offered load — the serving-paper methodology for exercising the
//! dynamic batcher and backpressure path.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::request::GenResponse;
use super::server::Server;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// offered load, requests/second (Poisson arrivals)
    pub rps: f64,
    pub n_requests: usize,
    /// sparsity tiers sampled uniformly per request
    pub tiers: Vec<String>,
    pub steps: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { rps: 4.0, n_requests: 16,
                      tiers: vec!["s90".into()], steps: 4, seed: 17 }
    }
}

#[derive(Debug)]
pub struct TraceReport {
    pub offered: usize,
    pub accepted: usize,
    pub rejected: usize,
    pub completed: usize,
    pub failed: usize,
    /// end-to-end request latency (submit -> response), seconds
    pub latency: Option<Summary>,
    pub wall_s: f64,
}

impl TraceReport {
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .push("offered", self.offered)
            .push("accepted", self.accepted)
            .push("rejected", self.rejected)
            .push("completed", self.completed)
            .push("failed", self.failed)
            .push("wall_s", self.wall_s)
            .push("throughput_rps", self.throughput_rps());
        if let Some(l) = &self.latency {
            j = j.push("latency_mean_ms", l.mean * 1e3)
                .push("latency_p50_ms", l.p50 * 1e3)
                .push("latency_p99_ms", l.p99 * 1e3);
        }
        j
    }
}

/// Replay a Poisson trace against a running server (open loop: arrivals
/// do not wait for completions, so overload genuinely queues/rejects).
pub fn run_trace(server: &Server, cfg: &TraceConfig) -> Result<TraceReport> {
    let mut rng = Pcg32::seeded(cfg.seed);
    let start = Instant::now();
    let mut inflight: Vec<(Instant, Receiver<Result<GenResponse>>)> =
        Vec::new();
    let mut rejected = 0usize;
    let mut next_arrival = Instant::now();
    for i in 0..cfg.n_requests {
        // Poisson process: exponential inter-arrival gaps
        next_arrival += Duration::from_secs_f64(rng.exp(cfg.rps));
        if let Some(gap) = next_arrival.checked_duration_since(Instant::now())
        {
            std::thread::sleep(gap);
        }
        let tier = cfg.tiers[rng.below(cfg.tiers.len() as u32) as usize]
            .clone();
        let label = rng.below(10) as i32;
        match server.submit(label, cfg.seed + i as u64, cfg.steps, &tier) {
            Ok(rx) => inflight.push((Instant::now(), rx)),
            Err(_) => rejected += 1, // backpressure: drop, keep offering
        }
    }
    let mut latencies = Vec::with_capacity(inflight.len());
    let mut failed = 0usize;
    for (t0, rx) in inflight {
        match rx.recv() {
            Ok(Ok(_)) => latencies.push(t0.elapsed().as_secs_f64()),
            _ => failed += 1,
        }
    }
    let completed = latencies.len();
    Ok(TraceReport {
        offered: cfg.n_requests,
        accepted: cfg.n_requests - rejected,
        rejected,
        completed,
        failed,
        latency: if latencies.is_empty() { None }
                 else { Some(Summary::of(&latencies)) },
        wall_s: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_config_defaults_sane() {
        let c = TraceConfig::default();
        assert!(c.rps > 0.0 && c.n_requests > 0 && !c.tiers.is_empty());
    }

    #[test]
    fn report_json_roundtrips() {
        let r = TraceReport {
            offered: 10, accepted: 8, rejected: 2, completed: 7,
            failed: 1, latency: Some(Summary::of(&[0.1, 0.2, 0.3])),
            wall_s: 2.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("rejected").unwrap().as_usize(), Some(2));
        assert!((j.get("throughput_rps").unwrap().as_f64().unwrap() - 3.5)
            .abs() < 1e-9);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("completed").unwrap().as_usize(), Some(7));
    }
}
