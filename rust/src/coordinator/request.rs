//! Request/response types for the generation service.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::tensor::Tensor;

/// A video-generation request (one clip).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// class conditioning (stands in for the text prompt)
    pub class_label: i32,
    /// seed for the initial noise latent
    pub seed: u64,
    /// sampling steps (must match across a batch; the batcher groups)
    pub steps: usize,
    /// sparsity tier: "s90" | "s95" | "s97" | "dense"
    pub tier: String,
    pub submitted_at: Instant,
    /// stamped by `RequestQueue::pop_batch` when the request leaves the
    /// queue; `None` for requests that never crossed the queue (direct
    /// `Engine::generate` calls in benches and tests)
    pub dequeued_at: Option<Instant>,
}

impl GenRequest {
    pub fn new(id: u64, class_label: i32, seed: u64, steps: usize,
               tier: &str) -> GenRequest {
        GenRequest { id, class_label, seed, steps, tier: tier.into(),
                     submitted_at: Instant::now(), dequeued_at: None }
    }

    /// Two requests can share a batch iff they run the same artifact
    /// and walk the same timestep grid.
    pub fn compatible(&self, other: &GenRequest) -> bool {
        self.tier == other.tier && self.steps == other.steps
    }

    /// Queue wait in milliseconds, measured submit -> dequeue.
    /// Non-negative by construction (the dequeue stamp is taken after
    /// the submit stamp); 0.0 when the request bypassed the queue.
    pub fn queue_wait_ms(&self) -> f64 {
        self.dequeued_at
            .map(|d| d.saturating_duration_since(self.submitted_at)
                      .as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }
}

/// Per-request service metrics (returned with the clip).
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub queue_ms: f64,
    pub compute_ms: f64,
    pub steps: usize,
    /// batch size this request was served in
    pub batch_size: usize,
}

#[derive(Debug)]
pub struct GenResponse {
    pub id: u64,
    pub clip: Tensor,
    pub metrics: RequestMetrics,
}

/// What actually travels through the queue: request + reply channel.
pub struct Envelope {
    pub request: GenRequest,
    pub reply: Sender<anyhow::Result<GenResponse>>,
}

impl std::fmt::Debug for Envelope {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Envelope").field("request", &self.request).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatibility() {
        let a = GenRequest::new(1, 0, 0, 8, "s95");
        let b = GenRequest::new(2, 5, 9, 8, "s95");
        let c = GenRequest::new(3, 0, 0, 4, "s95");
        let d = GenRequest::new(4, 0, 0, 8, "s97");
        assert!(a.compatible(&b));
        assert!(!a.compatible(&c)); // different step count
        assert!(!a.compatible(&d)); // different tier
    }

    #[test]
    fn queue_wait_is_zero_without_dequeue_and_nonnegative_with() {
        let mut r = GenRequest::new(1, 0, 0, 8, "s95");
        assert_eq!(r.queue_wait_ms(), 0.0);
        r.dequeued_at = Some(Instant::now());
        assert!(r.queue_wait_ms() >= 0.0);
        // a stamp that (impossibly) predates the submit still never
        // goes negative thanks to saturating_duration_since
        r.dequeued_at = Some(r.submitted_at);
        assert_eq!(r.queue_wait_ms(), 0.0);
    }
}
