//! Request/response types for the generation service.
//!
//! The reply path is sink-polymorphic: every request travels with a
//! [`ReplySink`] that is either a classic one-shot channel (the whole
//! clip in one [`GenResponse`]) or a [`ChunkSender`] feeding a
//! [`crate::coordinator::stream::ClipStream`].  The one-shot variant
//! is delivered THROUGH the chunking path (split + reassemble), so
//! both sinks exercise the same stream invariants.  Failures travel as
//! typed [`ServeError`]s — every request resolves to exactly one of
//! {clip, `ServeError`}.

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use super::error::ServeError;
use super::stream::ChunkSender;
use crate::tensor::Tensor;

/// A video-generation request (one clip).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// class conditioning (stands in for the text prompt)
    pub class_label: i32,
    /// seed for the initial noise latent
    pub seed: u64,
    /// sampling steps (must match across a batch; the batcher groups)
    pub steps: usize,
    /// sparsity tier: "s90" | "s95" | "s97" | "dense"
    pub tier: String,
    /// attention-variant override (`"sla2"`, `"sparge2"`, ...);
    /// `None` = the server's configured default.  Validated against
    /// the backend's supported set at admission (Gateway), so a bogus
    /// variant is a typed reject instead of a shard compile failure.
    /// Part of batch compatibility — shards compile per variant.
    pub variant: Option<String>,
    pub submitted_at: Instant,
    /// stamped by `RequestQueue::pop_batch` when the request leaves the
    /// queue; `None` for requests that never crossed the queue (direct
    /// `Engine::generate` calls in benches and tests)
    pub dequeued_at: Option<Instant>,
    /// absolute deadline; past it the request fails with
    /// [`ServeError::DeadlineExceeded`] instead of being served.
    /// Checked at dequeue, between sub-batches, and between denoise
    /// steps so an expired request frees its shard slot early.
    pub deadline: Option<Instant>,
    /// opt-in to tier degradation under overload: instead of a shed,
    /// admission control may move the request to a cheaper sparsity
    /// tier (recorded in `degraded_from`)
    pub allow_degrade: bool,
    /// retry attempts consumed so far (shard-panic requeues)
    pub retries: u32,
    /// original tier when admission control degraded this request
    pub degraded_from: Option<String>,
}

impl GenRequest {
    pub fn new(id: u64, class_label: i32, seed: u64, steps: usize,
               tier: &str) -> GenRequest {
        GenRequest { id, class_label, seed, steps, tier: tier.into(),
                     variant: None, submitted_at: Instant::now(),
                     dequeued_at: None, deadline: None,
                     allow_degrade: false, retries: 0,
                     degraded_from: None }
    }

    /// Builder: set a deadline `ms` milliseconds from submit time
    /// (`0` = no deadline).
    pub fn with_deadline_ms(mut self, ms: u64) -> GenRequest {
        if ms > 0 {
            self.deadline =
                Some(self.submitted_at + Duration::from_millis(ms));
        }
        self
    }

    /// Builder: opt in to tier degradation under overload.
    pub fn with_allow_degrade(mut self, allow: bool) -> GenRequest {
        self.allow_degrade = allow;
        self
    }

    /// Builder: override the attention variant (`None` = server
    /// default).
    pub fn with_variant(mut self, variant: Option<String>) -> GenRequest {
        self.variant = variant;
        self
    }

    /// Two requests can share a batch iff they run the same artifact
    /// (tier AND variant select the compiled executable) and walk the
    /// same timestep grid.
    pub fn compatible(&self, other: &GenRequest) -> bool {
        self.tier == other.tier && self.steps == other.steps
            && self.variant == other.variant
    }

    /// True once the deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }

    /// Queue wait in milliseconds, measured submit -> dequeue.
    /// Non-negative by construction (the dequeue stamp is taken after
    /// the submit stamp); 0.0 when the request bypassed the queue.
    pub fn queue_wait_ms(&self) -> f64 {
        self.dequeued_at
            .map(|d| d.saturating_duration_since(self.submitted_at)
                      .as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }
}

/// Per-request service metrics (returned with the clip).
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub queue_ms: f64,
    pub compute_ms: f64,
    pub steps: usize,
    /// batch size this request was served in
    pub batch_size: usize,
}

#[derive(Debug)]
pub struct GenResponse {
    pub id: u64,
    pub clip: Tensor,
    pub metrics: RequestMetrics,
}

/// Where a served request's output goes.
pub enum ReplySink {
    /// classic API: the full clip in one message
    Oneshot(Sender<Result<GenResponse, ServeError>>),
    /// streaming API: frame-range chunks as they become ready
    Stream(ChunkSender),
}

impl ReplySink {
    /// True when the consumer has abandoned a STREAMING request (the
    /// `ClipStream` was dropped or cancelled) — the serving side uses
    /// this to skip compute for dead work.  One-shot receivers cannot
    /// be observed without sending, so they always report `false`.
    pub fn is_cancelled(&self) -> bool {
        match self {
            ReplySink::Oneshot(_) => false,
            ReplySink::Stream(cs) => cs.is_cancelled(),
        }
    }

    /// Deliver a typed terminal failure.  Never blocks: a dropped
    /// one-shot receiver makes `send` a no-op, and the stream side
    /// uses a non-blocking error push.
    pub fn fail(&self, err: ServeError) {
        match self {
            ReplySink::Oneshot(tx) => {
                let _ = tx.send(Err(err));
            }
            ReplySink::Stream(cs) => cs.send_error(err),
        }
    }
}

/// What actually travels through the queue: request + reply sink.
pub struct Envelope {
    pub request: GenRequest,
    pub reply: ReplySink,
}

impl Envelope {
    /// Envelope with a classic one-shot reply channel.
    pub fn oneshot(request: GenRequest,
                   reply: Sender<Result<GenResponse, ServeError>>)
                   -> Envelope {
        Envelope { request, reply: ReplySink::Oneshot(reply) }
    }

    /// Envelope whose clip is delivered as a chunk stream.
    pub fn stream(request: GenRequest, chunks: ChunkSender) -> Envelope {
        Envelope { request, reply: ReplySink::Stream(chunks) }
    }
}

impl std::fmt::Debug for Envelope {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Envelope").field("request", &self.request).finish()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn compatibility() {
        let a = GenRequest::new(1, 0, 0, 8, "s95");
        let b = GenRequest::new(2, 5, 9, 8, "s95");
        let c = GenRequest::new(3, 0, 0, 4, "s95");
        let d = GenRequest::new(4, 0, 0, 8, "s97");
        assert!(a.compatible(&b));
        assert!(!a.compatible(&c)); // different step count
        assert!(!a.compatible(&d)); // different tier
        // variant overrides select different compiled executables, so
        // they split batches; two identical overrides still share
        let e = GenRequest::new(5, 0, 0, 8, "s95")
            .with_variant(Some("sparge2".into()));
        let f = GenRequest::new(6, 1, 2, 8, "s95")
            .with_variant(Some("sparge2".into()));
        assert!(!a.compatible(&e)); // default vs override
        assert!(e.compatible(&f));
        let g = GenRequest::new(7, 0, 0, 8, "s95")
            .with_variant(Some("svg_ear".into()));
        assert!(!e.compatible(&g)); // different overrides
    }

    #[test]
    fn queue_wait_is_zero_without_dequeue_and_nonnegative_with() {
        let mut r = GenRequest::new(1, 0, 0, 8, "s95");
        assert_eq!(r.queue_wait_ms(), 0.0);
        r.dequeued_at = Some(Instant::now());
        assert!(r.queue_wait_ms() >= 0.0);
        // a stamp that (impossibly) predates the submit still never
        // goes negative thanks to saturating_duration_since
        r.dequeued_at = Some(r.submitted_at);
        assert_eq!(r.queue_wait_ms(), 0.0);
    }

    #[test]
    fn deadlines() {
        let r = GenRequest::new(1, 0, 0, 8, "s95");
        assert!(r.deadline.is_none());
        assert!(!r.expired(Instant::now() + Duration::from_secs(3600)));

        let r = GenRequest::new(2, 0, 0, 8, "s95").with_deadline_ms(0);
        assert!(r.deadline.is_none(), "0 = no deadline");

        let r = GenRequest::new(3, 0, 0, 8, "s95").with_deadline_ms(50);
        assert!(!r.expired(r.submitted_at));
        assert!(r.expired(r.submitted_at + Duration::from_millis(51)));
    }

    #[test]
    fn typed_failure_reaches_the_oneshot_receiver() {
        let (tx, rx) = std::sync::mpsc::channel();
        let env = Envelope::oneshot(GenRequest::new(1, 0, 0, 4, "s90"), tx);
        env.reply.fail(ServeError::Overloaded { retry_after_ms: 40 });
        match rx.recv().unwrap() {
            Err(ServeError::Overloaded { retry_after_ms }) =>
                assert_eq!(retry_after_ms, 40),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }
}
