//! Request/response types for the generation service.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::tensor::Tensor;

/// A video-generation request (one clip).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// class conditioning (stands in for the text prompt)
    pub class_label: i32,
    /// seed for the initial noise latent
    pub seed: u64,
    /// sampling steps (must match across a batch; the batcher groups)
    pub steps: usize,
    /// sparsity tier: "s90" | "s95" | "s97" | "dense"
    pub tier: String,
    pub submitted_at: Instant,
}

impl GenRequest {
    pub fn new(id: u64, class_label: i32, seed: u64, steps: usize,
               tier: &str) -> GenRequest {
        GenRequest { id, class_label, seed, steps, tier: tier.into(),
                     submitted_at: Instant::now() }
    }

    /// Two requests can share a batch iff they run the same artifact
    /// and walk the same timestep grid.
    pub fn compatible(&self, other: &GenRequest) -> bool {
        self.tier == other.tier && self.steps == other.steps
    }
}

/// Per-request service metrics (returned with the clip).
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub queue_ms: f64,
    pub compute_ms: f64,
    pub steps: usize,
    /// batch size this request was served in
    pub batch_size: usize,
}

#[derive(Debug)]
pub struct GenResponse {
    pub id: u64,
    pub clip: Tensor,
    pub metrics: RequestMetrics,
}

/// What actually travels through the queue: request + reply channel.
pub struct Envelope {
    pub request: GenRequest,
    pub reply: Sender<anyhow::Result<GenResponse>>,
}

impl std::fmt::Debug for Envelope {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Envelope").field("request", &self.request).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatibility() {
        let a = GenRequest::new(1, 0, 0, 8, "s95");
        let b = GenRequest::new(2, 5, 9, 8, "s95");
        let c = GenRequest::new(3, 0, 0, 4, "s95");
        let d = GenRequest::new(4, 0, 0, 8, "s97");
        assert!(a.compatible(&b));
        assert!(!a.compatible(&c)); // different step count
        assert!(!a.compatible(&d)); // different tier
    }
}
