//! Analytic cost model: FLOPs accounting + calibrated device model.
//!
//! The paper's kernel-speed (Fig. 4) and end-to-end latency (Fig. 5)
//! numbers come from CUDA kernels on an RTX5090 — unreproducible on
//! this CPU-only testbed.  Per DESIGN.md §2, the *shape* of those
//! results is regenerated from first principles:
//!
//! * [`flops`] counts exact multiply-add work per attention variant
//!   (sparse branch, linear branch, router, quant overhead) and per
//!   model forward — the Table 1 "FLOPs" column;
//! * [`device`] turns (FLOPs, bytes) into kernel time via a roofline
//!   model with per-method efficiency factors calibrated on the
//!   paper's published points (FlashAttn2 baseline, SLA2 18.7x @ 97 %,
//!   VSA 2.6x slower, VMoBA 11.7x slower, quant 1.3x);
//! * [`e2e`] composes kernel times into end-to-end generation latency
//!   (Fig. 5) given a model geometry and step count.

pub mod device;
pub mod e2e;
pub mod flops;

pub use device::{Device, KernelTime};
pub use e2e::E2eEstimate;
pub use flops::{AttnGeometry, AttnKind, FlopCount};
