//! Exact FLOP accounting for every attention variant (2 FLOPs per MAC).
//!
//! The paper's convention: full attention "theoretical computation"
//! is `C = 4 N^2 d` per head (Sec. 9.1) — the two N x N x d matmuls.
//! All counts below follow that convention so our Table 1 FLOPs column
//! is directly comparable.

/// Geometry of one attention call (single head).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnGeometry {
    pub n: usize,
    pub d: usize,
    pub b_q: usize,
    pub b_k: usize,
    /// fraction of key blocks kept by the sparse branch (k%)
    pub keep: f64,
}

impl AttnGeometry {
    pub fn t_m(&self) -> usize {
        self.n / self.b_q
    }

    pub fn t_n(&self) -> usize {
        self.n / self.b_k
    }

    pub fn kept_blocks(&self) -> usize {
        ((self.keep * self.t_n() as f64).round() as usize).max(1)
    }

    /// Achieved block sparsity (what Table 1 reports).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.kept_blocks() as f64 / self.t_n() as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttnKind {
    Full,
    /// block-sparse softmax only (VSA / VMoBA kernels)
    SparseOnly,
    /// original SLA: sparse + linear + d x d output projection
    Sla,
    /// SLA2: sparse + linear + alpha mix (+ optional INT8 forward)
    Sla2 { quant: bool },
}

/// FLOPs split by component — lets benches report where compute goes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FlopCount {
    pub sparse: f64,
    pub linear: f64,
    pub router: f64,
    pub combine: f64,
    /// elementwise quant/dequant work (NOT matmul speedup — that is a
    /// device-model concern)
    pub quant_overhead: f64,
}

impl FlopCount {
    pub fn total(&self) -> f64 {
        self.sparse + self.linear + self.router + self.combine
            + self.quant_overhead
    }
}

/// Full-attention reference cost `C = 4 N^2 d`.
pub fn full_attention_flops(n: usize, d: usize) -> f64 {
    4.0 * (n as f64) * (n as f64) * (d as f64)
}

/// FLOPs for one single-head attention call of the given kind.
pub fn attention_flops(kind: AttnKind, g: &AttnGeometry) -> FlopCount {
    let n = g.n as f64;
    let d = g.d as f64;
    let t_m = g.t_m() as f64;
    let t_n = g.t_n() as f64;
    let kept_frac = g.kept_blocks() as f64 / t_n;
    let skip_frac = 1.0 - kept_frac;
    let full = full_attention_flops(g.n, g.d);

    let router = {
        // pooling (n*d adds) + two (T,d)x(d,d) projections + score matmul
        let pool = n * d;
        let proj = 2.0 * t_m * d * d + 2.0 * t_n * d * d;
        let scores = 2.0 * t_m * t_n * d;
        pool + proj + scores
    };

    // linear branch (Alg. 2 lines 6-7, 20, 24):
    //   h_j = K_j^T V_j for every block:        2 n d^2
    //   z_j = colsum(K_j):                      n d
    //   state accumulation over skipped tiles:  skip * t_m t_n d(d+1)
    //   O_l = Q H / (Q Z):                      2 n d^2 + 2 n d
    let linear = 2.0 * n * d * d + n * d
        + skip_frac * t_m * t_n * (d * d + d)
        + 2.0 * n * d * d + 2.0 * n * d;

    match kind {
        AttnKind::Full => FlopCount { sparse: full, ..Default::default() },
        AttnKind::SparseOnly => FlopCount {
            sparse: kept_frac * full,
            router,
            ..Default::default()
        },
        AttnKind::Sla => FlopCount {
            sparse: kept_frac * full,
            linear,
            router,
            combine: 2.0 * n * d * d, // proj(O_l) then add
            ..Default::default()
        },
        AttnKind::Sla2 { quant } => FlopCount {
            sparse: kept_frac * full,
            linear,
            router,
            combine: 3.0 * n * d, // alpha mix (Eq. 13)
            quant_overhead: if quant {
                // quant+dequant of Q,K tiles and P,V tiles (~3 ops/elem)
                3.0 * kept_frac * (2.0 * n * d + t_m * t_n / t_n * n * d)
            } else {
                0.0
            },
        },
    }
}

/// Attention FLOPs for a whole model forward (all layers and heads) —
/// the Table 1 "FLOPs" column.
pub fn model_attention_flops(kind: AttnKind, g: &AttnGeometry,
                             layers: usize, heads: usize) -> f64 {
    attention_flops(kind, g).total() * (layers * heads) as f64
}

/// The paper's evaluation geometries (Wan2.1 at 480P/720P), used to
/// regenerate Table 1's absolute FLOPs numbers.  Token counts are
/// solved so full-attention FLOPs match the paper's reported
/// 52.75T / 292.6T (`4 N^2 d x heads x layers`).  `attn_frac_full` is
/// the fraction of end-to-end runtime spent in attention under full
/// attention, solved from the paper's Fig. 5 end-to-end speedups
/// (2.30x at 13.9x attention speedup => 0.61; 4.35x => 0.815).
pub struct PaperModel {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub attn_frac_full: f64,
}

pub const WAN_1_3B: PaperModel = PaperModel {
    // 30 layers x 12 heads x 4 N^2 d = 52.75T  =>  N ~ 16.9k tokens
    name: "Wan2.1-1.3B-480P", n: 16917, d: 128, heads: 12, layers: 30,
    attn_frac_full: 0.61,
};

pub const WAN_14B: PaperModel = PaperModel {
    // 40 layers x 40 heads x 4 N^2 d = 292.6T  =>  N ~ 18.9k tokens
    name: "Wan2.1-14B-720P", n: 18900, d: 128, heads: 40, layers: 40,
    attn_frac_full: 0.815,
};

/// The geometry Fig. 4's kernel-speed curves are measured at (long
/// video sequences; block sizes b_q=128, b_k=64 per Sec. 9.1).
pub const FIG4_GEOM: AttnGeometry = AttnGeometry {
    n: 32768, d: 128, b_q: 128, b_k: 64, keep: 1.0,
};

impl PaperModel {
    pub fn geometry(&self, keep: f64) -> AttnGeometry {
        AttnGeometry { n: self.n, d: self.d, b_q: 128, b_k: 64, keep }
    }

    pub fn full_flops(&self) -> f64 {
        model_attention_flops(AttnKind::Full, &self.geometry(1.0),
                              self.layers, self.heads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(keep: f64) -> AttnGeometry {
        AttnGeometry { n: 256, d: 64, b_q: 32, b_k: 16, keep }
    }

    #[test]
    fn full_matches_paper_convention() {
        let f = attention_flops(AttnKind::Full, &geom(1.0));
        assert_eq!(f.total(), 4.0 * 256.0 * 256.0 * 64.0);
    }

    #[test]
    fn sparse_scales_with_keep() {
        let f90 = attention_flops(AttnKind::SparseOnly, &geom(0.10));
        let f50 = attention_flops(AttnKind::SparseOnly, &geom(0.50));
        assert!(f90.sparse < f50.sparse);
        assert_eq!(f90.router, f50.router);
    }

    #[test]
    fn kept_blocks_floor_at_one() {
        let g = geom(0.01);
        assert_eq!(g.kept_blocks(), 1);
        assert!(g.sparsity() < 1.0);
    }

    #[test]
    fn sla2_cheaper_than_full_at_high_sparsity() {
        // At our small test geometry (N=256, d=64) the O(N d^2) linear
        // branch is a large constant, so the saving is modest...
        let sla2 = attention_flops(AttnKind::Sla2 { quant: true },
                                   &geom(0.05));
        let full = attention_flops(AttnKind::Full, &geom(1.0));
        assert!(sla2.total() < 0.6 * full.total(),
                "sla2 {} vs full {}", sla2.total(), full.total());
        // ...while at paper scale (N >> d) it matches the paper's
        // "97 % sparsity ~ 96.7 % computation saving" claim.
        let g = AttnGeometry { n: 32768, d: 128, b_q: 128, b_k: 64,
                               keep: 0.03 };
        let s = attention_flops(AttnKind::Sla2 { quant: false }, &g);
        let f = attention_flops(AttnKind::Full, &AttnGeometry {
            keep: 1.0, ..g });
        let saving = 1.0 - s.total() / f.total();
        assert!(saving > 0.955 && saving < 0.975, "saving {saving:.4}");
    }

    #[test]
    fn linear_branch_is_o_n_d2() {
        // doubling N should ~double (not quadruple) the linear branch
        let g1 = AttnGeometry { n: 256, d: 64, b_q: 32, b_k: 16, keep: 0.05 };
        let g2 = AttnGeometry { n: 512, d: 64, b_q: 32, b_k: 16, keep: 0.05 };
        let l1 = attention_flops(AttnKind::Sla2 { quant: false }, &g1).linear;
        let l2 = attention_flops(AttnKind::Sla2 { quant: false }, &g2).linear;
        assert!(l2 / l1 < 2.6, "ratio {}", l2 / l1);
    }

    #[test]
    fn paper_table1_flops_reproduced() {
        // Table 1: Full Attention = 52.75T (1.3B) and 292.6T (14B)
        let f13 = WAN_1_3B.full_flops();
        assert!((f13 / 52.75e12 - 1.0).abs() < 0.01, "{f13:e}");
        let f14 = WAN_14B.full_flops();
        assert!((f14 / 292.6e12 - 1.0).abs() < 0.01, "{f14:e}");
    }

    #[test]
    fn paper_table1_sparse_rows() {
        // Table 1: 90 % sparsity rows ~ 5.28-5.51T for the 1.3B model
        let g = WAN_1_3B.geometry(0.10);
        let sla2 = model_attention_flops(AttnKind::Sla2 { quant: true }, &g,
                                         WAN_1_3B.layers, WAN_1_3B.heads);
        assert!(sla2 > 4.9e12 && sla2 < 6.6e12, "{sla2:e}");
    }

    #[test]
    fn components_sum() {
        let f = attention_flops(AttnKind::Sla2 { quant: true }, &geom(0.1));
        let s = f.sparse + f.linear + f.router + f.combine
            + f.quant_overhead;
        assert_eq!(f.total(), s);
    }
}
