//! End-to-end generation-latency composition (Fig. 5).
//!
//! ```text
//! latency = attention_time(method, sparsity) + other_time
//! ```
//!
//! `other_time` (projections, MLPs, norms, VAE) does not depend on the
//! attention method — Fig. 5's bars are exactly this decomposition.
//! It is anchored on the paper's own full-attention split
//! (`PaperModel::attn_frac_full`, solved from the reported end-to-end
//! speedups), because the non-attention stack (text encoder, VAE,
//! scheduler) is not something a FLOP model can see.

use super::device::{kernel_time, profile, vmoba_profile, Device};
use super::flops::{AttnGeometry, AttnKind, PaperModel};

#[derive(Debug, Clone, Copy)]
pub struct E2eEstimate {
    pub attention_s: f64,
    pub other_s: f64,
}

impl E2eEstimate {
    pub fn total_s(&self) -> f64 {
        self.attention_s + self.other_s
    }
}

/// Estimate one full generation (all sampling steps) for a paper-scale
/// model on the modelled device.
pub fn estimate(dev: &Device, model: &PaperModel, kind: AttnKind,
                keep: f64, steps: usize, vmoba: bool) -> E2eEstimate {
    let g: AttnGeometry = model.geometry(keep);
    let prof = if vmoba { vmoba_profile() } else { profile(kind) };
    let per_call = kernel_time(dev, kind, &g, prof).seconds;
    let attn = per_call * (model.layers * model.heads * steps) as f64;

    // full-attention reference fixes the method-independent remainder
    let full_call = kernel_time(dev, AttnKind::Full,
                                &model.geometry(1.0),
                                profile(AttnKind::Full)).seconds;
    let attn_full = full_call * (model.layers * model.heads * steps) as f64;
    let other = attn_full * (1.0 - model.attn_frac_full)
        / model.attn_frac_full;
    E2eEstimate { attention_s: attn, other_s: other }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::flops::{WAN_14B, WAN_1_3B};

    const STEPS: usize = 50;

    #[test]
    fn fig5_full_attention_split_1_3b() {
        let dev = Device::rtx5090();
        let e = estimate(&dev, &WAN_1_3B, AttnKind::Full, 1.0, STEPS, false);
        let frac = e.attention_s / e.total_s();
        assert!((frac - 0.61).abs() < 0.02, "attention fraction {frac:.2}");
    }

    #[test]
    fn fig5_e2e_speedup_1_3b() {
        // Paper: 2.30x end-to-end on Wan-1.3B with SLA2 @ 97 %.
        let dev = Device::rtx5090();
        let full = estimate(&dev, &WAN_1_3B, AttnKind::Full, 1.0, STEPS,
                            false);
        let sla2 = estimate(&dev, &WAN_1_3B, AttnKind::Sla2 { quant: true },
                            0.03, STEPS, false);
        let speedup = full.total_s() / sla2.total_s();
        assert!(speedup > 1.9 && speedup < 2.7, "e2e speedup {speedup:.2}");
    }

    #[test]
    fn fig5_e2e_speedup_14b_larger() {
        // Paper: 4.35x on the 14B model (attention-heavier at 720P).
        let dev = Device::rtx5090();
        let full = estimate(&dev, &WAN_14B, AttnKind::Full, 1.0, STEPS,
                            false);
        let sla2 = estimate(&dev, &WAN_14B, AttnKind::Sla2 { quant: true },
                            0.03, STEPS, false);
        let s14 = full.total_s() / sla2.total_s();
        let full13 = estimate(&dev, &WAN_1_3B, AttnKind::Full, 1.0, STEPS,
                              false);
        let sla13 = estimate(&dev, &WAN_1_3B, AttnKind::Sla2 { quant: true },
                             0.03, STEPS, false);
        let s13 = full13.total_s() / sla13.total_s();
        assert!(s14 > s13, "14B speedup {s14:.2} <= 1.3B {s13:.2}");
        assert!(s14 > 3.3 && s14 < 5.5, "{s14:.2}");
    }

    #[test]
    fn other_time_method_independent() {
        let dev = Device::rtx5090();
        let a = estimate(&dev, &WAN_1_3B, AttnKind::Full, 1.0, STEPS, false);
        let b = estimate(&dev, &WAN_1_3B, AttnKind::Sla2 { quant: true },
                         0.03, STEPS, false);
        assert!((a.other_s - b.other_s).abs() < 1e-9);
    }

    #[test]
    fn vmoba_e2e_slower_than_sla2() {
        let dev = Device::rtx5090();
        let vm = estimate(&dev, &WAN_1_3B, AttnKind::SparseOnly, 0.05,
                          STEPS, true);
        let sla2 = estimate(&dev, &WAN_1_3B, AttnKind::Sla2 { quant: true },
                            0.03, STEPS, false);
        assert!(vm.total_s() > sla2.total_s());
    }

    #[test]
    fn steps_scale_linearly() {
        let dev = Device::rtx5090();
        let a = estimate(&dev, &WAN_1_3B, AttnKind::Full, 1.0, 10, false);
        let b = estimate(&dev, &WAN_1_3B, AttnKind::Full, 1.0, 20, false);
        assert!((b.attention_s / a.attention_s - 2.0).abs() < 1e-9);
    }
}
