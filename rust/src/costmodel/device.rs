//! Roofline device model calibrated to the paper's RTX5090 points.
//!
//! Kernel time decomposes as
//!
//! ```text
//! t = launch + vector_t(router, quant) + linear_t
//!     + max(sparse_matmul_t, memory_t)
//! ```
//!
//! The sparse-branch matmuls target the tensor cores (INT8 when the
//! QAT path is on); the linear branch's many small `d x d` state
//! updates are bandwidth/vector bound, so they get their own
//! throughput constant — that floor is exactly why the paper's
//! measured 18.6x at 97 % sparsity is far below the 33x a pure-FLOP
//! model would predict.
//!
//! Calibration targets (paper Sec. 9.3 / Fig. 4 / Table 2):
//!   * FlashAttn2 dense baseline,
//!   * SLA2 @ 97 % = 18.7x over FlashAttn2,
//!   * SLA2 2.6x faster than VSA @ 95 %, 11.7x faster than VMoBA @ 95 %,
//!   * INT8 forward ~1.3x kernel speedup.

use super::flops::{attention_flops, AttnGeometry, AttnKind, FlopCount};

/// Device constants (an RTX5090-class accelerator).
#[derive(Debug, Clone)]
pub struct Device {
    pub name: String,
    /// dense fp16 tensor-core peak, FLOP/s
    pub peak_fp16: f64,
    /// dense int8 tensor-core peak, OP/s
    pub peak_int8: f64,
    /// elementwise / softmax / router throughput, op/s
    pub vector_ops: f64,
    /// linear-attention state-update throughput, op/s (bandwidth-bound
    /// small matmuls — far below tensor-core peak)
    pub linear_ops: f64,
    /// HBM bandwidth, bytes/s
    pub mem_bw: f64,
    /// fixed kernel launch + tail latency, seconds
    pub launch_overhead: f64,
}

impl Device {
    pub fn rtx5090() -> Device {
        Device {
            name: "RTX5090 (modelled)".into(),
            peak_fp16: 210e12,
            peak_int8: 420e12,
            vector_ops: 15e12,
            linear_ops: 30e12,
            mem_bw: 1.79e12,
            launch_overhead: 12e-6,
        }
    }

    /// A laptop-class single CPU core (sanity context for our measured
    /// interpret-mode numbers; not used for paper curves).
    pub fn cpu_core() -> Device {
        Device {
            name: "1-core CPU".into(),
            peak_fp16: 5e10,
            peak_int8: 5e10,
            vector_ops: 2e10,
            linear_ops: 2e10,
            mem_bw: 2e10,
            launch_overhead: 50e-6,
        }
    }
}

/// Per-method execution-efficiency profile (the calibration knobs).
#[derive(Debug, Clone, Copy)]
pub struct MethodProfile {
    /// fraction of tensor-core peak the sparse matmuls reach
    pub mxu_eff: f64,
    /// per-tile overhead multiplier (scheduling, mask gather, rescale)
    pub tile_overhead: f64,
    /// sparse-branch matmuls on the INT8 path?
    pub int8: bool,
}

pub fn profile(kind: AttnKind) -> MethodProfile {
    match kind {
        // FlashAttn2: dense, highly tuned
        AttnKind::Full => MethodProfile {
            mxu_eff: 0.62, tile_overhead: 1.0, int8: false },
        // VSA-like trainable block-sparse: decent but gather-limited
        AttnKind::SparseOnly => MethodProfile {
            mxu_eff: 0.45, tile_overhead: 2.0, int8: false },
        AttnKind::Sla => MethodProfile {
            mxu_eff: 0.50, tile_overhead: 1.3, int8: false },
        AttnKind::Sla2 { quant } => MethodProfile {
            mxu_eff: 0.60, tile_overhead: 1.0, int8: quant },
    }
}

/// VMoBA's token-granular gating breaks tile locality badly (the paper
/// measures it 11.7x slower than SLA2 @ 95 %).
pub fn vmoba_profile() -> MethodProfile {
    MethodProfile { mxu_eff: 0.20, tile_overhead: 4.0, int8: false }
}

#[derive(Debug, Clone, Copy)]
pub struct KernelTime {
    pub seconds: f64,
    /// effective TOPS by the paper's convention: C/t with C = 4 N^2 d
    pub effective_tops: f64,
}

/// Bytes moved by one single-head attention call (fp16 tensors).
fn attention_bytes(g: &AttnGeometry, kind: AttnKind) -> f64 {
    let nd = (g.n * g.d) as f64 * 2.0; // fp16
    let qkvo = 4.0 * nd;
    let mask = (g.t_m() * g.t_n()) as f64;
    let extra = match kind {
        AttnKind::Full => 0.0,
        // sparse/linear kernels make one extra K/V sweep (state pass)
        _ => 2.0 * nd,
    };
    qkvo + mask + extra
}

/// Roofline kernel-time estimate for one single-head attention call.
pub fn kernel_time(dev: &Device, kind: AttnKind, g: &AttnGeometry,
                   prof: MethodProfile) -> KernelTime {
    let f: FlopCount = attention_flops(kind, g);
    let peak = if prof.int8 { dev.peak_int8 } else { dev.peak_fp16 };
    let sparse_t =
        (f.sparse + f.combine) * prof.tile_overhead / (peak * prof.mxu_eff);
    let linear_t = f.linear / dev.linear_ops;
    let vector_t = (f.router + f.quant_overhead) / dev.vector_ops;
    let mem_t = attention_bytes(g, kind) / dev.mem_bw;
    let seconds = dev.launch_overhead + vector_t + linear_t
        + sparse_t.max(mem_t); // overlap sparse matmuls with HBM traffic
    let c = super::flops::full_attention_flops(g.n, g.d);
    KernelTime { seconds, effective_tops: c / seconds / 1e12 }
}

/// Convenience: kernel time with the default profile for the kind.
pub fn kernel_time_default(dev: &Device, kind: AttnKind,
                           g: &AttnGeometry) -> KernelTime {
    kernel_time(dev, kind, g, profile(kind))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::flops::FIG4_GEOM;

    fn paper_geom(keep: f64) -> AttnGeometry {
        AttnGeometry { keep, ..FIG4_GEOM }
    }

    #[test]
    fn fig4_headline_speedup() {
        // SLA2 @ 97 % vs FlashAttn2 dense: paper says 18.7x.
        let dev = Device::rtx5090();
        let full = kernel_time_default(&dev, AttnKind::Full,
                                       &paper_geom(1.0));
        let sla2 = kernel_time_default(&dev, AttnKind::Sla2 { quant: true },
                                       &paper_geom(0.03));
        let speedup = full.seconds / sla2.seconds;
        assert!(speedup > 15.0 && speedup < 23.0, "speedup {speedup:.1}");
    }

    #[test]
    fn fig4_vsa_gap() {
        // SLA2 @ 97 % is ~2.6x faster than VSA @ 95 %.
        let dev = Device::rtx5090();
        let sla2 = kernel_time_default(&dev, AttnKind::Sla2 { quant: true },
                                       &paper_geom(0.03));
        let vsa = kernel_time_default(&dev, AttnKind::SparseOnly,
                                      &paper_geom(0.05));
        let ratio = vsa.seconds / sla2.seconds;
        assert!(ratio > 1.8 && ratio < 4.0, "ratio {ratio:.2}");
    }

    #[test]
    fn fig4_vmoba_gap() {
        // SLA2 @ 97 % is ~11.7x faster than VMoBA @ 95 %.
        let dev = Device::rtx5090();
        let sla2 = kernel_time_default(&dev, AttnKind::Sla2 { quant: true },
                                       &paper_geom(0.03));
        let vmoba = kernel_time(&dev, AttnKind::SparseOnly,
                                &paper_geom(0.05), vmoba_profile());
        let ratio = vmoba.seconds / sla2.seconds;
        assert!(ratio > 8.0 && ratio < 16.0, "ratio {ratio:.2}");
    }

    #[test]
    fn quant_speedup_about_1_3x() {
        // Table 2: low-bit quantization ~1.3x kernel speedup.
        let dev = Device::rtx5090();
        let q = kernel_time_default(&dev, AttnKind::Sla2 { quant: true },
                                    &paper_geom(0.03));
        let nq = kernel_time_default(&dev, AttnKind::Sla2 { quant: false },
                                     &paper_geom(0.03));
        let ratio = nq.seconds / q.seconds;
        assert!(ratio > 1.15 && ratio < 1.5, "ratio {ratio:.2}");
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let dev = Device::rtx5090();
        let t = |keep| kernel_time_default(
            &dev, AttnKind::Sla2 { quant: true }, &paper_geom(keep)).seconds;
        assert!(t(0.03) < t(0.05));
        assert!(t(0.05) < t(0.10));
        assert!(t(0.10) < t(1.0));
    }

    #[test]
    fn speedup_saturates_memory_bound() {
        // At extreme sparsity the linear/memory/overhead floor caps the
        // win: 99.9 % sparse must NOT be ~1000x faster than dense.
        let dev = Device::rtx5090();
        let full = kernel_time_default(&dev, AttnKind::Full,
                                       &paper_geom(1.0)).seconds;
        let tiny = kernel_time_default(
            &dev, AttnKind::Sla2 { quant: true }, &paper_geom(0.001))
            .seconds;
        assert!(full / tiny < 60.0, "unbounded speedup {}", full / tiny);
    }

    #[test]
    fn effective_tops_convention() {
        let dev = Device::rtx5090();
        let g = paper_geom(1.0);
        let kt = kernel_time_default(&dev, AttnKind::Full, &g);
        let c = super::super::flops::full_attention_flops(g.n, g.d);
        assert!((kt.effective_tops - c / kt.seconds / 1e12).abs() < 1e-9);
    }

    #[test]
    fn fa2_absolute_tops_plausible() {
        // FlashAttn2 on a 210-TFLOPs-class part should land in the
        // 100-150 effective-TOPS band (Fig. 4's y-axis scale).
        let dev = Device::rtx5090();
        let kt = kernel_time_default(&dev, AttnKind::Full, &paper_geom(1.0));
        assert!(kt.effective_tops > 90.0 && kt.effective_tops < 160.0,
                "{:.0} TOPS", kt.effective_tops);
    }
}
