//! Property tests on coordinator invariants (no PJRT needed):
//! no request loss/duplication, batch compatibility, FIFO order for
//! the remainder, backpressure bounds, batch planning exactness.

use std::collections::HashSet;
use std::sync::mpsc::channel;
use std::time::Duration;

use sla2::coordinator::queue::RequestQueue;
use sla2::coordinator::request::{Envelope, GenRequest};
use sla2::coordinator::plan_batches;
use sla2::util::proptest::check;
use sla2::util::rng::Pcg32;

fn env(id: u64, tier: &str, steps: usize) -> Envelope {
    let (tx, rx) = channel();
    std::mem::forget(rx);
    Envelope { request: GenRequest::new(id, 0, id, steps, tier), reply: tx }
}

const TIERS: [&str; 3] = ["s90", "s95", "s97"];

#[test]
fn prop_no_request_lost_or_duplicated() {
    check("queue-conservation", 64,
          |r: &mut Pcg32| {
              (0..(1 + r.below(30) as u64))
                  .map(|id| (id, *r.choice(&TIERS),
                             if r.f32() < 0.5 { 4 } else { 8 }))
                  .collect::<Vec<_>>()
          },
          |reqs| {
              let q = RequestQueue::new(1024);
              for (id, tier, steps) in reqs {
                  q.push(env(*id, tier, *steps)).map_err(|e| e.to_string())?;
              }
              let mut seen = HashSet::new();
              let mut drained = 0usize;
              while drained < reqs.len() {
                  let b = q.pop_batch(4, Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("queue closed early")?;
                  if b.is_empty() {
                      return Err("timeout before drain complete".into());
                  }
                  for e in &b {
                      if !seen.insert(e.request.id) {
                          return Err(format!("duplicate id {}",
                                             e.request.id));
                      }
                  }
                  drained += b.len();
              }
              if seen.len() != reqs.len() {
                  return Err(format!("lost requests: {} of {}",
                                     seen.len(), reqs.len()));
              }
              Ok(())
          });
}

#[test]
fn prop_batches_are_homogeneous() {
    check("batch-compat", 64,
          |r: &mut Pcg32| {
              (0..(1 + r.below(25) as u64))
                  .map(|id| (id, *r.choice(&TIERS),
                             if r.f32() < 0.5 { 4 } else { 8 }))
                  .collect::<Vec<_>>()
          },
          |reqs| {
              let q = RequestQueue::new(1024);
              for (id, tier, steps) in reqs {
                  q.push(env(*id, tier, *steps)).map_err(|e| e.to_string())?;
              }
              let mut drained = 0;
              while drained < reqs.len() {
                  let b = q.pop_batch(3, Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout".into());
                  }
                  if b.len() > 3 {
                      return Err(format!("batch too big: {}", b.len()));
                  }
                  let first = &b[0].request;
                  for e in &b[1..] {
                      if !e.request.compatible(first) {
                          return Err(format!(
                              "incompatible batch: {:?}/{} with {:?}/{}",
                              first.tier, first.steps, e.request.tier,
                              e.request.steps));
                      }
                  }
                  drained += b.len();
              }
              Ok(())
          });
}

#[test]
fn prop_first_request_fifo() {
    // the head of every popped batch is the oldest pending request
    check("fifo-head", 64,
          |r: &mut Pcg32| {
              (0..(1 + r.below(20) as u64))
                  .map(|id| (id, *r.choice(&TIERS)))
                  .collect::<Vec<_>>()
          },
          |reqs| {
              let q = RequestQueue::new(1024);
              for (id, tier) in reqs {
                  q.push(env(*id, tier, 8)).map_err(|e| e.to_string())?;
              }
              let mut expected_heads: Vec<u64> = Vec::new();
              let mut pending: Vec<(u64, String)> = reqs.iter()
                  .map(|(i, t)| (*i, t.to_string())).collect();
              while !pending.is_empty() {
                  let b = q.pop_batch(4, Duration::from_millis(50),
                                      Duration::ZERO).ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout".into());
                  }
                  // head must be the oldest pending
                  if b[0].request.id != pending[0].0 {
                      return Err(format!("head {} != oldest {}",
                                         b[0].request.id, pending[0].0));
                  }
                  expected_heads.push(b[0].request.id);
                  let taken: HashSet<u64> =
                      b.iter().map(|e| e.request.id).collect();
                  pending.retain(|(id, _)| !taken.contains(id));
              }
              Ok(())
          });
}

#[test]
fn prop_backpressure_never_exceeds_capacity() {
    check("backpressure", 32,
          |r: &mut Pcg32| (1 + r.below(8) as usize,
                           r.below(40) as usize),
          |(cap, n)| {
              let q = RequestQueue::new(*cap);
              let mut accepted = 0;
              for i in 0..*n {
                  if q.push(env(i as u64, "s95", 8)).is_ok() {
                      accepted += 1;
                  }
                  if q.len() > *cap {
                      return Err(format!("len {} > cap {cap}", q.len()));
                  }
              }
              if accepted > *cap {
                  return Err(format!("accepted {accepted} > cap {cap}"));
              }
              Ok(())
          });
}

#[test]
fn prop_plan_batches_exact_cover() {
    check("plan-exact", 128,
          |r: &mut Pcg32| {
              let n = r.below(64) as usize;
              let mut sizes = vec![1];
              for s in [2, 3, 4, 8] {
                  if r.f32() < 0.5 {
                      sizes.push(s);
                  }
              }
              (n, sizes)
          },
          |(n, sizes)| {
              let plan = plan_batches(*n, sizes);
              let total: usize = plan.iter().sum();
              if total != *n {
                  return Err(format!("covered {total}, wanted {n}"));
              }
              if plan.iter().any(|s| !sizes.contains(s)) {
                  return Err("unsupported batch size in plan".into());
              }
              Ok(())
          });
}
