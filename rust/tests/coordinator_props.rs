//! Property tests on coordinator invariants (no PJRT needed):
//! no request loss/duplication, batch compatibility, FIFO order for
//! the remainder, backpressure bounds, scheduler-policy invariants
//! (per-class FIFO, anti-starvation, fifo-mode bit-for-bit parity),
//! batch planning exactness, and engine-pool dispatch under
//! concurrent load (mock processor) including warm-shard compile
//! dedup.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use sla2::coordinator::pool::{BatchProcessor, EnginePool};
use sla2::coordinator::queue::{RequestQueue, SchedPolicy,
                               MAX_BYPASS_STREAK};
use sla2::coordinator::request::{Envelope, GenRequest, GenResponse,
                                 RequestMetrics};
use sla2::coordinator::plan_batches;
use sla2::coordinator::ServerMetrics;
use sla2::tensor::Tensor;
use sla2::util::proptest::check;
use sla2::util::rng::Pcg32;

type Reply = Receiver<anyhow::Result<GenResponse>>;

/// Build an envelope, stashing the reply receiver in `keep` so it
/// stays alive for the envelope's lifetime (the seed's helper leaked
/// it via `mem::forget`).
fn env(keep: &mut Vec<Reply>, id: u64, tier: &str, steps: usize)
       -> Envelope {
    let (tx, rx) = channel();
    keep.push(rx);
    Envelope::oneshot(GenRequest::new(id, 0, id, steps, tier), tx)
}

const TIERS: [&str; 3] = ["s90", "s95", "s97"];

#[test]
fn prop_no_request_lost_or_duplicated() {
    check("queue-conservation", 64,
          |r: &mut Pcg32| {
              (0..(1 + r.below(30) as u64))
                  .map(|id| (id, *r.choice(&TIERS),
                             if r.f32() < 0.5 { 4 } else { 8 }))
                  .collect::<Vec<_>>()
          },
          |reqs| {
              let q = RequestQueue::new(1024);
              let mut keep = Vec::new();
              for (id, tier, steps) in reqs {
                  q.push(env(&mut keep, *id, tier, *steps))
                      .map_err(|e| e.to_string())?;
              }
              let mut seen = HashSet::new();
              let mut drained = 0usize;
              while drained < reqs.len() {
                  let b = q.pop_batch(4, Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("queue closed early")?;
                  if b.is_empty() {
                      return Err("timeout before drain complete".into());
                  }
                  for e in &b {
                      if !seen.insert(e.request.id) {
                          return Err(format!("duplicate id {}",
                                             e.request.id));
                      }
                  }
                  drained += b.len();
              }
              if seen.len() != reqs.len() {
                  return Err(format!("lost requests: {} of {}",
                                     seen.len(), reqs.len()));
              }
              Ok(())
          });
}

#[test]
fn prop_batches_are_homogeneous() {
    check("batch-compat", 64,
          |r: &mut Pcg32| {
              (0..(1 + r.below(25) as u64))
                  .map(|id| (id, *r.choice(&TIERS),
                             if r.f32() < 0.5 { 4 } else { 8 }))
                  .collect::<Vec<_>>()
          },
          |reqs| {
              let q = RequestQueue::new(1024);
              let mut keep = Vec::new();
              for (id, tier, steps) in reqs {
                  q.push(env(&mut keep, *id, tier, *steps))
                      .map_err(|e| e.to_string())?;
              }
              let mut drained = 0;
              while drained < reqs.len() {
                  let b = q.pop_batch(3, Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout".into());
                  }
                  if b.len() > 3 {
                      return Err(format!("batch too big: {}", b.len()));
                  }
                  let first = &b[0].request;
                  for e in &b[1..] {
                      if !e.request.compatible(first) {
                          return Err(format!(
                              "incompatible batch: {:?}/{} with {:?}/{}",
                              first.tier, first.steps, e.request.tier,
                              e.request.steps));
                      }
                  }
                  drained += b.len();
              }
              Ok(())
          });
}

#[test]
fn prop_first_request_fifo() {
    // the head of every popped batch is the oldest pending request
    check("fifo-head", 64,
          |r: &mut Pcg32| {
              (0..(1 + r.below(20) as u64))
                  .map(|id| (id, *r.choice(&TIERS)))
                  .collect::<Vec<_>>()
          },
          |reqs| {
              let q = RequestQueue::new(1024);
              let mut keep = Vec::new();
              for (id, tier) in reqs {
                  q.push(env(&mut keep, *id, tier, 8))
                      .map_err(|e| e.to_string())?;
              }
              let mut expected_heads: Vec<u64> = Vec::new();
              let mut pending: Vec<(u64, String)> = reqs.iter()
                  .map(|(i, t)| (*i, t.to_string())).collect();
              while !pending.is_empty() {
                  let b = q.pop_batch(4, Duration::from_millis(50),
                                      Duration::ZERO).ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout".into());
                  }
                  // head must be the oldest pending
                  if b[0].request.id != pending[0].0 {
                      return Err(format!("head {} != oldest {}",
                                         b[0].request.id, pending[0].0));
                  }
                  expected_heads.push(b[0].request.id);
                  let taken: HashSet<u64> =
                      b.iter().map(|e| e.request.id).collect();
                  pending.retain(|(id, _)| !taken.contains(id));
              }
              Ok(())
          });
}

#[test]
fn prop_backpressure_never_exceeds_capacity() {
    check("backpressure", 32,
          |r: &mut Pcg32| (1 + r.below(8) as usize,
                           r.below(40) as usize),
          |(cap, n)| {
              let q = RequestQueue::new(*cap);
              let mut keep = Vec::new();
              let mut accepted = 0;
              for i in 0..*n {
                  // rotate classes: capacity must bound the TOTAL
                  // across class buckets, not any single class
                  let tier = TIERS[i % TIERS.len()];
                  if q.push(env(&mut keep, i as u64, tier, 8)).is_ok() {
                      accepted += 1;
                  }
                  if q.len() > *cap {
                      return Err(format!("len {} > cap {cap}", q.len()));
                  }
              }
              if accepted > *cap {
                  return Err(format!("accepted {accepted} > cap {cap}"));
              }
              Ok(())
          });
}

// ---------------- scheduler-policy invariants -----------------------

#[test]
fn prop_class_policy_preserves_per_class_fifo() {
    // whatever the bypass policy does ACROSS classes, requests WITHIN
    // a class must always be served in arrival order
    check("class-fifo", 48,
          |r: &mut Pcg32| {
              let max_batch = 1 + r.below(4) as usize;
              let threshold_ms = r.below(3) as u64; // 0..2ms: jumpy
              let reqs: Vec<(u64, &str, usize)> =
                  (0..(1 + r.below(30) as u64))
                      .map(|id| (id, if r.f32() < 0.3 { "dense" }
                                     else { *r.choice(&TIERS) },
                                 if r.f32() < 0.5 { 4 } else { 8 }))
                      .collect();
              (max_batch, threshold_ms, reqs)
          },
          |(max_batch, threshold_ms, reqs)| {
              let q = RequestQueue::with_policy(
                  1024,
                  SchedPolicy::ClassAware {
                      bypass_threshold:
                          Duration::from_millis(*threshold_ms),
                  });
              let mut keep = Vec::new();
              for (id, tier, steps) in reqs {
                  q.push(env(&mut keep, *id, tier, *steps))
                      .map_err(|e| e.to_string())?;
              }
              let mut served: HashMap<(String, usize), Vec<u64>> =
                  HashMap::new();
              let mut drained = 0usize;
              while drained < reqs.len() {
                  let b = q.pop_batch(*max_batch,
                                      Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout before drain".into());
                  }
                  for e in &b {
                      served.entry((e.request.tier.clone(),
                                    e.request.steps))
                          .or_default()
                          .push(e.request.id);
                  }
                  drained += b.len();
              }
              // ids were pushed in increasing order, so per-class
              // serve order must be strictly increasing
              for (class, ids) in &served {
                  if ids.windows(2).any(|w| w[0] >= w[1]) {
                      return Err(format!(
                          "class {class:?} served out of order: \
                           {ids:?}"));
                  }
              }
              Ok(())
          });
}

#[test]
fn prop_fifo_mode_matches_reference_scan_bit_for_bit() {
    // the seed's algorithm: pop the global head, then scan the whole
    // queue in arrival order collecting compatible requests up to
    // max_batch.  Class buckets + oldest-head selection must
    // reproduce its served sequence EXACTLY.
    check("fifo-parity", 64,
          |r: &mut Pcg32| {
              let max_batch = 1 + r.below(4) as usize;
              let reqs: Vec<(u64, &str, usize)> =
                  (0..(1 + r.below(30) as u64))
                      .map(|id| (id, if r.f32() < 0.25 { "dense" }
                                     else { *r.choice(&TIERS) },
                                 if r.f32() < 0.5 { 4 } else { 8 }))
                      .collect();
              (max_batch, reqs)
          },
          |(max_batch, reqs)| {
              let q = RequestQueue::with_policy(1024, SchedPolicy::Fifo);
              let mut keep = Vec::new();
              for (id, tier, steps) in reqs {
                  q.push(env(&mut keep, *id, tier, *steps))
                      .map_err(|e| e.to_string())?;
              }
              // reference model over (id, tier, steps)
              let mut model: Vec<(u64, &str, usize)> = reqs.clone();
              let mut drained = 0usize;
              while drained < reqs.len() {
                  let b = q.pop_batch(*max_batch,
                                      Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout before drain".into());
                  }
                  let mut expect: Vec<u64> = Vec::new();
                  let (_, htier, hsteps) = model[0];
                  let mut rest = Vec::new();
                  for &(id, tier, steps) in model.iter() {
                      if expect.len() < *max_batch && tier == htier
                          && steps == hsteps
                      {
                          expect.push(id);
                      } else {
                          rest.push((id, tier, steps));
                      }
                  }
                  model = rest;
                  let got: Vec<u64> =
                      b.iter().map(|e| e.request.id).collect();
                  if got != expect {
                      return Err(format!(
                          "fifo divergence: got {got:?}, reference \
                           {expect:?}"));
                  }
                  drained += b.len();
              }
              Ok(())
          });
}

#[test]
fn prop_no_class_starves_under_adversarial_arrivals() {
    // threshold 0 makes every cheaper class bypass-eligible on every
    // pop; a continuous sparse arrival stream is the worst case for
    // the dense head.  The streak cap must still serve it within
    // MAX_BYPASS_STREAK + 1 pops.
    check("no-starvation", 32,
          |r: &mut Pcg32| {
              let dense_steps = if r.f32() < 0.5 { 4 } else { 8 };
              let sparse_tier = *r.choice(&TIERS);
              (dense_steps, sparse_tier)
          },
          |(dense_steps, sparse_tier)| {
              let q = RequestQueue::with_policy(
                  1024,
                  SchedPolicy::ClassAware {
                      bypass_threshold: Duration::ZERO,
                  });
              let mut keep = Vec::new();
              q.push(env(&mut keep, 1000, "dense", *dense_steps))
                  .map_err(|e| e.to_string())?;
              let mut next = 0u64;
              for pops in 1.. {
                  q.push(env(&mut keep, next, sparse_tier, 4))
                      .map_err(|e| e.to_string())?;
                  next += 1;
                  let b = q.pop_batch(1, Duration::from_millis(50),
                                      Duration::ZERO)
                      .ok_or("closed")?;
                  if b.is_empty() {
                      return Err("timeout".into());
                  }
                  if b[0].request.tier == "dense" {
                      return Ok(()); // served within the bound below
                  }
                  if pops > MAX_BYPASS_STREAK as usize + 1 {
                      return Err(format!(
                          "dense head still starved after {pops} \
                           pops (cap {MAX_BYPASS_STREAK})"));
                  }
              }
              unreachable!()
          });
}

// ---------------- engine-pool dispatch ------------------------------

/// Host-only processor: flags invariant violations, optionally burns
/// wall time (to force shard overlap) or panics on marked requests.
struct MockProcessor {
    work: Duration,
    incompatible_batch_seen: Arc<AtomicBool>,
    missing_dequeue_stamp: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
    max_overlap: Arc<AtomicUsize>,
}

impl BatchProcessor for MockProcessor {
    fn process(&mut self, reqs: &[GenRequest])
               -> anyhow::Result<Vec<(Tensor, RequestMetrics)>> {
        let cur = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_overlap.fetch_max(cur, Ordering::SeqCst);
        if reqs.windows(2).any(|w| !w[0].compatible(&w[1])) {
            self.incompatible_batch_seen.store(true, Ordering::Relaxed);
        }
        if reqs.iter().any(|r| r.dequeued_at.is_none()) {
            self.missing_dequeue_stamp.store(true, Ordering::Relaxed);
        }
        // class_label == -1 marks a poison request (panic-safety test)
        if reqs.iter().any(|r| r.class_label == -1) {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            panic!("poison request");
        }
        if !self.work.is_zero() {
            std::thread::sleep(self.work);
        }
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        Ok(reqs.iter()
            .map(|r| (Tensor::zeros(&[1]), RequestMetrics {
                queue_ms: r.queue_wait_ms(),
                compute_ms: self.work.as_secs_f64() * 1e3,
                steps: r.steps,
                batch_size: reqs.len(),
            }))
            .collect())
    }
}

struct MockPool {
    queue: Arc<RequestQueue>,
    metrics: Arc<Mutex<ServerMetrics>>,
    pool: EnginePool,
    incompatible_batch_seen: Arc<AtomicBool>,
    missing_dequeue_stamp: Arc<AtomicBool>,
    max_overlap: Arc<AtomicUsize>,
}

fn mock_pool(shards: usize, max_batch: usize, work: Duration) -> MockPool {
    let queue = Arc::new(RequestQueue::new(1024));
    let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
    let incompatible = Arc::new(AtomicBool::new(false));
    let missing = Arc::new(AtomicBool::new(false));
    let in_flight = Arc::new(AtomicUsize::new(0));
    let max_overlap = Arc::new(AtomicUsize::new(0));
    let (inc, mis) = (Arc::clone(&incompatible), Arc::clone(&missing));
    let (inf, ovl) = (Arc::clone(&in_flight), Arc::clone(&max_overlap));
    let pool = EnginePool::start_with(
        shards, Arc::clone(&queue), Arc::clone(&metrics), max_batch,
        Duration::ZERO,
        move |_shard| Ok(MockProcessor {
            work,
            incompatible_batch_seen: Arc::clone(&inc),
            missing_dequeue_stamp: Arc::clone(&mis),
            in_flight: Arc::clone(&inf),
            max_overlap: Arc::clone(&ovl),
        }))
        .expect("mock pool start");
    MockPool { queue, metrics, pool,
               incompatible_batch_seen: incompatible,
               missing_dequeue_stamp: missing,
               max_overlap }
}

#[test]
fn prop_pool_dispatch_under_concurrent_load() {
    check("pool-dispatch", 24,
          |r: &mut Pcg32| {
              let shards = 1 + r.below(3) as usize;
              let max_batch = 1 + r.below(4) as usize;
              let reqs: Vec<(u64, &str, usize)> =
                  (0..(1 + r.below(24) as u64))
                      .map(|id| (id, *r.choice(&TIERS),
                                 if r.f32() < 0.5 { 4 } else { 8 }))
                      .collect();
              (shards, max_batch, reqs)
          },
          |(shards, max_batch, reqs)| {
              let mp = mock_pool(*shards, *max_batch, Duration::ZERO);
              // concurrent producers: split the wave across two threads
              let mut rxs = Vec::new();
              let mut envs = Vec::new();
              for (id, tier, steps) in reqs {
                  let (tx, rx) = channel();
                  rxs.push(rx);
                  envs.push(Envelope::oneshot(
                      GenRequest::new(*id, 0, *id, *steps, tier), tx));
              }
              let tail = envs.split_off(envs.len() / 2);
              let (q1, q2) = (Arc::clone(&mp.queue), Arc::clone(&mp.queue));
              let p1 = std::thread::spawn(move || {
                  for e in envs {
                      q1.push(e).expect("push");
                  }
              });
              let p2 = std::thread::spawn(move || {
                  for e in tail {
                      q2.push(e).expect("push");
                  }
              });
              p1.join().unwrap();
              p2.join().unwrap();
              // exactly one reply per request, queue wait >= 0
              for rx in rxs {
                  let resp = rx.recv()
                      .map_err(|_| "reply channel dropped".to_string())?
                      .map_err(|e| format!("request failed: {e}"))?;
                  if resp.metrics.queue_ms < 0.0 {
                      return Err(format!("negative queue_ms: {}",
                                         resp.metrics.queue_ms));
                  }
              }
              // graceful shutdown: close joins every shard
              mp.queue.close();
              drop(mp.pool);
              if mp.incompatible_batch_seen.load(Ordering::Relaxed) {
                  return Err("pool dispatched an incompatible \
                              batch".into());
              }
              if mp.missing_dequeue_stamp.load(Ordering::Relaxed) {
                  return Err("a request reached a shard without a \
                              dequeue stamp".into());
              }
              let m = mp.metrics.lock().unwrap();
              if m.completed != reqs.len() as u64 {
                  return Err(format!("completed {} of {}", m.completed,
                                     reqs.len()));
              }
              Ok(())
          });
}

#[test]
fn pool_overlaps_shards_under_load() {
    // 8 x 20ms jobs over 2 shards: with the queue saturated, the two
    // shards must at some point process concurrently.  Asserted via
    // an in-flight high-water mark, not wall time; a few bounded
    // retry waves absorb the (pathological) case of a shard thread
    // being descheduled through an entire wave on a loaded runner.
    let work = Duration::from_millis(20);
    let mp = mock_pool(2, 1, work);
    let mut served = 0u64;
    for wave in 0..5u64 {
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            let (tx, rx) = channel();
            rxs.push(rx);
            mp.queue.push(Envelope::oneshot(
                GenRequest::new(wave * 8 + i, 0, i, 4, "s90"), tx))
                .unwrap();
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        served += 8;
        if mp.max_overlap.load(Ordering::SeqCst) >= 2 {
            break;
        }
    }
    mp.queue.close();
    let stats = mp.pool.stats();
    assert_eq!(stats.iter()
                   .map(|s| s.requests.load(Ordering::Relaxed))
                   .sum::<u64>(), served);
    drop(mp.pool);
    // overlap >= 2 implies both shards served work: a shard runs one
    // batch at a time, so two concurrent process() calls are two
    // distinct shards
    assert!(mp.max_overlap.load(Ordering::SeqCst) >= 2,
            "shards never processed concurrently across 5 saturated \
             waves");
}

#[test]
fn pool_survives_panicking_processor() {
    let mp = mock_pool(2, 1, Duration::ZERO);
    // poison request: class_label == -1 makes the mock panic
    let (ptx, prx) = channel();
    mp.queue.push(Envelope::oneshot(
        GenRequest::new(1, -1, 1, 4, "s90"), ptx)).unwrap();
    let poisoned = prx.recv().expect("reply must arrive, not be dropped");
    assert!(poisoned.is_err(), "panicked batch must surface an error");
    // the pool keeps serving afterwards
    let mut rxs = Vec::new();
    for id in 2..6u64 {
        let (tx, rx) = channel();
        rxs.push(rx);
        mp.queue.push(Envelope::oneshot(
            GenRequest::new(id, 0, id, 4, "s90"), tx)).unwrap();
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    mp.queue.close();
    drop(mp.pool);
    assert_eq!(mp.metrics.lock().unwrap().completed, 4);
}

/// Mock that "compiles" once per distinct compatibility class it
/// sees, like a real engine's per-shard executable cache: the pool's
/// `counters()` rollup then reports distinct-classes-served per shard.
struct CompileCountingProcessor {
    seen: HashSet<(String, usize)>,
    total_compiles: Arc<AtomicU64>,
}

impl BatchProcessor for CompileCountingProcessor {
    fn process(&mut self, reqs: &[GenRequest])
               -> anyhow::Result<Vec<(Tensor, RequestMetrics)>> {
        let key = (reqs[0].tier.clone(), reqs[0].steps);
        if self.seen.insert(key) {
            self.total_compiles.fetch_add(1, Ordering::SeqCst);
        }
        Ok(reqs.iter()
            .map(|r| (Tensor::zeros(&[1]), RequestMetrics {
                queue_ms: r.queue_wait_ms(),
                compute_ms: 0.0,
                steps: r.steps,
                batch_size: reqs.len(),
            }))
            .collect())
    }

    fn counters(&self) -> (u64, u64) {
        (self.seen.len() as u64, 0)
    }
}

#[test]
fn warm_shard_affinity_compiles_each_class_about_once() {
    // 3 shards, 3 classes, requests submitted strictly one at a time:
    // after each class's first (cold) route, the dispatcher must keep
    // routing it to a shard that already compiled it.  Without
    // affinity the steady state drifts toward classes x shards = 9
    // compiles; with it, compiles stay at the number of distinct
    // classes (one extra tolerated for an idle-token race on the very
    // first repeat).
    let shards = 3;
    let queue = Arc::new(RequestQueue::new(1024));
    let metrics = Arc::new(Mutex::new(ServerMetrics::new()));
    let total = Arc::new(AtomicU64::new(0));
    let t2 = Arc::clone(&total);
    let pool = EnginePool::start_with(
        shards, Arc::clone(&queue), Arc::clone(&metrics), 2,
        Duration::ZERO,
        move |_shard| Ok(CompileCountingProcessor {
            seen: HashSet::new(),
            total_compiles: Arc::clone(&t2),
        }))
        .expect("pool start");
    let classes: [(&str, usize); 3] =
        [("s90", 4), ("s97", 4), ("dense", 8)];
    for round in 0..8u64 {
        for (ci, (tier, steps)) in classes.iter().enumerate() {
            let (tx, rx) = channel();
            queue.push(Envelope::oneshot(
                GenRequest::new(round * 10 + ci as u64, 0, 1, *steps,
                                tier), tx)).unwrap();
            rx.recv().unwrap().unwrap(); // strictly sequential
            // let the shard's idle announcement land before the next
            // dispatch decision (de-races the affinity pick)
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    queue.close();
    let stats = pool.stats().to_vec();
    drop(pool); // joins every shard: counter stores are done
    let per_shard: u64 = stats.iter()
        .map(|s| s.compiles.load(Ordering::Relaxed))
        .sum();
    let compiled = total.load(Ordering::SeqCst);
    assert_eq!(per_shard, compiled,
               "shard rollup must agree with the mock's global count");
    assert!(compiled >= classes.len() as u64,
            "every class compiles at least once");
    assert!(compiled <= classes.len() as u64 + 1,
            "steady-state compiles must track distinct classes \
             (got {compiled} for {} classes on {shards} shards — \
              N x duplication means affinity is broken)",
            classes.len());
    assert_eq!(metrics.lock().unwrap().completed, 24);
}

#[test]
fn prop_plan_batches_exact_cover() {
    check("plan-exact", 128,
          |r: &mut Pcg32| {
              let n = r.below(64) as usize;
              let mut sizes = vec![1];
              for s in [2, 3, 4, 8] {
                  if r.f32() < 0.5 {
                      sizes.push(s);
                  }
              }
              (n, sizes)
          },
          |(n, sizes)| {
              let plan = plan_batches(*n, sizes);
              let total: usize = plan.iter().sum();
              if total != *n {
                  return Err(format!("covered {total}, wanted {n}"));
              }
              if plan.iter().any(|s| !sizes.contains(s)) {
                  return Err("unsupported batch size in plan".into());
              }
              Ok(())
          });
}
