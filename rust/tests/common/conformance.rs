//! Shared attention-conformance harness: one parity suite that every
//! native attention variant (`sla2`, `sparge2`, `svg_ear`, ...) runs
//! unchanged.
//!
//! The contract it pins is the acceptance criterion from the paper's
//! evaluation: at >= 90% block sparsity, a variant's output matches
//! the naive full-softmax reference within `rel_err < 1e-3` on seeded
//! peaked inputs (exact f32 path; the INT8 path gets a quantization
//! allowance), across both served head geometries and several seeds.
//!
//! Self-contained on purpose: only `sla2::` and `std`, no sibling test
//! modules — benches include this file directly via `#[path]` so the
//! fig4 variant shoot-out measures rel_err with the SAME reference
//! and input generator the tests gate on.

use sla2::runtime::native::attention;
use sla2::util::rng::Pcg32;

/// One attention head geometry the conformance suite runs on.
#[derive(Debug, Clone, Copy)]
pub struct HeadShape {
    pub name: &'static str,
    /// tokens
    pub n: usize,
    /// head dim
    pub d: usize,
    /// query block size
    pub b_q: usize,
    /// key block size
    pub b_k: usize,
}

impl HeadShape {
    /// (query blocks, key blocks)
    pub fn tiles(&self) -> (usize, usize) {
        (self.n / self.b_q, self.n / self.b_k)
    }
}

/// The served head geometries every variant must pass on.  The
/// "dit-tiny-like" shape keeps dit-tiny's tile sizes but enough key
/// blocks (t_n = 16) that the s95 keep-1 mask reaches 93.75% block
/// sparsity — true dit-tiny (t_n = 8) tops out at 87.5%, below the
/// acceptance bar.  "dit-small-head" is dit-small's real head shape.
pub const SHAPES: [HeadShape; 2] = [
    HeadShape { name: "dit-tiny-like", n: 64, d: 32, b_q: 8, b_k: 4 },
    HeadShape { name: "dit-small-head", n: 256, d: 64, b_q: 32, b_k: 16 },
];

/// Input seeds the suite sweeps (>= 3, per the acceptance criterion).
pub const SEEDS: [u64; 3] = [42, 1337, 2024];

/// Peak amplitude for [`peaked_qkv`] in the conformance sweep: large
/// enough that the mass outside the hot block is < 1e-4 even on the
/// d = 64 shape (score gap amp^2/sqrt(d) = 12.5), so a pure top-k
/// variant with no linear compensation can meet the 1e-3 bound.
pub const PEAK_AMP: f32 = 10.0;

/// Relative L2 error of `a` against reference `b`.
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
    num.sqrt() / (den.sqrt() + 1e-9)
}

/// Exact d x d identity matrix (f32).
pub fn eye(d: usize) -> Vec<f32> {
    (0..d * d).map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 }).collect()
}

/// Naive O(N^2) full-softmax attention on the host — the reference
/// every variant is measured against.
pub fn naive_attention(q: &[f32], k: &[f32], v: &[f32], n: usize,
                       d: usize) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    let mut row = vec![0.0f32; n];
    for i in 0..n {
        let mut mx = f32::NEG_INFINITY;
        for j in 0..n {
            let mut s = 0.0;
            for a in 0..d {
                s += q[i * d + a] * k[j * d + a];
            }
            row[j] = s * scale;
            mx = mx.max(row[j]);
        }
        let mut denom = 0.0;
        for j in 0..n {
            row[j] = (row[j] - mx).exp();
            denom += row[j];
        }
        for j in 0..n {
            let p = row[j] / denom;
            for a in 0..d {
                out[i * d + a] += p * v[j * d + a];
            }
        }
    }
    out
}

/// Build (q, k, v) whose attention is concentrated inside one key
/// block per query block: query block `i` points along basis vector
/// `e_i`, key block `2i` matches it (hot), odd key blocks point along
/// unrelated directions (cold).  The probability mass outside the hot
/// block is then exponentially small, so the paper's decomposition
/// bound (error <= dropped mass) makes a >= 90%-sparse variant
/// reconstruct full attention almost exactly — the property the
/// conformance suite pins.
pub fn peaked_qkv(n: usize, d: usize, b_q: usize, b_k: usize, amp: f32,
                  seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (t_m, t_n) = (n / b_q, n / b_k);
    assert_eq!(t_n, 2 * t_m, "construction pairs block i with block 2i");
    assert!(d >= t_m + t_n / 2, "needs enough orthogonal directions");
    let mut rng = Pcg32::seeded(seed);
    let noise = 0.01f32;
    let mut q = vec![0.0f32; n * d];
    for i in 0..t_m {
        for r in 0..b_q {
            let row = &mut q[(i * b_q + r) * d..(i * b_q + r + 1) * d];
            for v in row.iter_mut() {
                *v = noise * rng.normal();
            }
            row[i] += amp;
        }
    }
    let mut k = vec![0.0f32; n * d];
    for j in 0..t_n {
        // hot blocks are even: block 2i matches query direction i;
        // odd blocks get directions no query points along
        let dir = if j % 2 == 0 { j / 2 } else { t_m + j / 2 };
        for r in 0..b_k {
            let row = &mut k[(j * b_k + r) * d..(j * b_k + r + 1) * d];
            for v in row.iter_mut() {
                *v = noise * rng.normal();
            }
            row[dir] += amp;
        }
    }
    let v = rng.normal_vec(n * d);
    (q, k, v)
}

/// Block sparsity a tier's `k_pct` yields on `shape` (fraction of key
/// blocks NOT kept by the top-k budget).
pub fn block_sparsity(k_pct: f64, shape: &HeadShape) -> f64 {
    let (_, t_n) = shape.tiles();
    1.0 - attention::top_k_count(k_pct, t_n) as f64 / t_n as f64
}

/// Run one variant through the shared parity suite: peaked inputs on
/// every shape in [`SHAPES`] x every seed in [`SEEDS`], output
/// compared to [`naive_attention`] under `tol`.  `min_sparsity`
/// asserts the claim is earned — the suite refuses to pass a variant
/// whose `k_pct` keeps too many blocks on these geometries.
///
/// `attn` is the variant under test: `(q, k, v, shape) -> output`.
pub fn check_conformance<F>(label: &str, k_pct: f64, min_sparsity: f64,
                            tol: f64, attn: F)
where
    F: Fn(&[f32], &[f32], &[f32], &HeadShape) -> Vec<f32>,
{
    for shape in &SHAPES {
        let sparsity = block_sparsity(k_pct, shape);
        assert!(sparsity >= min_sparsity,
                "{label} on {}: k_pct={k_pct} reaches only {sparsity:.4} \
                 block sparsity (suite requires >= {min_sparsity})",
                shape.name);
        for &seed in &SEEDS {
            let (q, k, v) = peaked_qkv(shape.n, shape.d, shape.b_q,
                                       shape.b_k, PEAK_AMP, seed);
            let full = naive_attention(&q, &k, &v, shape.n, shape.d);
            let out = attn(&q, &k, &v, shape);
            assert_eq!(out.len(), full.len(),
                       "{label} on {}: wrong output size", shape.name);
            let err = rel_err(&out, &full);
            assert!(err < tol,
                    "{label} on {} seed {seed}: rel_err {err} vs full \
                     softmax at {sparsity:.4} sparsity (bound {tol})",
                    shape.name);
        }
    }
}
