//! Shared helpers for integration tests.
//!
//! Integration tests need real AOT artifacts (`make artifacts`).  When
//! they are absent (e.g. a pure-cargo CI leg) the tests SKIP rather
//! than fail, loudly.

use std::path::PathBuf;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("SLA2_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts",
                                    env!("CARGO_MANIFEST_DIR")));
    let p = PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?} — run `make artifacts`");
        None
    }
}

/// Naive O(N^2) softmax attention on the host — the cross-language
/// oracle for the HLO kernels.
#[allow(dead_code)] // used by runtime_artifacts.rs, not every test bin
pub fn naive_attention(q: &[f32], k: &[f32], v: &[f32], n: usize,
                       d: usize) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    let mut row = vec![0.0f32; n];
    for i in 0..n {
        let mut mx = f32::NEG_INFINITY;
        for j in 0..n {
            let mut s = 0.0;
            for a in 0..d {
                s += q[i * d + a] * k[j * d + a];
            }
            row[j] = s * scale;
            mx = mx.max(row[j]);
        }
        let mut denom = 0.0;
        for j in 0..n {
            row[j] = (row[j] - mx).exp();
            denom += row[j];
        }
        for j in 0..n {
            let p = row[j] / denom;
            for a in 0..d {
                out[i * d + a] += p * v[j * d + a];
            }
        }
    }
    out
}
