//! Shared helpers for integration tests.
//!
//! Integration tests need real AOT artifacts (`make artifacts`).  When
//! they are absent (e.g. a pure-cargo CI leg) the tests SKIP rather
//! than fail, loudly.

use std::path::PathBuf;

/// The shared attention-conformance harness (naive full-softmax
/// reference, rel_err, seeded peaked-input generator, per-shape parity
/// runner).  Self-contained so benches can include the same file via
/// `#[path]`.
#[allow(dead_code)] // each test bin uses the slice it needs
pub mod conformance;

/// Back-compat alias: the full-softmax oracle now lives in the
/// conformance harness.
#[allow(unused_imports)]
pub use conformance::naive_attention;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("SLA2_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts",
                                    env!("CARGO_MANIFEST_DIR")));
    let p = PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?} — run `make artifacts`");
        None
    }
}

